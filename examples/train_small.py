"""Train a ~100M-param model for a few hundred steps on the QA corpus
(deliverable b: end-to-end training driver).

    PYTHONPATH=src python examples/train_small.py [--steps 300]

Uses the mamba2-130m architecture at FULL assigned size (130M params — the
one assigned config that is genuinely CPU-trainable), the synthetic QA
corpus, AdamW + cosine schedule, and checkpoints at the end.
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced variant (fast CI)")
    args = ap.parse_args()
    argv = ["train", "--arch", "mamba2-130m", "--steps", str(args.steps),
            "--batch", "4", "--seq", "128", "--lr", "1e-3",
            "--checkpoint", "/tmp/repro_mamba2_130m.npz"]
    if not args.reduced:
        argv.append("--full")
    sys.argv = argv
    train_main()
