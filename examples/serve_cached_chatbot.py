"""End-to-end driver (deliverable b): a cached customer-service chatbot
serving batched requests with a REAL model backend (reduced yi-6b) behind
the semantic cache.

    PYTHONPATH=src python examples/serve_cached_chatbot.py

Pipeline per batch: embed -> semantic cache lookup -> hits answered from
the cache -> misses answered by the JAX model (prefill + greedy decode)
and inserted. Prints the paper's serving metrics at the end.
"""
import jax

from repro.configs import get_arch
from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.data.tokenizer import HashTokenizer
from repro.models.model import Model
from repro.serving import CachedEngine, ModelBackend, Request

print("building reduced yi-6b backend ...")
config = get_arch("yi-6b").reduced()
model = Model(config)
params = model.init_params(jax.random.PRNGKey(0))
backend = ModelBackend(model, params, HashTokenizer(vocab_size=config.vocab),
                       max_prompt_len=32, max_new_tokens=12)

engine = CachedEngine(
    CacheConfig(dim=384, capacity=4096, value_len=32, ttl=None, threshold=0.8),
    backend, batch_size=16)

pairs = build_corpus(100, seed=0)
queries = build_test_queries(pairs, n_per_category=10, seed=1)

# first pass: everything misses -> the model generates (and is cached)
reqs = [Request(query=q.query, category=q.category) for q in queries[:32]]
print("pass 1 (cold cache) ...")
r1 = engine.process(reqs)
print(f"  hits: {sum(r.cached for r in r1)}/32, model calls: {backend.calls}")

# second pass: identical traffic -> served from cache, no model calls
print("pass 2 (warm cache) ...")
calls_before = backend.calls
r2 = engine.process(reqs)
print(f"  hits: {sum(r.cached for r in r2)}/32, "
      f"new model calls: {backend.calls - calls_before}")

# NOTE: the backend model is randomly initialized (no checkpoint downloads
# offline), so its generations are gibberish tokens — the point of this
# example is the CACHE behaviour: pass 2 answers are identical bytes to
# pass 1 and cost zero model calls. Train the backend first (see
# examples/train_small.py) for meaningful text.
for r in r2[:3]:
    print(f"  [cached={r.cached} score={r.score:.2f}] {r.answer[:70]}")

import json
print(json.dumps(engine.metrics.summary(), indent=1))
