"""Multi-tenant cached chatbot: three isolation domains sharing ONE
device-resident semantic cache and one compiled step (DESIGN.md §13).

    PYTHONPATH=src python examples/multi_tenant_chatbot.py

Scenes over the simulated LLM API:

  1. *isolation* — "acme" caches an answer; "globex" asking the byte-
     identical question (cosine similarity 1.0) still misses: the
     partition map makes other tenants' entries invisible, not merely
     sub-threshold;
  2. *noisy neighbour* — "free" floods the scheduler while "enterprise"
     trickles; deficit-round-robin admission keeps the trickle tenant's
     latency flat instead of queueing it behind the flood;
  3. *accounting* — per-tenant hit/miss/insert/eviction counters from the
     device (TenancyState) and per-tenant latency percentiles from the
     host (ServingMetrics).
"""
import asyncio
import json

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, ServingMetrics,
                           SimulatedLLMBackend, build_multi_tenant_workload)
from repro.tenancy import TenantRegistry, TenantSpec

registry = TenantRegistry((
    TenantSpec("acme", share=2.0, weight=2.0),
    TenantSpec("globex", share=1.0, weight=1.0),
    TenantSpec("free", share=1.0, weight=1.0),
))

print("building corpus and warming each tenant's region ...")
pairs = build_corpus(120, seed=0)
backend = SimulatedLLMBackend(pairs, latency_per_call_s=0.02, block=True)
engine = CachedEngine(
    CacheConfig(dim=384, capacity=3 * 4096, value_len=48, ttl=None,
                threshold=0.8),
    backend, batch_size=16, registry=registry)
for name in registry.names:
    engine.warm(pairs[:60], tenant=name)
# compile the serve path outside the timed scenes, then zero the metrics
engine.serve_batch([Request(query="compile warmup", tenant="acme")])
engine.metrics = ServingMetrics()

# -- scene 1: isolation at cosine 1.0 ---------------------------------- #
q = "is the artisanal coffee subscription available in belgium"
first = engine.process([Request(query=q, tenant="acme")])[0]
again = engine.process([Request(query=q, tenant="acme")])[0]
cross = engine.process([Request(query=q, tenant="globex")])[0]
print(f"isolation: acme first={first.cached} acme again={again.cached} "
      f"globex same bytes={cross.cached}  (True/False = hit/miss)")
assert again.cached and not cross.cached


async def main():
    sched = SchedulerConfig(max_batch=16, max_wait_ms=3.0,
                            tenant_weights=registry.weights(),
                            max_queue_per_tenant=256)
    async with AsyncCacheServer(engine, sched) as server:
        # -- scene 2: noisy neighbour ---------------------------------- #
        flood = build_multi_tenant_workload(
            pairs, 240, tenants=["free"], skew=0.0, seed=7)
        vip = [Request(query=p.question, tenant="acme")
               for p in pairs[:20]]          # warm entries -> pure hits

        flood_tasks = [asyncio.create_task(server.submit_request(r))
                       for r in flood]
        await asyncio.sleep(0.01)            # flood is queued first
        vip_resp = await asyncio.gather(
            *(server.submit_request(r) for r in vip))
        await asyncio.gather(*flood_tasks)
        vip_p95 = sorted(r.latency_s for r in vip_resp)[
            int(0.95 * (len(vip_resp) - 1))]
        print(f"noisy neighbour: acme served {len(vip_resp)} hits at "
              f"p95={vip_p95 * 1e3:.1f}ms while free flooded "
              f"{len(flood)} requests")

asyncio.run(main())

# -- scene 3: per-tenant accounting ------------------------------------ #
print("device-side per-tenant counters:")
print(json.dumps(engine.tenant_stats(), indent=1))
print("host-side per-tenant serving metrics:")
print(json.dumps(engine.metrics.summary()["tenants"], indent=1))
