"""Multi-turn cached chatbot: one semantic cache serving *conversations*,
with each session's recent turns fused into the lookup key (DESIGN.md §16).

    PYTHONPATH=src python examples/multi_turn_chatbot.py

Scenes over the simulated LLM API:

  1. *ellipsis* — a follow-up that is meaningless in isolation ("what
     about the free tier?") misses, is answered, and a second
     conversation in the same dialogue state asking it *differently*
     ("would the same hold for the free tier?") hits the fused entry —
     while a stateless engine serving the identical traffic cannot;
  2. *no collision* — the byte-identical follow-up text under an
     unrelated conversation misses: different dialogue state, different
     fused key (the rotated-subspace guarantee, §16.2);
  3. *wire protocol* — the TCP JSON-lines front-end with the additive
     ``session`` field and the ``context`` response flag; a request line
     without the field gets the pre-session payload byte-for-byte;
  4. *hygiene* — session-store counters: bounded sessions, TTL expiry.
"""
import asyncio
import json

from repro.context import DecayMeanFusion
from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SimulatedLLMBackend)

print("building corpus and two engines (context fusion on / off) ...")
pairs = build_corpus(120, seed=0)


def mk_engine(fusion):
    eng = CachedEngine(
        CacheConfig(dim=384, capacity=4096, value_len=48, ttl=None,
                    threshold=0.8),
        SimulatedLLMBackend(pairs, latency_per_call_s=0.02),
        batch_size=8, fusion=fusion, session_ttl_s=1800.0, max_sessions=64)
    eng.warm(pairs[:60])
    return eng


fused = mk_engine(DecayMeanFusion(window=4))
stateless = mk_engine(None)

OPENER = pairs[0].question
FOLLOW_A = "what about the free tier?"            # recording's phrasing
FOLLOW_B = "would the same hold for the free tier?"   # replay's phrasing


def turn(eng, query, session):
    return eng.process([Request(query=query, session=session)])[0]


# -- scene 1: elliptical follow-ups across two conversations ------------ #
# recording: opener (warm hit) then an elliptical follow-up (miss -> LLM)
rec_open = turn(fused, OPENER, "conv-rec")
rec_follow = turn(fused, FOLLOW_A, "conv-rec")
# replay: same opener verbatim, then the follow-up REPHRASED
rep_open = turn(fused, OPENER, "conv-rep")
rep_follow = turn(fused, FOLLOW_B, "conv-rep")
print(f"fused:     recording follow-up cached={rec_follow.cached} "
      f"(miss, pays the LLM) -> replay rephrased cached={rep_follow.cached} "
      f"score={rep_follow.score:.3f}")
assert not rec_follow.cached and rep_follow.cached
assert rep_follow.answer == rec_follow.answer

# identical traffic through the stateless engine: the rephrased follow-up
# shares too few tokens with anything cached — it can only miss
for q, s in ((OPENER, "conv-rec"), (FOLLOW_A, "conv-rec"),
             (OPENER, "conv-rep")):
    turn(stateless, q, s)
flat = turn(stateless, FOLLOW_B, "conv-rep")
print(f"stateless: replay rephrased cached={flat.cached} "
      f"score={flat.score:.3f}  (no context to resolve the ellipsis)")
assert not flat.cached

# -- scene 2: same text, different dialogue state ----------------------- #
turn(fused, pairs[1].question, "conv-other")      # an unrelated opener
other = turn(fused, FOLLOW_A, "conv-other")       # byte-identical text!
print(f"collision: identical follow-up text under an unrelated "
      f"conversation cached={other.cached} (must be a miss)")
assert not other.cached


# -- scene 3: the wire protocol ----------------------------------------- #
async def wire_demo():
    async with AsyncCacheServer(fused) as server:
        port = await server.serve_tcp()
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        lines = [
            # a fresh dialogue state (unused opener): its follow-up has
            # nothing fused to hit, so the flags below are deterministic
            {"id": 1, "query": pairs[2].question, "session": "wire-conv"},
            {"id": 2, "query": "and for mobile devices?",
             "session": "wire-conv"},
            {"id": 3, "query": OPENER},           # no session field
        ]
        out = {}
        # a session's turns are sequential: await each response before
        # sending the next turn (the §16.1 ordering contract — pipelining
        # two turns of ONE session would co-batch them blind to each other)
        for obj in lines:
            writer.write(json.dumps(obj).encode() + b"\n")
            await writer.drain()
            resp = json.loads(await reader.readline())
            out[resp["id"]] = resp
        writer.close()
        await writer.wait_closed()
        return out

replies = asyncio.run(wire_demo())
print("wire: session line ->", {k: replies[2][k] for k in
                                ("cached", "context")})
assert replies[2]["context"] is True              # fused under a window
assert replies[2]["cached"] is False              # fresh dialogue state
assert "context" not in replies[3]                # stateless line: old payload

# -- scene 4: session hygiene ------------------------------------------- #
fused.tick(3600.0)                                # everyone idle past TTL
turn(fused, "a fresh question after the lull", "conv-new")
print("session store:", json.dumps(fused.sessions.stats()))
assert fused.sessions.stats()["sessions"] <= 64
print("ok")
