"""Generative near-hit chatbot: the [τ_lo, τ_hi) band turning almost-hits
into synthesized answers instead of backend calls (DESIGN.md §17).

    PYTHONPATH=src python examples/generative_cache_chatbot.py

Scenes over the simulated LLM API:

  1. *the band* — the same paraphrase traffic through an exact-reuse
     engine and a banded engine with a ``TemplateSplice`` synthesizer:
     near-hits convert, backend calls drop strictly below the baseline,
     and every row the exact path hit is byte-identical;
  2. *admission* — a served near-hit is admitted under the query's own
     key: repeating it is an exact hit with zero new backend calls;
  3. *abstention* — a rivalrous band row (two neighbours of different
     provenance, close scores) abstains and pays the backend: synthesis
     reduces cost, never correctness;
  4. *small-model rewrite* — the same gate with a cheap rewrite call at
     ~10% of a full backend call, with its cost/latency accounted;
  5. *wire protocol* — the additive ``near_hit`` response flag; a
     band-less engine's payload stays byte-for-byte the old one.
"""
import asyncio
import json

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.generative import (BandPolicy, SmallModelRewrite,
                              SmallRewriteBackend, TemplateSplice)
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SimulatedLLMBackend)

print("building corpus and engines (band on / off) ...")
pairs = build_corpus(100, seed=0)
key_by_sid = {p.qa_id: p.semantic_key for p in pairs}


def judge(req, sid):
    return key_by_sid.get(sid, "") == req.semantic_key


def mk_engine(synthesizer=None):
    eng = CachedEngine(
        CacheConfig(dim=384, capacity=4096, value_len=48, ttl=None,
                    threshold=0.8),
        SimulatedLLMBackend(pairs, latency_per_call_s=0.02),
        judge=judge, batch_size=8, synthesizer=synthesizer,
        policy=None if synthesizer is None
        else BandPolicy(tau_lo=0.75, tau_hi=0.8))
    eng.warm(pairs)
    return eng


queries = build_test_queries(pairs, 60, paraphrase_ratio=0.8, seed=1)
reqs = [Request(query=q.query, category=q.category, source_id=q.source_id,
                semantic_key=q.semantic_key) for q in queries]

# -- scene 1: the band vs exact reuse ----------------------------------- #
exact = mk_engine()
exact_resp = exact.process(reqs)
banded = mk_engine(TemplateSplice(rival_margin=0.12))
band_resp = banded.process(reqs)

near = banded.metrics.near
print(f"band: {near.band} band rows -> {near.served} near-hits served "
      f"(judge precision {near.precision:.2f}), backend calls "
      f"{banded.backend.calls} vs {exact.backend.calls} exact-only")
assert near.served > 0
assert banded.backend.calls < exact.backend.calls
for a, b in zip(exact_resp, band_resp):
    if a.cached:                       # exact-path rows are untouched
        assert b.cached and b.answer == a.answer and b.score == a.score

# -- scene 2: admission under the query's own key ----------------------- #
i = next(i for i, r in enumerate(band_resp) if r.near_hit)
calls = banded.backend.calls
again = banded.process([reqs[i]])[0]
print(f"admission: near-hit repeat cached={again.cached} "
      f"near_hit={again.near_hit} new_backend_calls="
      f"{banded.backend.calls - calls}")
assert again.cached and not again.near_hit
assert again.answer == band_resp[i].answer
assert banded.backend.calls == calls

# -- scene 3: abstention on rivalrous neighbours ------------------------ #
from repro.generative import Neighbour  # noqa: E402

splice = TemplateSplice(rival_margin=0.12)
confident = splice.synthesize("q", [
    Neighbour(slot=0, score=0.78, source_id=7, answer="the dominant one"),
    Neighbour(slot=1, score=0.61, source_id=9, answer="a distant rival")])
rivalrous = splice.synthesize("q", [
    Neighbour(slot=0, score=0.78, source_id=7, answer="too close"),
    Neighbour(slot=1, score=0.74, source_id=9, answer="to call")])
print(f"abstention: clear margin -> {confident.answer!r}; "
      f"rival within margin -> {rivalrous}")
assert confident is not None and rivalrous is None

# -- scene 4: small-model rewrite at ~10% cost --------------------------- #
small = SmallRewriteBackend(latency_per_call_s=0.002,
                            cost_per_call_usd=0.0002)
rewriter = mk_engine(SmallModelRewrite(backend=small))
rewriter.process(reqs)
m = rewriter.metrics.near
print(f"rewrite: {m.served} rewrites, {small.calls} small-model calls, "
      f"synthesis cost ${m.synthesis_cost_usd:.4f} "
      f"(vs ${0.002 * m.served:.4f} at full-call price)")
assert small.calls == m.served > 0
assert m.synthesis_cost_usd < 0.002 * m.served


# -- scene 5: the wire protocol ----------------------------------------- #
async def wire_demo(engine):
    async with AsyncCacheServer(engine) as server:
        port = await server.serve_tcp()
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write(json.dumps(
            {"id": 1, "query": pairs[0].question}).encode() + b"\n")
        await writer.drain()
        resp = json.loads(await reader.readline())
        writer.close()
        await writer.wait_closed()
        return resp

with_band = asyncio.run(wire_demo(banded))
without = asyncio.run(wire_demo(exact))
print("wire: banded ->", {k: with_band[k] for k in ("cached", "near_hit")},
      "| band-less keys:", sorted(without))
assert "near_hit" in with_band
assert "near_hit" not in without           # additive: old payload untouched
print("ok")
