"""Async cached chatbot: concurrent traffic through the micro-batch
scheduler with in-flight coalescing (DESIGN.md §12).

    PYTHONPATH=src python examples/async_chatbot.py

Three scenes over the simulated LLM API (gold-answer oracle with a real
blocking per-call latency so the timings below are wall-clock):

  1. a *thundering herd* — 24 users ask the same novel question at the
     same instant; coalescing answers all 24 with ONE backend call;
  2. open-loop Poisson chat traffic with a paraphrase/repeat mixture —
     continuous micro-batches, hits from the warm cache, misses batched
     to the backend;
  3. the serving summary: paper metrics plus p50/p95/p99 per path and the
     coalesced-call count.
"""
import asyncio
import json

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, ServingMetrics,
                           SimulatedLLMBackend, build_workload,
                           run_open_loop)

print("warming the semantic cache with the QA corpus ...")
pairs = build_corpus(150, seed=0)
backend = SimulatedLLMBackend(pairs, latency_per_call_s=0.05, block=True)
engine = CachedEngine(
    CacheConfig(dim=384, capacity=8192, value_len=48, ttl=None, threshold=0.8),
    backend, batch_size=16)
engine.warm(pairs)
# compile the serve path outside the timed scenes, then zero the metrics
# so the summary in scene 3 shows only real traffic
engine.serve_batch([Request(query="compile warmup")])
engine.metrics = ServingMetrics()


async def main():
    sched = SchedulerConfig(max_batch=16, max_wait_ms=3.0, coalesce=True)
    async with AsyncCacheServer(engine, sched) as server:
        # -- scene 1: thundering herd ---------------------------------- #
        herd_q = "do you ship the limited edition console to antarctica"
        calls_before = backend.calls
        responses = await asyncio.gather(
            *(server.submit(herd_q, category="customer_shopping")
              for _ in range(24)))
        assert len({r.answer for r in responses}) == 1
        print(f"herd: 24 identical concurrent questions -> "
              f"{backend.calls - calls_before} backend call(s), "
              f"{sum(r.coalesced for r in responses)} coalesced")

        # -- scene 2: Poisson chat traffic ------------------------------ #
        workload = build_workload(pairs, 200, paraphrase_ratio=0.8,
                                  burst_prob=0.25, burst_size=6, seed=42)
        res = await run_open_loop(server.submit_request, workload,
                                  rate_qps=300.0)
        hits = sum(r.cached for r in res.responses)
        print(f"traffic: {len(res.responses)} requests at "
              f"{res.achieved_qps:.0f} qps sustained, {hits} cache hits, "
              f"{backend.calls} total backend calls")

# -- scene 3: the serving summary ------------------------------------- #
asyncio.run(main())
print(json.dumps(engine.metrics.summary(), indent=1))
