"""Resilient serving demo: a backend outage the cache survives
(DESIGN.md §20).

    PYTHONPATH=src python examples/resilience_demo.py

Five scenes over the simulated LLM API wrapped in a deterministic fault
schedule (windows are keyed by backend call index, so every run of this
script injects exactly the same faults):

  1. a *transient blip* — one failed call, absorbed by a budgeted retry;
     the caller never notices;
  2. a *hard outage* — every call fails; the circuit breaker trips and
     the warm cache keeps answering in degraded mode (best cached
     neighbour above the relaxed floor, flagged ``degraded=True``, never
     admitted to the slab);
  3. *recovery* — the outage window ends, a half-open probe succeeds,
     the breaker closes, and the same query now pays a real backend call
     (proof the degraded answer was never cached under its key);
  4. a *spent deadline* — ``deadline_ms=0`` skips the backend entirely
     and the row falls straight to degraded serving;
  5. the serving summary's new ``resilience`` section plus the breaker's
     final state.
"""
import json

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (CachedEngine, CircuitBreaker, FaultSchedule,
                           FaultWindow, FaultyBackend, Request,
                           ResilienceConfig, RetryPolicy, SimulatedLLMBackend)

print("warming the semantic cache with the QA corpus ...")
pairs = build_corpus(150, seed=0)

# call-index fault schedule: call 0 is a blip, calls 1-6 a hard outage
schedule = FaultSchedule((
    FaultWindow("error", 0, 1),          # scene 1: one transient failure
    FaultWindow("error", 2, 7),          # scene 2: sustained outage
))
backend = FaultyBackend(SimulatedLLMBackend(pairs), schedule)

resilience = ResilienceConfig(
    retry=RetryPolicy(max_attempts=3, base_backoff_s=0.01, max_backoff_s=0.05),
    breaker=CircuitBreaker(failure_threshold=3, cooldown_s=0.0),
    degraded_band_lo=0.3)                # relaxed floor for the demo corpus
engine = CachedEngine(
    CacheConfig(dim=384, capacity=8192, value_len=48, ttl=None, threshold=0.8),
    backend, batch_size=4, resilience=resilience)
engine.warm(pairs)

# -- scene 1: transient blip, absorbed by one retry --------------------- #
r = engine.process([Request(query="does the orbital hotel have a gym")])[0]
rm = engine.metrics.resilience
print(f"blip: answered={bool(r.answer)} degraded={r.degraded} "
      f"retries={rm.retries} retry_successes={rm.retry_successes}")
assert r.answer and not r.degraded and rm.retry_successes == 1

# -- scene 2: hard outage -> breaker trips, cache serves degraded ------- #
outage_q = "recommend a warranty plan for my kitchen robot"
r = engine.process([Request(query=outage_q)])[0]
print(f"outage: degraded={r.degraded} score={r.score:.2f} "
      f"breaker={resilience.breaker.state} trips={resilience.breaker.trips} "
      f"answer={r.answer[:40]!r}...")
assert r.degraded and r.error == ""

# -- scene 3: recovery — probe closes the breaker, query pays for real -- #
r = engine.process([Request(query=outage_q)])[0]
print(f"recovery: cached={r.cached} degraded={r.degraded} "
      f"breaker={resilience.breaker.state} "
      f"recoveries={resilience.breaker.recoveries}")
# the degraded answer was never admitted, so this is a REAL miss + call
assert not r.degraded and resilience.breaker.state == "closed"

# -- scene 4: a spent deadline fails fast, no backend call -------------- #
calls = backend.calls_started
r = engine.process([Request(query="what is the meaning of liff",
                            deadline_ms=0.0)])[0]
print(f"deadline: served_degraded={r.degraded} "
      f"backend_calls_spent={backend.calls_started - calls}")
assert backend.calls_started == calls and r.degraded

# -- scene 5: the resilience section of the serving summary ------------- #
summary = engine.metrics.summary()
print(json.dumps({"resilience": summary["resilience"],
                  "faults_injected": backend.faults_injected,
                  "breaker_state": resilience.breaker.state}, indent=1))
