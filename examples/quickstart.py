"""Quickstart: the GPT Semantic Cache in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Embeds queries, caches responses, and shows a paraphrase being served from
the cache without an LLM call (the paper's core loop, §2.5). All cache
state — slab, counters, policy and index state — lives in one
``CacheRuntime`` pytree threaded through the pure lookup/insert calls.
"""
import jax.numpy as jnp

from repro.core import CacheConfig, SemanticCache
from repro.embedding import HashEmbedder
from repro.data.tokenizer import HashTokenizer

# 1. a semantic cache: 384-dim embeddings, cosine threshold 0.8, 1h TTL
cache = SemanticCache(CacheConfig(dim=384, capacity=1024, value_len=32,
                                  ttl=3600.0, threshold=0.8))
runtime = cache.init()           # one pytree: slab + stats + policy + index
embedder = HashEmbedder(dim=384)
tok = HashTokenizer()

# 2. cache one (question, answer) pair, as if an LLM had just answered it
question = "How do I reset my online banking password?"
answer = "Go to Settings -> Security -> Reset password, then follow the email link."
emb = jnp.asarray(embedder.embed_batch([question]))
toks, lens = tok.encode_batch([answer], 32)
runtime = cache.insert(runtime, emb, jnp.asarray(toks),
                       jnp.asarray(lens), now=0.0)

# 3. a semantically similar query arrives
paraphrase = "please how do I reset my online banking password"
q = jnp.asarray(embedder.embed_batch([paraphrase]))
result, runtime = cache.lookup(runtime, q, now=10.0)

print(f"query      : {paraphrase}")
print(f"cosine     : {float(result.score[0]):.3f}")
print(f"cache hit  : {bool(result.hit[0])}")
print(f"answer     : {tok.decode(result.values[0])}")

# 4. an unrelated query misses -> would go to the LLM
other = jnp.asarray(embedder.embed_batch(["what's the best pizza topping"]))
result, runtime = cache.lookup(runtime, other, now=11.0)
print(f"unrelated  : hit={bool(result.hit[0])} "
      f"(score {float(result.score[0]):.3f}) -> call the LLM")
print(f"stats      : lookups={int(runtime.stats.lookups)} "
      f"hits={int(runtime.stats.hits)}")
