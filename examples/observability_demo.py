"""Observability walkthrough (DESIGN.md §18): serve traffic through the
async stack with tracing + attribution + the event ring on, then drain
all three planes — a retained request trace, an ``explain`` decision
record, and a live ``GET /metrics`` scrape.

    PYTHONPATH=src python examples/observability_demo.py
"""
import asyncio
import json

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.obs import EventLog, TraceConfig, Tracer
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, SimulatedLLMBackend)
from repro.tenancy import TenantRegistry, TenantSpec

pairs = build_corpus(200, seed=0)
queries = build_test_queries(pairs, n_per_category=12, seed=1)

# two tenants, one with a stricter hit threshold — so the explain record
# has a tenant-sourced edge to attribute
registry = TenantRegistry((TenantSpec(name="acme", threshold=0.9),
                           TenantSpec(name="globex")))
engine = CachedEngine(
    CacheConfig(dim=384, capacity=8192, value_len=48, ttl=None,
                threshold=0.8),
    SimulatedLLMBackend(pairs, latency_per_call_s=0.01),
    batch_size=16, registry=registry,
    tracer=Tracer(TraceConfig(sample_rate=1.0, head=8, max_traces=512)),
    events=EventLog(capacity=256))
for name in registry.names:
    engine.warm(pairs[:100], tenant=name)


async def main():
    sched = SchedulerConfig(max_batch=16, max_wait_ms=5.0)
    async with AsyncCacheServer(engine, sched) as server:
        print("serving 48 queries (async scheduler, tracing on) ...")
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key,
                        tenant=registry.names[i % 2])
                for i, q in enumerate(queries[:48])]
        # a duplicate herd rides along so the trace set shows coalescing
        herd = [Request(query="what exactly does the warranty cover",
                        tenant="acme") for _ in range(4)]
        await asyncio.gather(*(server.submit_request(r)
                               for r in reqs + herd))

        print("\n--- one retained request trace " + "-" * 30)
        trace = engine.tracer.traces()[-1]
        print(json.dumps(trace.to_dict(), indent=1))

        print("\n--- per-stage decomposition over retained traces " + "-" * 12)
        print(json.dumps(engine.tracer.stage_decomposition(), indent=1))

        print("\n--- explain: why would this query hit/miss right now? " + "-" * 6)
        why = engine.explain(pairs[0].question, tenant="acme")
        print(json.dumps(why, indent=1))

        print("\n--- last structured events " + "-" * 34)
        for ev in engine.events.events()[-3:]:
            print(json.dumps(ev, sort_keys=True))

        print("\n--- GET /metrics (Prometheus text exposition) " + "-" * 15)
        try:
            port = await server.serve_metrics()
        except OSError as exc:          # sandboxed environment: render inline
            print(f"(no loopback sockets: {exc}; rendering directly)")
            text = server.exporter.render()
        else:
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            writer.write(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")
            await writer.drain()
            raw = await reader.read()
            writer.close()
            text = raw.decode().partition("\r\n\r\n")[2]
        wanted = ("repro_queries_total", "repro_coalesced_requests_total",
                  "repro_tenant_hits_total", "repro_latency_quantile",
                  "repro_trace_stage_seconds")
        for line in text.splitlines():
            if any(line.startswith(w) for w in wanted):
                print(line)
        print(f"({len(text.splitlines())} exposition lines total)")


asyncio.run(main())
