"""Distributed semantic cache demo (paper §2.10 future work, implemented).

    PYTHONPATH=src python examples/distributed_cache_demo.py

Runs the sharded cache on 8 forced host devices: the slab shards over the
``data`` mesh axis, lookups fan out with a pmax combine, inserts route
round-robin — a query cached on one shard is served to a query landing
anywhere on the mesh. State is one ``CacheRuntime`` pytree: slab sharded,
stats/policy replicated, threaded through the fused step.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import CacheConfig, DistributedCache, SemanticCache  # noqa: E402
from repro.embedding import HashEmbedder  # noqa: E402
from repro.data.tokenizer import HashTokenizer  # noqa: E402

mesh = jax.make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

cache = SemanticCache(CacheConfig(dim=384, capacity=1024, value_len=24,
                                  ttl=3600.0, threshold=0.8))
dc = DistributedCache(cache, mesh, cache_axes=("data",))
runtime = dc.init()
step = dc.make_lookup_insert()
embedder = HashEmbedder()
tok = HashTokenizer()

faqs = [
    ("what are the interest rates for savings accounts",
     "Savings accounts earn 4.1% APY, paid monthly."),
    ("how do i reset my online banking password",
     "Use Settings -> Security -> Reset password."),
    ("where is the nearest branch",
     "Use the branch locator on the website homepage."),
    ("how do i order a new debit card",
     "Request a replacement card under Cards -> Replace."),
]
q_emb = jnp.asarray(embedder.embed_batch([q for q, _ in faqs]))
vals, lens = tok.encode_batch([a for _, a in faqs], 24)

# pass 1: cold — every query misses and the responses are inserted (sharded)
runtime, (slot, score, hit, v, vl, src) = step(
    runtime, q_emb, jnp.asarray(vals), jnp.asarray(lens),
    jnp.arange(len(faqs)), jnp.float32(0.0))
print(f"cold pass: hits={int(np.asarray(hit).sum())}/4")
per_shard = np.asarray(runtime.state.valid).reshape(4, -1).sum(axis=1)
print(f"entries per cache shard (round-robin): {per_shard.tolist()}")

# pass 2: paraphrased traffic — served from whichever shard owns the entry
paraphrases = [
    "what are the interest rates for savings accounts please",
    "hi how do i reset my online banking password",
    "where is the nearest branch located",
    "how do i order a new debit card today",
]
p_emb = jnp.asarray(embedder.embed_batch(paraphrases))
runtime, (slot, score, hit, v, vl, src) = step(
    runtime, p_emb, jnp.asarray(vals), jnp.asarray(lens),
    jnp.arange(len(faqs)), jnp.float32(1.0))
for i, p in enumerate(paraphrases):
    print(f"[hit={bool(np.asarray(hit)[i])} score={float(np.asarray(score)[i]):.2f} "
          f"shard={int(np.asarray(slot)[i]) // dc.local_capacity}] {p}")
print(f"global stats: lookups={int(runtime.stats.lookups)} "
      f"hits={int(runtime.stats.hits)} inserts={int(runtime.stats.inserts)}")
