"""Kernel microbenchmarks: the scoring hot-spot at cache sizes from 4k to
512k entries (jnp/XLA path on this CPU host; the Pallas kernel is the TPU
target, validated in interpret mode by tests — interpret timings are
Python-bound and not meaningful, so we benchmark the oracle the kernel
replaces and report the analytic TPU-side expectation)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cosine_topk import quantize_keys


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def cosine_topk_scaling():
    rows = []
    d, b, k = 384, 32, 4
    f = jax.jit(lambda q, kk, v: ref.cosine_topk_ref(q, kk, v, k))
    fq = jax.jit(lambda q, kk, sc, v: ref.quant_cosine_topk_ref(q, kk, sc, v, k))
    for n in (4096, 32768, 131072, 524288):
        rng = jax.random.PRNGKey(n)
        kq, kk_ = jax.random.split(rng)
        q = jax.random.normal(kq, (b, d))
        q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
        keys = jax.random.normal(kk_, (n, d))
        keys = keys / jnp.linalg.norm(keys, axis=1, keepdims=True)
        valid = jnp.ones((n,), bool)
        t = _time(f, q, keys, valid)
        kq8, sc = quantize_keys(keys)
        tq = _time(fq, q, kq8, sc, valid)
        # TPU expectation: GEMM flops / MXU peak + slab HBM read
        flops = 2 * b * n * d
        mxu_s = flops / 197e12
        hbm_s = n * d * 4 / 819e9
        hbm_q = n * d * 1 / 819e9
        rows.append({
            "name": f"kernel/cosine_topk_n{n}",
            "us_per_call": t * 1e6,
            "derived": (f"cpu_f32_us={t*1e6:.0f} cpu_int8_us={tq*1e6:.0f} "
                        f"tpu_roofline_us={max(mxu_s, hbm_s)*1e6:.1f} "
                        f"tpu_int8_roofline_us={max(mxu_s, hbm_q)*1e6:.1f}"),
        })
    return rows, {}


def masked_lookup_scaling():
    """Per-row-masked (tenancy) lookup: interval operands vs a dense (B, N)
    mask (DESIGN.md §14).

    On TPU the interval kernel builds the visibility mask from iota in VMEM,
    so per-row masking adds exactly 8 bytes/row of operand traffic (start +
    size, int32) — O(B), independent of slab size — where a dense bool mask
    adds B*N bytes of HBM traffic on the lookup's memory-bound axis. This
    CPU host times the two jnp oracles (same contract as the kernels) and
    reports the operand-bytes ratio the kernel avoids.
    """
    rows = []
    d, b, k, tenants = 384, 32, 4, 8
    f_dense = jax.jit(lambda q, kk, m: ref.cosine_topk_ref(q, kk, m, k))
    f_intv = jax.jit(lambda q, kk, v, st, sz: ref.cosine_topk_interval_ref(
        q, kk, v, st, sz, k))
    for n in (32768, 131072, 524288):
        rng = jax.random.PRNGKey(n)
        kq, kk_, kt = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (b, d))
        q = q / jnp.linalg.norm(q, axis=1, keepdims=True)
        keys = jax.random.normal(kk_, (n, d))
        keys = keys / jnp.linalg.norm(keys, axis=1, keepdims=True)
        valid = jnp.ones((n,), bool)
        # uniform tenant partition: contiguous equal regions, random mix
        region = n // tenants
        tid = jax.random.randint(kt, (b,), 0, tenants, dtype=jnp.int32)
        starts, sizes = tid * region, jnp.full((b,), region, jnp.int32)
        dense = (jnp.arange(n, dtype=jnp.int32)[None, :] >= starts[:, None]) \
            & (jnp.arange(n, dtype=jnp.int32)[None, :]
               < (starts + sizes)[:, None])
        t_dense = _time(f_dense, q, keys, dense)
        t_intv = _time(f_intv, q, keys, valid, starts, sizes)
        mask_bytes = b * n            # (B, N) bool materialized in HBM
        intv_bytes = 2 * b * 4       # (B,) start + (B,) size, int32
        rows.append({
            "name": f"kernel/masked_lookup_n{n}",
            "us_per_call": t_intv * 1e6,
            "derived": (f"cpu_interval_us={t_intv*1e6:.0f} "
                        f"cpu_dense_mask_us={t_dense*1e6:.0f} "
                        f"mask_operand_bytes={mask_bytes} "
                        f"interval_operand_bytes={intv_bytes} "
                        f"hbm_traffic_saved={mask_bytes/intv_bytes:.0f}x"),
        })
    return rows, {}


def fused_ivf_bench():
    """Fused IVF candidate kernel vs the jnp gather path (DESIGN.md §15).

    On this CPU host we time the jnp IVF search (the path the kernel
    replaces; interpret-mode kernel timings are Python-bound and not
    meaningful) and report the *analytic per-lookup HBM operand bytes* of
    the candidate stage on TPU, per path:

      jnp gather path:  the (B, M, d) gathered-candidate tensor
                        materializes in HBM — slab rows are read by the
                        gather (slab dtype), the gathered tensor is written
                        (f32 after dequant) and re-read by the einsum:
                        B*M*d * (s + 4 + 4) bytes.
      fused kernel:     candidate rows stream HBM -> VMEM once (slab
                        dtype) and are scored from VMEM; the (B, M, d)
                        tensor never exists in HBM: B*M*d * s bytes
                        (+ O(B*M) id operands, counted).

    s = slab itemsize. The headline row is the int8 slab — the serving
    configuration (§14.3: the int8 slab exists precisely because this
    lookup is memory-bound) — where fused/jnp = 1/9; the f32 slab row
    (4/12 = 1/3) is reported alongside. Masked-candidate DMA skip and
    dedup only lower the fused side further; the analytic numbers ignore
    both (worst case for the kernel).
    """
    from repro.core.index import IVFIndex

    b, d, nprobe, cap, c = 128, 768, 8, 128, 64   # the §15 default config
    n = c * cap                                   # slab fully bucketable
    m = nprobe * cap
    rng = jax.random.PRNGKey(0)
    keys = jax.random.normal(rng, (n, d))
    keys = keys / jnp.linalg.norm(keys, axis=1, keepdims=True)
    keys8 = jnp.clip(jnp.round(keys * 127.0), -127, 127).astype(jnp.int8)
    valid = jnp.ones((n,), bool)
    queries = keys[:b] + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                  (b, d))
    queries = queries / jnp.linalg.norm(queries, axis=1, keepdims=True)
    ivf = IVFIndex(ncentroids=c, nprobe=nprobe, bucket_cap=cap, topk=4,
                   backend="jnp")
    st = ivf.fit(keys, valid, jax.random.PRNGKey(2))

    rows = []
    id_bytes = 2 * b * m * 4          # cand ids: SMEM + VMEM copies
    for label, slab, s_item in (("int8", keys8, 1), ("f32", keys, 4)):
        f = jax.jit(lambda q, kk: ivf.search(st, q, kk, valid))
        t = _time(f, queries, slab)
        jnp_bytes = b * m * d * (s_item + 4 + 4)
        fused_bytes = b * m * d * s_item + id_bytes
        name = ("kernel/ivf_fused_default" if label == "int8"
                else f"kernel/ivf_fused_{label}")
        rows.append({
            "name": name,
            "us_per_call": t * 1e6,
            "derived": (f"slab={label} cpu_jnp_us={t*1e6:.0f} "
                        f"jnp_gather_bytes={jnp_bytes} "
                        f"fused_bytes={fused_bytes} "
                        f"fused_over_jnp={fused_bytes/jnp_bytes:.3f} "
                        f"B={b} d={d} nprobe={nprobe} cap={cap}"),
        })
    return rows, {}


def ivf_crossover(full: bool = True):
    """Exact-vs-IVF wall-clock crossover over slab size N (DESIGN.md §15.5).

    Exact scoring is one dense (B, d) x (d, N) GEMM — unbeatable while the
    slab fits the arithmetic budget; IVF's probe + gather only pays off
    once N is large enough that scoring everything costs more than probing
    nprobe/C of it. This sweep times both jnp paths on the host (same
    contract as the kernels) and reports IVF recall@1 at each point."""
    from repro.core.index import ExactIndex, ExactState, IVFIndex

    d, b, c_frac = 384, 32, 64
    sizes = (4096, 16384, 65536) + ((262144,) if full else ())
    rows = []
    for n in sizes:
        rng = jax.random.PRNGKey(n)
        keys = jax.random.normal(rng, (n, d))
        keys = keys / jnp.linalg.norm(keys, axis=1, keepdims=True)
        valid = jnp.ones((n,), bool)
        queries = keys[:b] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (b, d))
        queries = queries / jnp.linalg.norm(queries, axis=1, keepdims=True)
        c = min(512, max(16, n // c_frac))
        ivf = IVFIndex(ncentroids=c, nprobe=8,
                       bucket_cap=max(128, 2 * n // c), topk=1,
                       backend="jnp")
        st = ivf.fit(keys, valid, jax.random.PRNGKey(2))
        fi = jax.jit(lambda q: ivf.search(st, q, keys, valid))
        fe = jax.jit(lambda q: ExactIndex(topk=1, backend="jnp").search(
            ExactState(), q, keys, valid))
        t_ivf = _time(fi, queries)
        t_ex = _time(fe, queries)
        _, i_ivf = fi(queries)
        _, i_ex = fe(queries)
        recall = float(jnp.mean((i_ivf[:, 0] == i_ex[:, 0]
                                 ).astype(jnp.float32)))
        rows.append({
            "name": f"kernel/ivf_crossover_n{n}",
            "us_per_call": t_ivf * 1e6,
            "derived": (f"ivf_us={t_ivf*1e6:.0f} exact_us={t_ex*1e6:.0f} "
                        f"speedup={t_ex/t_ivf:.2f}x recall@1={recall:.3f} "
                        f"ncentroids={c}"),
        })
    return rows, {}


def hnsw_vs_exact():
    """Paper-faithful HNSW vs the TPU-native exact scoring (DESIGN.md §3)."""
    import numpy as np
    from repro.core.hnsw import HNSWIndex
    d, n, nq = 384, 8192, 64
    rng = np.random.default_rng(0)
    keys = rng.normal(size=(n, d)).astype(np.float32)
    keys /= np.linalg.norm(keys, axis=1, keepdims=True)
    queries = keys[:nq] + 0.05 * rng.normal(size=(nq, d)).astype(np.float32)

    idx = HNSWIndex(dim=d, max_elements=n, m=16, ef_construction=100,
                    ef_search=64)
    t0 = time.perf_counter()
    for v in keys:
        idx.add(v)
    build_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    ids_h, _ = idx.search_batch(queries, 1)
    search_s = (time.perf_counter() - t0) / nq

    f = jax.jit(lambda q, kk, v: ref.cosine_topk_ref(q, kk, v, 1))
    qj = jnp.asarray(queries / np.linalg.norm(queries, axis=1, keepdims=True))
    kj = jnp.asarray(keys)
    valid = jnp.ones((n,), bool)
    exact_s = _time(f, qj, kj, valid) / nq
    s_ex, i_ex = f(qj, kj, valid)
    recall = float((np.asarray(i_ex)[:, 0] == ids_h[:, 0]).mean())
    rows = [{
        "name": "design3/hnsw_vs_exact",
        "us_per_call": search_s * 1e6,
        "derived": (f"hnsw_search_us={search_s*1e6:.0f} "
                    f"exact_batched_us={exact_s*1e6:.1f} "
                    f"hnsw_build_s={build_s:.1f} agreement={recall:.2f}"),
    }]
    return rows, {}


def ivf_bench():
    from repro.core.index import ExactIndex, ExactState, IVFIndex
    d, n, nq = 384, 65536, 64
    rng = jax.random.PRNGKey(0)
    keys = jax.random.normal(rng, (n, d))
    keys = keys / jnp.linalg.norm(keys, axis=1, keepdims=True)
    valid = jnp.ones((n,), bool)
    queries = keys[:nq] + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                   (nq, d))
    ivf = IVFIndex(ncentroids=256, nprobe=16, bucket_cap=512, topk=1)
    st = ivf.fit(keys, valid, jax.random.PRNGKey(2))
    fs = jax.jit(lambda q: ivf.search(st, q, keys, valid))
    fe = jax.jit(lambda q: ExactIndex(topk=1, backend="jnp").search(
        ExactState(), q, keys, valid))
    t_ivf = _time(fs, queries)
    t_ex = _time(fe, queries)
    _, i_ivf = fs(queries)
    _, i_ex = fe(queries)
    recall = float(jnp.mean((i_ivf[:, 0] == i_ex[:, 0]).astype(jnp.float32)))
    rows = [{
        "name": "beyond/ivf_n65536",
        "us_per_call": t_ivf * 1e6,
        "derived": (f"ivf_us={t_ivf*1e6:.0f} exact_us={t_ex*1e6:.0f} "
                    f"speedup={t_ex/t_ivf:.2f}x recall@1={recall:.3f}"),
    }]
    return rows, {}


def fused_step_bench():
    """Fused ``SemanticCache.step`` (one compiled dispatch) vs the real
    separate path — two jitted dispatches, lookup then masked insert, with
    the hit mask crossing the dispatch boundary — on a hot mixed hit/miss
    batch (DESIGN.md §7)."""
    from repro.core import CacheConfig, SemanticCache
    d, n, b, vlen = 384, 32768, 64, 32
    cfg = CacheConfig(dim=d, capacity=n, value_len=vlen, ttl=None,
                      threshold=0.8)
    cache = SemanticCache(cfg)
    runtime = cache.init()
    rng = jax.random.PRNGKey(0)
    warm_q = jax.random.normal(rng, (n // 2, d))
    vals = jnp.zeros((n // 2, vlen), jnp.int32)
    runtime = cache.insert(runtime, warm_q, vals,
                           jnp.full((n // 2,), vlen), 0.0)
    # half the batch paraphrases cached entries (hits), half is novel
    queries = jnp.concatenate([
        warm_q[:b // 2] + 0.01 * jax.random.normal(rng, (b // 2, d)),
        jax.random.normal(jax.random.PRNGKey(1), (b // 2, d))])
    mv = jnp.zeros((b, vlen), jnp.int32)
    ml = jnp.full((b,), vlen)

    fused = jax.jit(lambda rt, q, t: cache.step(rt, q, mv, ml, t))
    lookup_j = jax.jit(lambda rt, q, t: cache.lookup(rt, q, t))
    insert_j = jax.jit(lambda rt, q, m, t: cache.insert(
        rt, q, mv, ml, t, mask=m))

    def sep(q):
        res, rt = lookup_j(runtime, q, jnp.float32(1.0))
        return insert_j(rt, q, ~res.hit, jnp.float32(1.0))

    t_fused = _time(lambda q: fused(runtime, q, jnp.float32(1.0)), queries)
    t_sep = _time(sep, queries)
    rows = [{
        "name": "beyond/fused_step_n32768_b64",
        "us_per_call": t_fused * 1e6,
        "derived": (f"fused_us={t_fused*1e6:.0f} separate_us={t_sep*1e6:.0f} "
                    f"speedup={t_sep/max(t_fused, 1e-9):.2f}x"),
    }]
    return rows, {}
