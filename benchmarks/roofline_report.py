"""Render the §Roofline table from dry-run artifacts (benchmarks/artifacts)."""
from __future__ import annotations

import glob
import json
import os

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_artifacts(mesh: str = "pod_16x16") -> list[dict]:
    arts = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, f"*_{mesh}.json"))):
        with open(f) as fh:
            a = json.load(fh)
        if a.get("ok") and isinstance(a.get("roofline"), dict) \
                and "arch" in a.get("roofline", {}):
            arts.append(a)
    return arts


def markdown_table(mesh: str = "pod_16x16") -> str:
    arts = load_artifacts(mesh)
    arts.sort(key=lambda a: (a["arch"], SHAPE_ORDER.index(a["shape"])
                             if a["shape"] in SHAPE_ORDER else 9))
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| model_GF | HLO-true_GF | useful | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in arts:
        r = a["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['model_gflops']:.3g} "
            f"| {r['hlo_gflops']:.3g} | {r['useful_ratio']:.2f} "
            f"| {r.get('note', '')} |")
    return "\n".join(lines)


def rows_for_run(mesh: str = "pod_16x16"):
    rows = []
    for a in load_artifacts(mesh):
        r = a["roofline"]
        dom_s = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}[r["dominant"]]
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": dom_s * 1e6,
            "derived": (f"dominant={r['dominant']} "
                        f"compute_s={r['compute_s']:.4f} "
                        f"memory_s={r['memory_s']:.4f} "
                        f"collective_s={r['collective_s']:.4f} "
                        f"useful={r['useful_ratio']:.2f}"),
        })
    return rows, {}


def dryrun_summary_rows():
    rows = []
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        arts = load_artifacts(mesh)
        n_cache = len([1 for f in glob.glob(os.path.join(
            ART_DIR, f"semantic-cache_*_{mesh}.json"))])
        rows.append({
            "name": f"dryrun/{mesh}",
            "us_per_call": 0.0,
            "derived": f"model_pairs_ok={len(arts)} cache_step_ok={n_cache}",
        })
    return rows, {}
