"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table1/*        — paper Table 1 / Fig 4 (hits + positive hits per category)
  fig2/*          — API-call frequency, traditional vs cached
  fig3/*          — latency with vs without cache
  sec5.3/*        — threshold sweep 0.60..0.90
  sec2.7/*        — TTL behaviour
  kernel/*        — scoring-kernel scaling (slab 4k..512k)
  design3/*       — HNSW (paper algorithm) vs exact MXU scoring
  beyond/*        — IVF index (beyond-paper ANN); fused runtime step()
  roofline/*      — per (arch x shape) dominant roofline terms (from dry-run)
  dryrun/*        — dry-run coverage counters

Run ``python -m benchmarks.run --quick`` for a reduced-size pass.
"""
from __future__ import annotations

import argparse
import sys


def _emit(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced dataset sizes (CI-friendly)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark group names")
    args = ap.parse_args()
    full = not args.quick

    from benchmarks import kernel_bench, paper_tables, roofline_report

    groups = []
    groups.append(("table1", lambda: paper_tables.table1(full=full)))
    # fig2/fig3 reuse table1's system run only when sizes match; rerun cheap
    summary_holder = {}

    def _table1_then_figs():
        rows, s = paper_tables.table1(full=full)
        summary_holder["s"] = s
        return rows, s

    groups = [
        ("table1", _table1_then_figs),
        ("fig2", lambda: paper_tables.fig2(summary_holder.get("s"))),
        ("fig3", lambda: paper_tables.fig3(summary_holder.get("s"))),
        ("sec5.3", lambda: paper_tables.threshold_sweep(full=False)),
        ("sec2.7", paper_tables.ttl_behaviour),
        ("tenancy", lambda: paper_tables.tenant_table(full=full)),
        ("kernel", kernel_bench.cosine_topk_scaling),
        ("kernel-masked", kernel_bench.masked_lookup_scaling),
        ("design3", kernel_bench.hnsw_vs_exact),
        ("beyond", kernel_bench.ivf_bench),
        ("beyond-fused", kernel_bench.fused_step_bench),
        ("roofline", roofline_report.rows_for_run),
        ("dryrun", roofline_report.dryrun_summary_rows),
    ]

    for name, fn in groups:
        if args.only and args.only not in name:
            continue
        try:
            rows, _ = fn()
            _emit(rows)
        except Exception as e:  # noqa: BLE001 — keep the harness going
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
