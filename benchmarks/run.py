"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table1/*        — paper Table 1 / Fig 4 (hits + positive hits per category)
  fig2/*          — API-call frequency, traditional vs cached
  fig3/*          — latency with vs without cache
  sec5.3/*        — threshold sweep 0.60..0.90
  sec2.7/*        — TTL behaviour
  context/*       — multi-turn record/replay: fused vs stateless follow-up
                    hit conversion + context-hit precision (DESIGN.md §16)
  shard/*         — fused step on a 4-shard forced-CPU mesh vs local: step
                    us/call + hit-mask parity (DESIGN.md §19)
  fault/*         — resilient serving under deterministic chaos: availability
                    with vs without the §20 layer, retry/breaker counters,
                    degraded-mode serving (DESIGN.md §20)
  kernel/*        — scoring-kernel scaling (slab 4k..512k); fused-IVF
                    operand bytes + exact-vs-IVF crossover (DESIGN.md §15)
  design3/*       — HNSW (paper algorithm) vs exact MXU scoring
  beyond/*        — IVF index (beyond-paper ANN); fused runtime step()
  roofline/*      — per (arch x shape) dominant roofline terms (from dry-run)
  dryrun/*        — dry-run coverage counters

Run ``python -m benchmarks.run --quick`` for a reduced-size pass.
``--json PATH`` additionally writes the machine-readable artifact
``{"meta": {...}, "rows": [...], "errors": [...]}`` — the BENCH trajectory
format CI smokes and perf PRs diff against (every row keeps ``name``,
``us_per_call`` and the parsed ``key=value`` pairs of ``derived``).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def _emit(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        sys.stdout.flush()


def _derived_fields(derived: str) -> dict:
    """Parse the human-oriented ``key=value`` pairs (non-pairs are kept
    verbatim under ``notes``) so JSON consumers never re-parse strings."""
    fields, notes = {}, []
    for tok in str(derived).split():
        if "=" in tok:
            k, v = tok.split("=", 1)
            try:
                fields[k] = json.loads(v)
            except (json.JSONDecodeError, ValueError):
                fields[k] = v
        else:
            notes.append(tok)
    if notes:
        fields["notes"] = " ".join(notes)
    return fields


def _write_json(path: str, rows: list, errors: list, argv: list) -> None:
    import jax

    doc = {
        "meta": {
            "argv": argv,
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "unix_time": time.time(),
        },
        "rows": [{
            "name": r["name"],
            "us_per_call": float(r["us_per_call"]),
            "derived": _derived_fields(r["derived"]),
            "derived_raw": str(r["derived"]),
        } for r in rows],
        "errors": errors,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {len(doc['rows'])} rows -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced dataset sizes (CI-friendly)")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on benchmark "
                         "group names (a group runs if any filter matches)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a machine-readable results artifact")
    args = ap.parse_args()
    full = not args.quick

    from benchmarks import kernel_bench, paper_tables, roofline_report

    groups = []
    groups.append(("table1", lambda: paper_tables.table1(full=full)))
    # fig2/fig3 reuse table1's system run only when sizes match; rerun cheap
    summary_holder = {}

    def _table1_then_figs():
        rows, s = paper_tables.table1(full=full)
        summary_holder["s"] = s
        return rows, s

    groups = [
        ("table1", _table1_then_figs),
        ("fig2", lambda: paper_tables.fig2(summary_holder.get("s"))),
        ("fig3", lambda: paper_tables.fig3(summary_holder.get("s"))),
        ("sec5.3", lambda: paper_tables.threshold_sweep(full=False)),
        ("sec2.7", paper_tables.ttl_behaviour),
        ("tenancy", lambda: paper_tables.tenant_table(full=full)),
        ("context", lambda: paper_tables.context_table(full=full)),
        ("near", lambda: paper_tables.near_hit_table(full=full)),
        ("obs", lambda: paper_tables.obs_table(full=full)),
        ("shard", lambda: paper_tables.shard_table(full=full)),
        ("fault", lambda: paper_tables.resilience_table(full=full)),
        ("kernel", kernel_bench.cosine_topk_scaling),
        ("kernel-masked", kernel_bench.masked_lookup_scaling),
        ("kernel-ivf", kernel_bench.fused_ivf_bench),
        ("kernel-crossover", lambda: kernel_bench.ivf_crossover(full=full)),
        ("design3", kernel_bench.hnsw_vs_exact),
        ("beyond", kernel_bench.ivf_bench),
        ("beyond-fused", kernel_bench.fused_step_bench),
        ("roofline", roofline_report.rows_for_run),
        ("dryrun", roofline_report.dryrun_summary_rows),
    ]

    only = [s.strip() for s in args.only.split(",")] if args.only else None
    all_rows, errors = [], []
    for name, fn in groups:
        if only and not any(o and o in name for o in only):
            continue
        try:
            rows, _ = fn()
            _emit(rows)
            all_rows.extend(rows)
        except Exception as e:  # noqa: BLE001 — keep the harness going
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            errors.append({"group": name, "error": f"{type(e).__name__}: {e}"})

    if args.json:
        _write_json(args.json, all_rows, errors, sys.argv[1:])


if __name__ == "__main__":
    main()
