"""Tail-latency + coalescing benchmark for the async serving subsystem.

Six experiments on the simulated backend (DESIGN.md §12.5, §13.5, §16.6,
§17.6):

  1. **parity** — the async scheduler must reproduce the sync engine's
     results on an identical workload: same per-request hit/miss
     decisions, byte-identical answers, same hit rate. Driven in lockstep
     waves of ``max_batch`` so both paths see the same batch partitioning.
  2. **coalescing** — a duplicate-burst workload under open-loop Poisson
     arrivals, coalescing on vs off: reports backend calls, the reduction
     ratio, and coalesced-call counts.
  3. **tail latency** — open-loop Poisson at a configurable rate against a
     *blocking* backend (real sleeps): sustained QPS and p50/p95/p99 per
     path (hit / miss / coalesced).
  4. **tenancy** — a 3-tenant Zipf-skewed workload through a partitioned
     cache with DRR admission: cross-tenant isolation (an answer cached by
     one tenant must miss for another even for the byte-identical query),
     per-tenant accounting consistency, and per-tenant hit rates.
  5. **multi-turn** — record/replay conversations through the async
     scheduler with context fusion on vs off: replayed follow-up turns
     (globally unique raw texts) must convert from 0% hits stateless to
     hits under fusion, while context-hit precision clears the same >97%
     bar as stateless serving and the session store stays bounded.
  6. **near-hit** — the generative band (§17) against an exact-reuse-only
     baseline on the same workload: judged near-hits must convert, cut
     backend calls strictly beyond exact reuse at >0.9 judge precision,
     and leave every exact-hit row byte-identical.
  8. **sharded** — the mesh-backed engine (DESIGN.md §19): a large slab
     (≥1M slots; 64K in smoke) sharded over a forced-8-device CPU
     topology, driven by skewed multi-tenant Zipf traffic through the
     async scheduler. Asserts per-request decision parity against a
     single-shard engine on identical traffic, a cross-shard cache hit
     (warmed entries round-robin across shards and every query row finds
     them), and a hit-path p99 bound. Runs in a re-exec'd subprocess —
     the parent process has already initialized its single-device JAX.
  9. **resilience** — deterministic chaos (DESIGN.md §20.7): the same
     workload through the same seeded ``FaultSchedule`` (hard-error,
     brownout and latency-spike windows keyed by backend call index)
     with the resilience layer on vs off. Asserts zero stranded futures,
     availability strictly above the no-resilience baseline (degraded
     cache serving is doing real work), that the circuit breaker both
     trips and recovers, that deadline-expired rows fail fast without a
     backend call, a hit-path p99 bound on the unaffected traffic, and
     that the retry/breaker/degraded Prometheus families are served.

Output: ``name,value`` CSV rows, then a JSON metrics summary.

``--smoke`` shrinks sizes for CI and turns the parity/coalescing/tenancy
expectations into hard assertions (non-zero exit on violation), so a
scheduler or isolation regression fails the build.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.context import DecayMeanFusion
from repro.generative import BandPolicy, TemplateSplice
from repro.serving import (AsyncCacheServer, CachedEngine, CircuitBreaker,
                           FaultSchedule, FaultWindow, FaultyBackend,
                           Request, ResilienceConfig, Response, RetryPolicy,
                           SchedulerConfig, ServingMetrics,
                           SimulatedLLMBackend, availability,
                           build_multi_tenant_workload,
                           build_multi_turn_workload, build_workload,
                           run_open_loop, run_sessions, run_waves)
from repro.tenancy import TenantRegistry, TenantSpec


def _emit(name: str, value) -> None:
    print(f"{name},{value}")
    sys.stdout.flush()


def make_engine(pairs, *, batch_size: int, latency_s: float = 0.0,
                block: bool = False, warm: bool = True,
                registry=None, fusion=None, judge=None,
                max_sessions: int = 4096, synthesizer=None,
                policy=None, backend=None, resilience=None) -> CachedEngine:
    by_id = {p.qa_id: p for p in pairs}

    def default_judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    if backend is None:
        backend = SimulatedLLMBackend(pairs, latency_per_call_s=latency_s,
                                      block=block)
    per_tenant = max(4096, 8 * len(pairs))
    cfg = CacheConfig(dim=384,
                      capacity=per_tenant * (len(registry) if registry
                                             else 1),
                      value_len=48, ttl=None, threshold=0.8)
    eng = CachedEngine(cfg, backend, judge=judge or default_judge,
                       batch_size=batch_size, registry=registry,
                       fusion=fusion, max_sessions=max_sessions,
                       synthesizer=synthesizer, policy=policy,
                       resilience=resilience)
    if warm:
        if registry is None:
            eng.warm(pairs)
        else:
            for name in registry.names:
                eng.warm(pairs, tenant=name)
    return eng


def bench_parity(pairs, workload, *, batch: int) -> dict:
    """Sync engine vs async scheduler on the same workload/partitioning."""
    sync_eng = make_engine(pairs, batch_size=batch)
    sync_resp = sync_eng.process(workload)

    async_eng = make_engine(pairs, batch_size=batch)

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=50.0,
                                coalesce=False)
        async with AsyncCacheServer(async_eng, sched) as server:
            return await run_waves(server.submit_request, workload,
                                   wave=batch)
    async_resp = asyncio.run(drive()).responses

    decisions_match = all(a.cached == b.cached
                          for a, b in zip(sync_resp, async_resp))
    answers_match = all(a.answer == b.answer
                        for a, b in zip(sync_resp, async_resp))
    sync_hits = sum(r.cached for r in sync_resp)
    async_hits = sum(r.cached for r in async_resp)
    return {
        "sync_hit_rate": sync_hits / len(workload),
        "async_hit_rate": async_hits / len(workload),
        "decisions_match": decisions_match,
        "answers_match": answers_match,
    }


def bench_coalescing(pairs, workload, *, batch: int, rate_qps: float) -> dict:
    """Duplicate-burst workload, coalescing on vs off."""
    out = {}
    for coalesce in (False, True):
        eng = make_engine(pairs, batch_size=batch)

        async def drive():
            sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0,
                                    coalesce=coalesce)
            async with AsyncCacheServer(eng, sched) as server:
                return await run_open_loop(server.submit_request, workload,
                                           rate_qps=rate_qps, seed=7)
        asyncio.run(drive())
        tag = "coalesce_on" if coalesce else "coalesce_off"
        out[f"{tag}_backend_calls"] = eng.backend.calls
        out[f"{tag}_coalesced"] = eng.metrics.coalesced_calls
    off, on = out["coalesce_off_backend_calls"], \
        out["coalesce_on_backend_calls"]
    out["backend_call_reduction_pct"] = round(100.0 * (1 - on / max(off, 1)),
                                              2)
    return out


def bench_tail_latency(pairs, workload, *, batch: int, rate_qps: float,
                       llm_latency_s: float) -> dict:
    """Open-loop Poisson against a blocking backend: real wall-clock tails."""
    eng = make_engine(pairs, batch_size=batch, latency_s=llm_latency_s,
                      block=True)
    # compile the fused serve path before the clock starts — otherwise the
    # first micro-batch's jit trace (~1s) queues behind itself and floods
    # every percentile with cold-start time — then zero the bookkeeping so
    # the warmup row doesn't appear in the reported samples/counters
    eng.serve_batch([Request(query="serve-path warmup")])
    eng.metrics = ServingMetrics()

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0)
        async with AsyncCacheServer(eng, sched) as server:
            return await run_open_loop(server.submit_request, workload,
                                       rate_qps=rate_qps, seed=11)
    res = asyncio.run(drive())
    summary = eng.metrics.summary()
    return {
        "achieved_qps": round(res.achieved_qps, 1),
        "wall_s": round(res.wall_s, 3),
        "percentiles": summary["latency_percentiles"],
        "coalesced_calls": summary["coalesced_calls"],
    }


def bench_tenancy(pairs, *, batch: int, n_req: int, rate_qps: float) -> dict:
    """3-tenant Zipf-skewed workload through a partitioned cache (§13.5)."""
    registry = TenantRegistry((
        TenantSpec("free", share=1.0, weight=1.0),
        TenantSpec("pro", share=2.0, weight=2.0),
        TenantSpec("enterprise", share=2.0, weight=4.0),
    ))
    eng = make_engine(pairs, batch_size=batch, registry=registry)

    # isolation probe: a novel answer cached under 'free' must be invisible
    # to 'pro' even though the query bytes (hence the embedding) are equal
    probe = "what is the meaning of the tenant isolation probe"
    eng.process([Request(query=probe, tenant="free")])       # miss + insert
    again = eng.process([Request(query=probe, tenant="free")])[0]
    cross = eng.process([Request(query=probe, tenant="pro")])[0]
    isolation_ok = bool(again.cached) and not cross.cached

    workload = build_multi_tenant_workload(
        pairs, n_req, tenants=list(registry.names), skew=1.2,
        burst_prob=0.2, burst_size=4, seed=13)

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0,
                                tenant_weights=registry.weights(),
                                max_queue_per_tenant=max(batch, n_req // 4))
        async with AsyncCacheServer(eng, sched) as server:
            return await run_open_loop(server.submit_request, workload,
                                       rate_qps=rate_qps, seed=17)
    res = asyncio.run(drive())
    served_all = (len(res.responses) == n_req
                  and all(r is not None and r.answer for r in res.responses))

    dev = eng.tenant_stats()
    summary = eng.metrics.summary()
    host = summary["tenants"]
    # accounting: device-side per-tenant lookups must sum to the global
    # counter, and host-side per-tenant lookups to the query count
    accounting_ok = (
        sum(v["lookups"] for v in dev.values()) == int(eng.stats.lookups)
        and sum(v["hits"] for v in dev.values()) == int(eng.stats.hits)
        and sum(v["lookups"] for v in host.values()) == summary["queries"])
    out = {
        "isolation_ok": isolation_ok,
        "served_all": served_all,
        "accounting_ok": accounting_ok,
    }
    for name in registry.names:
        out[f"{name}_lookups"] = dev[name]["lookups"]
        out[f"{name}_hit_rate"] = round(
            dev[name]["hits"] / max(dev[name]["lookups"], 1), 4)
    return out


def bench_multi_turn(pairs, *, batch: int, n_groups: int,
                     turns: int) -> dict:
    """Record/replay conversations through the async scheduler, context
    fusion on vs off (DESIGN.md §16.6)."""
    convs = build_multi_turn_workload(pairs, n_groups, turns=turns, seed=23)
    rec, rep = convs[:n_groups], convs[n_groups:]
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}
    for conv in convs:
        for r in conv:
            key_by_sid.setdefault(r.source_id, r.semantic_key)

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key

    out = {}
    for tag, fusion in (("fusion_on", DecayMeanFusion(window=4)),
                        ("fusion_off", None)):
        eng = make_engine(pairs, batch_size=batch, fusion=fusion,
                          judge=judge)

        async def drive():
            sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0)
            async with AsyncCacheServer(eng, sched) as server:
                # replay only after every recording is fully served — a
                # replay's hits ARE the recording's inserts
                await run_sessions(server.submit_request, rec,
                                   concurrency=max(2, batch // 2))
                return await run_sessions(server.submit_request, rep,
                                          concurrency=max(2, batch // 2))
        res = asyncio.run(drive())
        s = eng.metrics.summary()
        m = s["categories"]["ctx/followup_repeat"]
        out[f"{tag}_followup_repeat_hit_rate"] = m["hit_rate"]
        out[f"{tag}_followup_repeat_positive_rate"] = m["positive_rate"]
        out[f"{tag}_backend_calls"] = eng.backend.calls
        if fusion is not None:
            replay_context = sum(
                r.context for r in res.responses if r is not None)
            c = s["context"]["context"]
            out["context_hit_rate"] = c["hit_rate"]
            out["context_positive_rate"] = c["positive_rate"]
            out["replay_context_rows"] = replay_context
            out["session_store"] = eng.sessions.stats()
            out["sessions_bounded"] = (
                len(eng.sessions) <= eng.sessions.max_sessions)
    return out


def bench_near_hit(pairs, workload, *, batch: int) -> dict:
    """Generative near-hit band vs exact-reuse-only baseline (§17).

    Same workload through (a) a plain exact-reuse engine and (b) a banded
    engine with a TemplateSplice synthesizer. The band engine must convert
    judged band rows into served near-hits, cut backend calls *strictly
    below* the exact-reuse baseline, keep judge-verified near precision
    high, and — because bands only touch rows the exact path would have
    missed — serve byte-identical answers on every row the baseline hit.
    """
    base = make_engine(pairs, batch_size=batch)
    base_resp = base.process(workload)

    banded = make_engine(pairs, batch_size=batch,
                         synthesizer=TemplateSplice(rival_margin=0.12),
                         policy=BandPolicy(tau_lo=0.75, tau_hi=0.8))
    band_resp = banded.process(workload)

    near = banded.metrics.near
    exact_rows_identical = all(
        b.cached and a.answer == b.answer and a.score == b.score
        for a, b in zip(base_resp, band_resp) if a.cached)
    return {
        "baseline_backend_calls": base.backend.calls,
        "band_backend_calls": banded.backend.calls,
        "calls_saved_beyond_exact": base.backend.calls - banded.backend.calls,
        "band_lookups": near.band,
        "near_hits_served": near.served,
        "near_conversion_rate": round(near.conversion_rate, 4),
        "near_precision": round(near.precision, 4),
        "synthesis_cost_usd": round(near.synthesis_cost_usd, 6),
        "exact_rows_identical": exact_rows_identical,
        "band_lo_final": round(float(banded.policy_state[0]), 4),
    }


def bench_observability(pairs, *, batch: int, n_req: int, rate_qps: float,
                        llm_latency_s: float) -> dict:
    """Observability plane end to end (DESIGN.md §18.6).

    (a) a traced run (sample rate 1.0) through the async scheduler on a
    2-tenant engine with a blocking backend: per-stage p50/p95 rows, the
    span-sum-vs-e2e invariant (the stage decomposition must reconstruct
    the measured end-to-end latency within 10% at p50/p95), and a live
    ``/metrics`` scrape validated against ``REQUIRED_FAMILIES`` plus
    per-tenant labels; (b) traced-vs-untraced sync throughput (best-of-3
    walls) bounding the tracing overhead; (c) the tracing-off path must
    start zero traces — the hot path allocates nothing.
    """
    import time as _time

    from repro.obs import EventLog, REQUIRED_FAMILIES, TraceConfig, Tracer
    from repro.serving.metrics import percentiles

    registry = TenantRegistry.uniform(["acme", "globex"])
    eng = make_engine(pairs, batch_size=batch, latency_s=llm_latency_s,
                      block=True, registry=registry)
    eng.events = EventLog(capacity=512)
    # compile before the clock starts, then zero the bookkeeping so the
    # warmup row doesn't appear in the reported traces/samples
    eng.serve_batch([Request(query="obs warmup", tenant="acme")])
    eng.metrics = ServingMetrics()
    eng.tracer = Tracer(TraceConfig(sample_rate=1.0, head=0,
                                    max_traces=8192))
    workload = build_multi_tenant_workload(
        pairs, n_req, tenants=list(registry.names), seed=31)
    scrape = {}

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0,
                                tenant_weights=registry.weights())
        async with AsyncCacheServer(eng, sched) as server:
            try:
                port = await server.serve_metrics()
            except OSError:
                port = None               # sandboxed CI: no sockets
            res = await run_open_loop(server.submit_request, workload,
                                      rate_qps=rate_qps, seed=37)
            if port is not None:
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", port)
                writer.write(b"GET /metrics HTTP/1.0\r\n\r\n")
                await writer.drain()
                raw = (await reader.read()).decode()
                writer.close()
                scrape["status"] = raw.split("\r\n", 1)[0]
                scrape["body"] = raw.split("\r\n\r\n", 1)[1]
            return res

    asyncio.run(drive())
    traces = eng.tracer.traces()
    e2e = [t.e2e_s for t in traces if t.e2e_s]
    sums = [t.span_sum_s for t in traces if t.e2e_s]
    p_e2e, p_sum = percentiles(e2e), percentiles(sums)
    out = {
        "traces_retained": len(traces),
        "span_sum_p50_ratio": round(
            p_sum["p50_s"] / max(p_e2e["p50_s"], 1e-9), 4),
        "span_sum_p95_ratio": round(
            p_sum["p95_s"] / max(p_e2e["p95_s"], 1e-9), 4),
        "events_logged": len(eng.events),
        "events_bounded": len(eng.events) <= eng.events.capacity,
    }
    for stage, row in eng.tracer.stage_decomposition().items():
        out[f"stage_{stage}_p50_s"] = row["p50_s"]
        out[f"stage_{stage}_p95_s"] = row["p95_s"]
    if scrape:
        body = scrape["body"]
        missing = [f for f in REQUIRED_FAMILIES
                   if f"# TYPE {f} " not in body]
        out["scrape_ok"] = (scrape["status"].endswith("200 OK")
                            and not missing
                            and 'tenant="acme"' in body
                            and 'tenant="globex"' in body)
    else:
        out["scrape_ok"] = None           # sockets unavailable: skipped

    # (b) tracing overhead: identical sync workloads, traced vs off —
    # best-of-3 walls so timer jitter doesn't drown the comparison
    sync_wl = build_workload(pairs, max(n_req, 4 * batch), burst_prob=0.0,
                             seed=41)
    walls = {}
    for tag, tracer in (("off", Tracer(TraceConfig.off())),
                        ("on", Tracer(TraceConfig(sample_rate=1.0,
                                                  max_traces=8192)))):
        e = make_engine(pairs, batch_size=batch)
        e.tracer = tracer
        e.process(sync_wl[:batch])        # compile before the clock
        best = float("inf")
        for _ in range(3):
            t0 = _time.perf_counter()
            e.process(sync_wl)
            best = min(best, _time.perf_counter() - t0)
        walls[tag] = best
    out["untraced_wall_s"] = round(walls["off"], 4)
    out["traced_wall_s"] = round(walls["on"], 4)
    out["trace_overhead_pct"] = round(
        100.0 * (walls["on"] / walls["off"] - 1.0), 2)

    # (c) tracing off = zero per-request tracing work
    off_eng = make_engine(pairs, batch_size=batch, warm=False)
    off_eng.process(sync_wl[:batch])
    out["off_path_traces_started"] = off_eng.tracer.started
    return out


def bench_resilience(pairs, *, batch: int, n_req: int) -> dict:
    """Stage 9: deterministic chaos serving (DESIGN.md §20.7).

    The SAME workload runs twice through the async scheduler against two
    fresh ``FaultyBackend`` wrappers sharing one seeded ``FaultSchedule``
    (a hard-error window, a 50% brownout, a latency spike — all keyed by
    backend call index, so lockstep waves make both runs bit-replayable):

      * **off** — a plain engine: per-row containment only (§20.2). Every
        miss row whose backend call falls in a fault window resolves as a
        ``BackendError``; hits in the same batch still serve.
      * **on**  — retries with deterministic backoff (no real sleeps), a
        zero-cooldown circuit breaker (trips during the error window,
        probes every batch, recovers as soon as the window passes), and
        degraded cache serving over a ``BandPolicy.degraded_lo`` floor.

    Availability (fraction of slots answered with an error-free Response)
    must be *strictly* higher with the layer on; the deadline probe at the
    end asserts an already-expired budget never reaches the backend.
    """
    from repro.obs import REQUIRED_FAMILIES
    from repro.obs.export import MetricsExporter

    schedule = FaultSchedule(windows=(
        FaultWindow("error", 2, 7),
        FaultWindow("brownout", 8, 11, error_rate=0.5),
        FaultWindow("latency_spike", 11, 13, extra_latency_s=0.02),
    ), seed=5)
    # high paraphrase share: failed miss rows usually have a cached
    # neighbour above the degraded floor — the regime degraded serving
    # exists for (a purely-novel workload has nothing to serve from)
    workload = build_workload(pairs, n_req, paraphrase_ratio=0.9,
                              burst_prob=0.0, seed=43)
    policy = BandPolicy(tau_lo=0.70, tau_hi=0.80, degraded_lo=0.60)

    def run(resilient: bool):
        backend = FaultyBackend(SimulatedLLMBackend(pairs), schedule)
        res = None
        if resilient:
            res = ResilienceConfig(
                retry=RetryPolicy(max_attempts=3, base_backoff_s=0.001,
                                  max_backoff_s=0.002, seed=3),
                breaker=CircuitBreaker(failure_threshold=3, window=8,
                                       cooldown_s=0.0),
                sleep=lambda s: None)
        eng = make_engine(pairs, batch_size=batch, backend=backend,
                          policy=policy, resilience=res)
        # compile before the clock starts (consumes fault index 0 in both
        # runs alike), then zero the bookkeeping
        eng.serve_batch([Request(query="resilience warmup")])
        eng.metrics = ServingMetrics()

        async def drive():
            sched = SchedulerConfig(max_batch=batch, max_wait_ms=50.0,
                                    coalesce=False)
            async with AsyncCacheServer(eng, sched) as server:
                return await run_waves(server.submit_request, workload,
                                       wave=batch, return_exceptions=True)
        return eng, backend, res, asyncio.run(drive())

    eng_off, be_off, _, lr_off = run(False)
    eng_on, be_on, res_on, lr_on = run(True)

    rm = eng_on.metrics.resilience
    br = res_on.breaker
    on_slots, off_slots = lr_on.responses, lr_off.responses
    out = {
        "availability_on": round(availability(on_slots), 4),
        "availability_off": round(availability(off_slots), 4),
        "no_stranded": (
            len(on_slots) == n_req and len(off_slots) == n_req
            and all(isinstance(r, (Response, Exception)) for r in on_slots)
            and all(isinstance(r, (Response, Exception))
                    for r in off_slots)),
        "faults_injected_on": be_on.faults_injected,
        "faults_injected_off": be_off.faults_injected,
        "retries": rm.retries,
        "retry_successes": rm.retry_successes,
        "backend_failures": rm.backend_failures,
        "degraded_served": rm.degraded_served,
        "degraded_failed": rm.degraded_failed,
        "breaker_trips": br.trips,
        "breaker_recoveries": br.recoveries,
        "breaker_short_circuits": br.short_circuits,
        "breaker_state_final": br.state,
    }
    pct = eng_on.metrics.summary()["latency_percentiles"]
    out["hit_p99_s"] = pct.get("hit", {}).get("p99_s", 0.0)

    # deadline probe: an already-expired budget on a guaranteed-miss row
    # must fail fast — degraded or error, but never a backend call
    calls_before = be_on.calls_started
    expired = eng_on.process([Request(
        query="what does the deadline probe row with a spent budget do",
        deadline_ms=0.0)])[0]
    out["deadline_fast_fail"] = (
        be_on.calls_started == calls_before
        and bool(expired.error or expired.degraded)
        and rm.deadline_exhausted >= 1)

    body = MetricsExporter(eng_on).render()
    out["families_ok"] = all(f"# TYPE {f} " in body
                             for f in REQUIRED_FAMILIES)
    return out


def _sharded_child(args) -> dict:
    """Body of the sharded stage — runs in the re-exec'd 8-device child."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    smoke = args.smoke
    mesh = jax.make_mesh((4,), ("data",))
    corpus = args.corpus or (40 if smoke else 400)
    batch = args.batch or (16 if smoke else 64)
    n_req = args.requests or (160 if smoke else 1000)
    rate = args.rate_qps or (400.0 if smoke else 800.0)
    capacity = args.capacity or ((1 << 16) if smoke else (1 << 21))
    pairs = build_corpus(corpus, seed=0)
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    registry = TenantRegistry((
        TenantSpec("free", weight=1.0), TenantSpec("pro", weight=2.0),
        TenantSpec("enterprise", weight=4.0), TenantSpec("batch",
                                                         weight=1.0)))
    # reduced dim keeps the full-capacity scoring GEMM tractable on the
    # forced-CPU topology; the slab's *entry count* is the scaling axis
    cfg = CacheConfig(dim=64, capacity=capacity, value_len=48, ttl=None,
                      threshold=0.8)

    def mk(mesh_, *, block=False, latency=0.0):
        eng = CachedEngine(
            cfg, SimulatedLLMBackend(pairs, latency_per_call_s=latency,
                                     block=block),
            judge=judge, batch_size=batch, registry=registry, mesh=mesh_)
        for name in registry.names:
            eng.warm(pairs, tenant=name)
        return eng

    out = {"num_shards": 4, "capacity": capacity,
           "local_capacity": capacity // 4}
    workload = build_multi_tenant_workload(
        pairs, n_req, tenants=list(registry.names), skew=1.2,
        burst_prob=0.2, burst_size=4, seed=13)

    # (a) per-request decision parity vs a single-shard engine on
    # identical traffic with identical batch partitioning
    e_sh = mk(mesh)
    e_ref = mk(None)
    r_sh = e_sh.process(workload)
    r_ref = e_ref.process(workload)
    out["parity_decisions_match"] = all(
        a.cached == b.cached for a, b in zip(r_ref, r_sh))
    out["parity_answers_match"] = all(
        a.answer == b.answer for a, b in zip(r_ref, r_sh))
    out["hit_rate"] = round(sum(r.cached for r in r_sh) / len(r_sh), 4)
    out["entries"] = int(np.asarray(e_sh.runtime.state.valid).sum())
    out["entries_per_shard"] = np.asarray(
        e_sh.runtime.state.valid).reshape(4, -1).sum(axis=1).tolist()

    # (b) cross-shard hits: warmed entries were routed round-robin, so the
    # matched slots of known-warm queries must span >1 shard owner
    L = e_sh.cache.local_capacity
    probe = pairs[:min(len(pairs), 64)]
    emb = jnp.asarray(e_sh.embedder.embed_batch(
        [p.question for p in probe]))
    tid = jnp.zeros((len(probe),), dtype=jnp.int32)
    res, _ = e_sh.cache.lookup(e_sh.runtime, emb, e_sh._now,
                               update_counters=False, tenant_id=tid)
    hit = np.asarray(res.hit)
    owners = sorted(set(
        (np.asarray(res.index)[hit] // L).tolist()))
    out["probe_hits"] = int(hit.sum())
    out["cross_shard_hit_owners"] = owners
    out["cross_shard_hit"] = len(owners) >= 2

    # (c) the async scheduler drives the sharded step directly: open-loop
    # Poisson Zipf traffic against a blocking backend, DRR admission
    e_async = mk(mesh, block=True, latency=0.01 if smoke else 0.05)
    e_async.serve_batch([Request(query="sharded warmup",
                                 tenant=registry.names[0])])
    e_async.metrics = ServingMetrics()

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0,
                                tenant_weights=registry.weights(),
                                max_queue_per_tenant=max(batch, n_req // 4))
        async with AsyncCacheServer(e_async, sched) as server:
            return await run_open_loop(server.submit_request, workload,
                                       rate_qps=rate, seed=17)
    res2 = asyncio.run(drive())
    out["served_all"] = (len(res2.responses) == len(workload)
                         and all(r is not None and r.answer
                                 for r in res2.responses))
    out["achieved_qps"] = round(res2.achieved_qps, 1)
    summary = e_async.metrics.summary()
    for path, pct in summary["latency_percentiles"].items():
        for key in ("p50_s", "p95_s", "p99_s"):
            out[f"{path}_{key}"] = pct[key]
    return out


def bench_sharded(args) -> dict:
    """Stage 8 parent half: re-exec this script with a forced multi-device
    CPU topology (the parent's JAX is already pinned to one device) and
    collect the child's JSON summary."""
    import os
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    cmd = [sys.executable, os.path.abspath(__file__), "--sharded-child"]
    if args.smoke:
        cmd.append("--smoke")
    for flag, val in (("--corpus", args.corpus),
                      ("--requests", args.requests),
                      ("--batch", args.batch),
                      ("--rate-qps", args.rate_qps),
                      ("--capacity", args.capacity)):
        if val is not None:
            cmd += [flag, str(val)]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    if r.returncode != 0:
        return {"child_ok": False,
                "stderr_tail": r.stderr[-2000:] or r.stdout[-2000:]}
    for line in r.stdout.splitlines():
        if line.startswith("SHARDED-JSON "):
            out = json.loads(line[len("SHARDED-JSON "):])
            out["child_ok"] = True
            return out
    return {"child_ok": False, "stderr_tail": "no SHARDED-JSON line"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny sizes + hard assertions")
    ap.add_argument("--corpus", type=int, default=None,
                    help="QA pairs per category")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--rate-qps", type=float, default=None)
    ap.add_argument("--capacity", type=int, default=None,
                    help="sharded-stage slab slots (default 1<<21, "
                         "1<<16 in smoke)")
    ap.add_argument("--sharded-child", action="store_true",
                    help=argparse.SUPPRESS)   # internal re-exec entry
    args = ap.parse_args(argv)

    if args.sharded_child:
        print("SHARDED-JSON " + json.dumps(_sharded_child(args)))
        return 0

    corpus = args.corpus or (60 if args.smoke else 500)
    n_req = args.requests or (192 if args.smoke else 2000)
    batch = args.batch or (16 if args.smoke else 64)
    rate = args.rate_qps or (400.0 if args.smoke else 800.0)

    pairs = build_corpus(corpus, seed=0)

    # 1. parity: paper mixture, no duplicate bursts
    plain = build_workload(pairs, n_req, burst_prob=0.0, seed=1)
    parity = bench_parity(pairs, plain, batch=batch)
    for k, v in parity.items():
        _emit(f"serve/parity_{k}", v)

    # 2. coalescing: concurrent-duplicate workload
    bursty = build_workload(pairs, n_req, burst_prob=0.35, burst_size=8,
                            seed=2)
    coal = bench_coalescing(pairs, bursty, batch=batch, rate_qps=rate)
    for k, v in coal.items():
        _emit(f"serve/{k}", v)

    # 3. tail latency under Poisson load with a real-sleeping backend
    tail_req = bursty[:min(len(bursty), 96 if args.smoke else 1000)]
    tail = bench_tail_latency(pairs, tail_req, batch=batch, rate_qps=rate,
                              llm_latency_s=0.01 if args.smoke else 0.05)
    _emit("serve/achieved_qps", tail["achieved_qps"])
    for path, pct in tail["percentiles"].items():
        for key in ("p50_s", "p95_s", "p99_s"):
            _emit(f"serve/{path}_{key}", pct[key])
    print(json.dumps(tail, indent=1))

    # 4. multi-tenant: 3 tenants, skewed traffic, partitioned cache + DRR
    ten = bench_tenancy(pairs, batch=batch,
                        n_req=min(n_req, 192 if args.smoke else 1000),
                        rate_qps=rate)
    for k, v in ten.items():
        _emit(f"serve/tenancy_{k}", v)

    # 5. multi-turn sessions: record/replay, fusion on vs off
    ctx = bench_multi_turn(pairs, batch=batch,
                           n_groups=8 if args.smoke else 10, turns=3)
    for k, v in ctx.items():
        _emit(f"serve/context_{k}", v)

    # 6. generative near-hit band: judged synthesis vs exact-reuse baseline
    near_wl = build_workload(pairs, min(n_req, 256 if args.smoke else 1000),
                             paraphrase_ratio=0.8, burst_prob=0.0, seed=29)
    nh = bench_near_hit(pairs, near_wl, batch=batch)
    for k, v in nh.items():
        _emit(f"serve/near_{k}", v)

    # 7. observability: stage decomposition, span-sum invariant, tracing
    #    overhead, /metrics scrape (DESIGN.md §18.6)
    obs = bench_observability(pairs, batch=batch,
                              n_req=min(n_req, 96 if args.smoke else 500),
                              rate_qps=rate,
                              llm_latency_s=0.01 if args.smoke else 0.05)
    for k, v in obs.items():
        _emit(f"serve/obs_{k}", v)

    # 8. sharded: large slab on a forced-8-device mesh through the async
    #    scheduler (DESIGN.md §19.6) — subprocess re-exec
    shard = bench_sharded(args)
    for k, v in shard.items():
        _emit(f"shard/{k}", v)

    # 9. resilience: deterministic chaos — fault windows, deadline-budgeted
    #    retries, circuit breaker, degraded cache serving (DESIGN.md §20.7)
    fault = bench_resilience(pairs, batch=batch,
                             n_req=min(12 * batch, n_req))
    for k, v in fault.items():
        _emit(f"serve/fault_{k}", v)

    ok = True
    if not parity["decisions_match"] or not parity["answers_match"]:
        print("FAIL: async scheduler diverged from sync engine", file=sys.stderr)
        ok = False
    if parity["sync_hit_rate"] != parity["async_hit_rate"]:
        print("FAIL: hit-rate parity broken", file=sys.stderr)
        ok = False
    if coal["coalesce_on_backend_calls"] >= coal["coalesce_off_backend_calls"]:
        print("FAIL: coalescing did not reduce backend calls", file=sys.stderr)
        ok = False
    if not ten["isolation_ok"]:
        print("FAIL: cross-tenant cache leak", file=sys.stderr)
        ok = False
    if not (ten["served_all"] and ten["accounting_ok"]):
        print("FAIL: tenancy serving/accounting broken", file=sys.stderr)
        ok = False
    # multi-turn expectations are hard requirements (§16.6): fused replays
    # must convert, stateless replays must not hit at all, and context-hit
    # precision must clear the paper-grade bar
    if ctx["fusion_on_followup_repeat_hit_rate"] < 0.5:
        print("FAIL: fused follow-up replays did not convert to hits",
              file=sys.stderr)
        ok = False
    if ctx["fusion_off_followup_repeat_hit_rate"] != 0.0:
        print("FAIL: stateless cache hit an elliptical follow-up",
              file=sys.stderr)
        ok = False
    if ctx["context_positive_rate"] <= 0.97:
        print("FAIL: context-hit precision below the 97% bar",
              file=sys.stderr)
        ok = False
    if not ctx["sessions_bounded"]:
        print("FAIL: session store exceeded its LRU cap", file=sys.stderr)
        ok = False
    # near-hit band expectations are hard requirements (§17): the band must
    # convert, its savings must be strictly beyond exact reuse, the judge
    # must confirm the synthesized answers, and exact-path serving must be
    # byte-identical to a cache without bands
    if nh["near_hits_served"] <= 0:
        print("FAIL: near-hit band served nothing", file=sys.stderr)
        ok = False
    if nh["near_precision"] <= 0.9:
        print("FAIL: near-hit judge precision below the 0.9 bar",
              file=sys.stderr)
        ok = False
    if nh["band_backend_calls"] >= nh["baseline_backend_calls"]:
        print("FAIL: band did not cut backend calls beyond exact reuse",
              file=sys.stderr)
        ok = False
    if not nh["exact_rows_identical"]:
        print("FAIL: band engine diverged on exact-hit rows", file=sys.stderr)
        ok = False
    # observability expectations are hard requirements (§18.6): the stage
    # decomposition must reconstruct measured e2e latency within 10% at
    # p50/p95, tracing must cost <5% when on and NOTHING when off, and the
    # /metrics exposition must serve every required family with tenant
    # labels (skipped only when the sandbox forbids sockets)
    if not 0.9 <= obs["span_sum_p50_ratio"] <= 1.1:
        print("FAIL: span-sum p50 off by >10% from measured e2e",
              file=sys.stderr)
        ok = False
    if not 0.9 <= obs["span_sum_p95_ratio"] <= 1.1:
        print("FAIL: span-sum p95 off by >10% from measured e2e",
              file=sys.stderr)
        ok = False
    if obs["trace_overhead_pct"] >= 5.0:
        print("FAIL: tracing overhead above the 5% bound", file=sys.stderr)
        ok = False
    if obs["scrape_ok"] is False:
        print("FAIL: /metrics scrape missing families or tenant labels",
              file=sys.stderr)
        ok = False
    if obs["off_path_traces_started"] != 0:
        print("FAIL: tracing-off engine still started traces",
              file=sys.stderr)
        ok = False
    if not (obs["events_logged"] > 0 and obs["events_bounded"]):
        print("FAIL: event log empty or over capacity", file=sys.stderr)
        ok = False
    # sharded expectations are hard requirements (§19.6): the mesh engine
    # must make the SAME per-request decisions as a single-shard engine on
    # identical traffic, serve hits whose entries live on >1 shard, keep
    # the async scheduler fully served, and hold the hit-path p99 bound
    if not shard.get("child_ok"):
        print(f"FAIL: sharded child failed: {shard.get('stderr_tail')}",
              file=sys.stderr)
        ok = False
    else:
        if not (shard["parity_decisions_match"]
                and shard["parity_answers_match"]):
            print("FAIL: sharded engine diverged from single-shard engine",
                  file=sys.stderr)
            ok = False
        if not shard["cross_shard_hit"]:
            print("FAIL: no cross-shard cache hit (owners: "
                  f"{shard['cross_shard_hit_owners']})", file=sys.stderr)
            ok = False
        if not shard["served_all"]:
            print("FAIL: sharded async scheduler dropped requests",
                  file=sys.stderr)
            ok = False
        p99_bound = 0.5 if args.smoke else 1.0
        if shard.get("hit_p99_s", 0.0) >= p99_bound:
            print(f"FAIL: sharded hit-path p99 {shard.get('hit_p99_s')}s "
                  f"over the {p99_bound}s bound", file=sys.stderr)
            ok = False
    # resilience expectations are hard requirements (§20.7): every submitted
    # slot resolves (zero stranded futures even with the backend on fire),
    # degraded serving keeps availability STRICTLY above the no-resilience
    # baseline under the same fault schedule, the breaker both trips and
    # recovers (ending closed), expired deadlines never reach the backend,
    # the unaffected hit traffic keeps its tail, and the retry/breaker/
    # degraded metric families are served
    if not fault["no_stranded"]:
        print("FAIL: chaos run stranded or dropped futures", file=sys.stderr)
        ok = False
    if fault["availability_on"] <= fault["availability_off"]:
        print("FAIL: resilience layer did not improve availability "
              f"({fault['availability_on']} vs {fault['availability_off']})",
              file=sys.stderr)
        ok = False
    if not (fault["breaker_trips"] >= 1 and fault["breaker_recoveries"] >= 1
            and fault["breaker_state_final"] == "closed"):
        print("FAIL: breaker did not trip and recover "
              f"(trips={fault['breaker_trips']}, "
              f"recoveries={fault['breaker_recoveries']}, "
              f"state={fault['breaker_state_final']})", file=sys.stderr)
        ok = False
    if fault["degraded_served"] <= 0:
        print("FAIL: degraded mode served nothing during the outage",
              file=sys.stderr)
        ok = False
    fault_p99 = 0.5 if args.smoke else 1.0
    if fault["hit_p99_s"] >= fault_p99:
        print(f"FAIL: chaos hit-path p99 {fault['hit_p99_s']}s over the "
              f"{fault_p99}s bound", file=sys.stderr)
        ok = False
    if not fault["deadline_fast_fail"]:
        print("FAIL: expired deadline row reached the backend",
              file=sys.stderr)
        ok = False
    if not fault["families_ok"]:
        print("FAIL: resilience metric families missing from /metrics",
              file=sys.stderr)
        ok = False
    _emit("serve/ok", ok)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
