"""Tail-latency + coalescing benchmark for the async serving subsystem.

Three experiments on the simulated backend (DESIGN.md §12.5):

  1. **parity** — the async scheduler must reproduce the sync engine's
     results on an identical workload: same per-request hit/miss
     decisions, byte-identical answers, same hit rate. Driven in lockstep
     waves of ``max_batch`` so both paths see the same batch partitioning.
  2. **coalescing** — a duplicate-burst workload under open-loop Poisson
     arrivals, coalescing on vs off: reports backend calls, the reduction
     ratio, and coalesced-call counts.
  3. **tail latency** — open-loop Poisson at a configurable rate against a
     *blocking* backend (real sleeps): sustained QPS and p50/p95/p99 per
     path (hit / miss / coalesced).

Output: ``name,value`` CSV rows, then a JSON metrics summary.

``--smoke`` shrinks sizes for CI and turns the parity/coalescing
expectations into hard assertions (non-zero exit on violation), so a
scheduler regression fails the build.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, ServingMetrics,
                           SimulatedLLMBackend, build_workload,
                           run_open_loop, run_waves)


def _emit(name: str, value) -> None:
    print(f"{name},{value}")
    sys.stdout.flush()


def make_engine(pairs, *, batch_size: int, latency_s: float = 0.0,
                block: bool = False, warm: bool = True) -> CachedEngine:
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    backend = SimulatedLLMBackend(pairs, latency_per_call_s=latency_s,
                                  block=block)
    cfg = CacheConfig(dim=384, capacity=max(4096, 8 * len(pairs)),
                      value_len=48, ttl=None, threshold=0.8)
    eng = CachedEngine(cfg, backend, judge=judge, batch_size=batch_size)
    if warm:
        eng.warm(pairs)
    return eng


def bench_parity(pairs, workload, *, batch: int) -> dict:
    """Sync engine vs async scheduler on the same workload/partitioning."""
    sync_eng = make_engine(pairs, batch_size=batch)
    sync_resp = sync_eng.process(workload)

    async_eng = make_engine(pairs, batch_size=batch)

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=50.0,
                                coalesce=False)
        async with AsyncCacheServer(async_eng, sched) as server:
            return await run_waves(server.submit_request, workload,
                                   wave=batch)
    async_resp = asyncio.run(drive()).responses

    decisions_match = all(a.cached == b.cached
                          for a, b in zip(sync_resp, async_resp))
    answers_match = all(a.answer == b.answer
                        for a, b in zip(sync_resp, async_resp))
    sync_hits = sum(r.cached for r in sync_resp)
    async_hits = sum(r.cached for r in async_resp)
    return {
        "sync_hit_rate": sync_hits / len(workload),
        "async_hit_rate": async_hits / len(workload),
        "decisions_match": decisions_match,
        "answers_match": answers_match,
    }


def bench_coalescing(pairs, workload, *, batch: int, rate_qps: float) -> dict:
    """Duplicate-burst workload, coalescing on vs off."""
    out = {}
    for coalesce in (False, True):
        eng = make_engine(pairs, batch_size=batch)

        async def drive():
            sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0,
                                    coalesce=coalesce)
            async with AsyncCacheServer(eng, sched) as server:
                return await run_open_loop(server.submit_request, workload,
                                           rate_qps=rate_qps, seed=7)
        asyncio.run(drive())
        tag = "coalesce_on" if coalesce else "coalesce_off"
        out[f"{tag}_backend_calls"] = eng.backend.calls
        out[f"{tag}_coalesced"] = eng.metrics.coalesced_calls
    off, on = out["coalesce_off_backend_calls"], \
        out["coalesce_on_backend_calls"]
    out["backend_call_reduction_pct"] = round(100.0 * (1 - on / max(off, 1)),
                                              2)
    return out


def bench_tail_latency(pairs, workload, *, batch: int, rate_qps: float,
                       llm_latency_s: float) -> dict:
    """Open-loop Poisson against a blocking backend: real wall-clock tails."""
    eng = make_engine(pairs, batch_size=batch, latency_s=llm_latency_s,
                      block=True)
    # compile the fused serve path before the clock starts — otherwise the
    # first micro-batch's jit trace (~1s) queues behind itself and floods
    # every percentile with cold-start time — then zero the bookkeeping so
    # the warmup row doesn't appear in the reported samples/counters
    eng.serve_batch([Request(query="serve-path warmup")])
    eng.metrics = ServingMetrics()

    async def drive():
        sched = SchedulerConfig(max_batch=batch, max_wait_ms=2.0)
        async with AsyncCacheServer(eng, sched) as server:
            return await run_open_loop(server.submit_request, workload,
                                       rate_qps=rate_qps, seed=11)
    res = asyncio.run(drive())
    summary = eng.metrics.summary()
    return {
        "achieved_qps": round(res.achieved_qps, 1),
        "wall_s": round(res.wall_s, 3),
        "percentiles": summary["latency_percentiles"],
        "coalesced_calls": summary["coalesced_calls"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny sizes + hard assertions")
    ap.add_argument("--corpus", type=int, default=None,
                    help="QA pairs per category")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--rate-qps", type=float, default=None)
    args = ap.parse_args(argv)

    corpus = args.corpus or (60 if args.smoke else 500)
    n_req = args.requests or (192 if args.smoke else 2000)
    batch = args.batch or (16 if args.smoke else 64)
    rate = args.rate_qps or (400.0 if args.smoke else 800.0)

    pairs = build_corpus(corpus, seed=0)

    # 1. parity: paper mixture, no duplicate bursts
    plain = build_workload(pairs, n_req, burst_prob=0.0, seed=1)
    parity = bench_parity(pairs, plain, batch=batch)
    for k, v in parity.items():
        _emit(f"serve/parity_{k}", v)

    # 2. coalescing: concurrent-duplicate workload
    bursty = build_workload(pairs, n_req, burst_prob=0.35, burst_size=8,
                            seed=2)
    coal = bench_coalescing(pairs, bursty, batch=batch, rate_qps=rate)
    for k, v in coal.items():
        _emit(f"serve/{k}", v)

    # 3. tail latency under Poisson load with a real-sleeping backend
    tail_req = bursty[:min(len(bursty), 96 if args.smoke else 1000)]
    tail = bench_tail_latency(pairs, tail_req, batch=batch, rate_qps=rate,
                              llm_latency_s=0.01 if args.smoke else 0.05)
    _emit("serve/achieved_qps", tail["achieved_qps"])
    for path, pct in tail["percentiles"].items():
        for key in ("p50_s", "p95_s", "p99_s"):
            _emit(f"serve/{path}_{key}", pct[key])
    print(json.dumps(tail, indent=1))

    ok = True
    if not parity["decisions_match"] or not parity["answers_match"]:
        print("FAIL: async scheduler diverged from sync engine", file=sys.stderr)
        ok = False
    if parity["sync_hit_rate"] != parity["async_hit_rate"]:
        print("FAIL: hit-rate parity broken", file=sys.stderr)
        ok = False
    if coal["coalesce_on_backend_calls"] >= coal["coalesce_off_backend_calls"]:
        print("FAIL: coalescing did not reduce backend calls", file=sys.stderr)
        ok = False
    _emit("serve/ok", ok)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
