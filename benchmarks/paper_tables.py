"""Benchmarks reproducing the paper's tables/figures (one function each).

fig2  — API-call frequency: traditional vs semantic caching (per category)
fig3  — average query response time: with cache vs without
fig4/table1 — cache hits + positive-hit accuracy per category
threshold_sweep — §5.3: cosine threshold 0.6..0.9 step 0.05
tenant_table — beyond-paper (DESIGN.md §13): per-tenant hit/miss/latency
               breakdown of a partitioned multi-tenant run
context_table — beyond-paper (DESIGN.md §16): multi-turn record/replay
                conversations with context fusion on vs off — follow-up
                hit conversion and context-hit precision

Each returns (rows, summary) where rows are CSV-able dicts; ``run.py``
prints them in the harness format.
"""
from __future__ import annotations

import time

from repro.core.types import CacheConfig
from repro.data.qa_dataset import (CATEGORIES, build_corpus,
                                   build_test_queries)
from repro.serving import (CachedEngine, Request, SimulatedLLMBackend,
                           build_multi_tenant_workload)
from repro.tenancy import TenantRegistry, TenantSpec

_PAPER_TABLE1 = {   # category -> (cache hits / 500, positive hits)
    "python_basics": (335, 310),
    "network_support": (335, 326),
    "order_shipping": (344, 331),
    "customer_shopping": (308, 298),
}


def _run_system(threshold: float = 0.8, n_per_category: int = 2000,
                n_queries_per_cat: int = 500, ttl: float | None = None,
                seed: int = 0):
    pairs = build_corpus(n_per_category, seed=seed)
    queries = build_test_queries(pairs, n_per_category=n_queries_per_cat,
                                 seed=seed + 1)
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    cfg = CacheConfig(dim=384, capacity=4 * n_per_category * 2, value_len=48,
                      ttl=ttl, threshold=threshold)
    eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                       batch_size=128)
    eng.warm(pairs)
    t0 = time.perf_counter()
    eng.process([Request(query=q.query, category=q.category,
                         source_id=q.source_id, semantic_key=q.semantic_key)
                 for q in queries])
    wall = time.perf_counter() - t0
    return eng.metrics.summary(), wall, len(queries)


def table1(full: bool = True):
    """Table 1 + Fig 4: hits and positive hits per category vs paper."""
    n = 2000 if full else 400
    nq = 500 if full else 100
    s, wall, nqueries = _run_system(n_per_category=n, n_queries_per_cat=nq)
    rows = []
    for cat in CATEGORIES:
        m = s["categories"][cat]
        paper_hits, paper_pos = _PAPER_TABLE1[cat]
        rows.append({
            "name": f"table1/{cat}",
            "us_per_call": 1e6 * wall / nqueries,
            "derived": (f"hits={m['cache_hits']}/{m['lookups']}"
                        f" hit_rate={m['hit_rate']:.3f}"
                        f" positive_rate={m['positive_rate']:.3f}"
                        f" paper_hits={paper_hits}/500"
                        f" paper_pos={paper_pos}"),
        })
    return rows, s


def fig2(summary=None):
    """API-call frequency: traditional = 100%; ours = miss fraction."""
    if summary is None:
        summary, _, _ = _run_system()
    rows = []
    for cat in CATEGORIES:
        m = summary["categories"][cat]
        rows.append({
            "name": f"fig2/api_calls/{cat}",
            "us_per_call": 0.0,
            "derived": (f"traditional=1.00 cached={m['api_call_fraction']:.3f}"
                        f" reduction={1 - m['api_call_fraction']:.3f}"),
        })
    return rows, summary


def fig3(summary=None):
    """Response time with vs without cache (LLM latency modeled, cache
    path measured on this host)."""
    if summary is None:
        summary, _, _ = _run_system()
    rows = [{
        "name": "fig3/latency",
        "us_per_call": summary["avg_latency_with_cache_s"] * 1e6,
        "derived": (f"with_cache_s={summary['avg_latency_with_cache_s']:.4f}"
                    f" without_cache_s={summary['avg_latency_without_cache_s']:.4f}"
                    f" speedup={summary['avg_latency_without_cache_s'] / max(summary['avg_latency_with_cache_s'], 1e-9):.2f}x"),
    }]
    return rows, summary


def threshold_sweep(full: bool = False):
    """§5.3: sweep 0.60..0.90 in 0.05 steps; 0.8 should be the knee."""
    n = 1000 if full else 500
    nq = 250 if full else 125
    rows = []
    best = None
    for thr in [0.60, 0.65, 0.70, 0.75, 0.80, 0.85, 0.90]:
        s, wall, nqueries = _run_system(threshold=thr, n_per_category=n,
                                        n_queries_per_cat=nq)
        hit = sum(s["categories"][c]["cache_hits"] for c in CATEGORIES) / \
            sum(s["categories"][c]["lookups"] for c in CATEGORIES)
        jh = sum(round(s["categories"][c]["positive_rate"]
                       * s["categories"][c]["cache_hits"]) for c in CATEGORIES)
        th = sum(s["categories"][c]["cache_hits"] for c in CATEGORIES)
        pos = jh / max(th, 1)
        # the paper's selection logic (§5.3): thresholds below the knee
        # "introduce irrelevant matches, decreasing the positive hit rate";
        # pick the highest hit rate whose precision clears the paper's
        # observed floor (92.5%)
        score = hit if pos >= 0.92 else -1.0
        if best is None or score > best[1]:
            best = (thr, score)
        rows.append({
            "name": f"sec5.3/threshold_{thr:.2f}",
            "us_per_call": 1e6 * wall / nqueries,
            "derived": f"hit_rate={hit:.3f} positive_rate={pos:.3f} "
                       f"tradeoff={score:.3f}",
        })
    rows.append({"name": "sec5.3/optimal", "us_per_call": 0.0,
                 "derived": f"best_threshold={best[0]:.2f} (paper: 0.80)"})
    return rows, {"best": best}


def tenant_table(full: bool = False):
    """Per-tenant breakdown (beyond-paper, DESIGN.md §13): one partitioned
    cache, Zipf-skewed 3-tenant traffic, per-tenant hit rate + precision +
    mean latency — the multi-tenant analogue of Table 1."""
    n = 800 if full else 250
    nq = 600 if full else 240
    pairs = build_corpus(n, seed=0)
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    registry = TenantRegistry((
        TenantSpec("free", share=1.0, weight=1.0),
        TenantSpec("pro", share=2.0, weight=2.0),
        TenantSpec("enterprise", share=2.0, weight=4.0, threshold=0.85),
    ))
    cfg = CacheConfig(dim=384, capacity=8 * n * len(registry), value_len=48,
                      ttl=None, threshold=0.8)
    eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                       batch_size=64, registry=registry)
    for name in registry.names:
        eng.warm(pairs, tenant=name)
    workload = build_multi_tenant_workload(
        pairs, nq, tenants=list(registry.names), skew=1.2, seed=2)
    t0 = time.perf_counter()
    eng.process(workload)
    wall = time.perf_counter() - t0

    s = eng.metrics.summary()
    dev = eng.tenant_stats()
    rows = []
    for name in registry.names:
        h = s["tenants"][name]
        d = dev[name]
        rows.append({
            "name": f"tenancy/{name}",
            "us_per_call": 1e6 * wall / max(nq, 1),
            "derived": (f"lookups={d['lookups']}"
                        f" hit_rate={h['hit_rate']:.3f}"
                        f" inserts={d['inserts']}"
                        f" evictions={d['evictions']}"
                        f" region_slots={d['region_slots']}"),
        })
    return rows, s


def context_table(full: bool = False):
    """Multi-turn context caching (beyond-paper, DESIGN.md §16.6).

    One dialogue state served twice (record, then replay with rephrased
    follow-ups), through the same engine with context fusion on vs off.
    The rows the session subsystem stands on: follow-up *replays* convert
    from 0% hits (stateless — their raw texts are globally unique) to
    near-100% hits (fused — their dialogue states repeat), while
    context-hit precision holds the paper-grade >97% bar.
    """
    from repro.context import DecayMeanFusion
    from repro.serving import build_multi_turn_workload, turn_levels

    n = 400 if full else 150
    n_groups, turns = 10, 3
    pairs = build_corpus(n, seed=0)
    convs = build_multi_turn_workload(pairs, n_groups, turns=turns, seed=23)
    rec, rep = convs[:n_groups], convs[n_groups:]
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}
    for conv in convs:
        for r in conv:
            key_by_sid.setdefault(r.source_id, r.semantic_key)

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key

    rows = []
    summaries = {}
    for tag, fusion in (("fusion_on", DecayMeanFusion(window=4)),
                        ("fusion_off", None)):
        cfg = CacheConfig(dim=384, capacity=8 * n, value_len=48,
                          ttl=None, threshold=0.8)
        eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                           batch_size=32, fusion=fusion)
        eng.warm(pairs)
        t0 = time.perf_counter()
        for half in (rec, rep):           # record first, then replay
            for level in turn_levels(half):
                eng.process(level)
        wall = time.perf_counter() - t0
        s = eng.metrics.summary()
        summaries[tag] = s
        nq = sum(len(c) for c in convs)
        for cat in ("ctx/open_repeat", "ctx/followup", "ctx/followup_repeat"):
            m = s["categories"][cat]
            rows.append({
                "name": f"context/{tag}/{cat.split('/', 1)[1]}",
                "us_per_call": 1e6 * wall / nq,
                "derived": (f"hits={m['cache_hits']}/{m['lookups']}"
                            f" hit_rate={m['hit_rate']:.3f}"
                            f" positive_rate={m['positive_rate']:.3f}"),
            })
        if s["context"]:
            c = s["context"]["context"]
            rows.append({
                "name": f"context/{tag}/context_rows",
                "us_per_call": 0.0,
                "derived": (f"lookups={c['lookups']}"
                            f" hit_rate={c['hit_rate']:.3f}"
                            f" positive_rate={c['positive_rate']:.3f}"),
            })
    on = summaries["fusion_on"]["categories"]["ctx/followup_repeat"]
    off = summaries["fusion_off"]["categories"]["ctx/followup_repeat"]
    rows.append({
        "name": "context/followup_conversion",
        "us_per_call": 0.0,
        "derived": (f"fused_hit_rate={on['hit_rate']:.3f}"
                    f" stateless_hit_rate={off['hit_rate']:.3f}"
                    f" fused_positive_rate={on['positive_rate']:.3f}"),
    })
    return rows, summaries


def near_hit_table(full: bool = False):
    """Generative near-hit band (beyond-paper, DESIGN.md §17.6).

    One paraphrase-heavy workload served twice: by an exact-reuse engine
    and by the same engine with a [τ_lo, τ_hi) band + TemplateSplice
    synthesizer. The rows the generative subsystem stands on: judged band
    rows convert into served near-hits that cut backend calls strictly
    beyond exact reuse, at high judge-verified precision, while every row
    the exact path hit is served byte-identically.
    """
    from repro.generative import BandPolicy, TemplateSplice

    n = 300 if full else 100
    pairs = build_corpus(n, seed=0)
    queries = build_test_queries(pairs, n_per_category=100 if full else 60,
                                 paraphrase_ratio=0.8, seed=1)
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key

    reqs = [Request(query=q.query, category=q.category,
                    source_id=q.source_id, semantic_key=q.semantic_key)
            for q in queries]
    rows, summaries = [], {}
    resps, calls = {}, {}
    for tag, syn, pol in (
            ("band_off", None, None),
            ("band_on", TemplateSplice(rival_margin=0.12),
             BandPolicy(tau_lo=0.75, tau_hi=0.8))):
        cfg = CacheConfig(dim=384, capacity=8 * n, value_len=48,
                          ttl=None, threshold=0.8)
        eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                           batch_size=32, synthesizer=syn, policy=pol)
        eng.warm(pairs)
        t0 = time.perf_counter()
        resps[tag] = eng.process(reqs)
        wall = time.perf_counter() - t0
        s = eng.metrics.summary()
        summaries[tag] = s
        calls[tag] = eng.backend.calls
        hit_rate = sum(r.cached for r in resps[tag]) / len(reqs)
        rows.append({
            "name": f"near/{tag}/serving",
            "us_per_call": 1e6 * wall / len(reqs),
            "derived": (f"backend_calls={eng.backend.calls}"
                        f" hit_rate={hit_rate:.3f}"
                        f" cost_usd={s['total_cost_usd']:.4f}"),
        })
        if s["near"]:
            m = s["near"]
            rows.append({
                "name": f"near/{tag}/band",
                "us_per_call": 0.0,
                "derived": (f"band={m['band_lookups']}"
                            f" served={m['near_hits_served']}"
                            f" conversion={m['conversion_rate']:.3f}"
                            f" precision={m['near_precision']:.3f}"),
            })
    exact_identical = all(
        b.answer == a.answer and b.score == a.score
        for a, b in zip(resps["band_off"], resps["band_on"]) if a.cached)
    saved = calls["band_off"] - calls["band_on"]
    rows.append({
        "name": "near/calls_saved_beyond_exact",
        "us_per_call": 0.0,
        "derived": (f"saved={saved}"
                    f" exact_rows_identical={exact_identical}"),
    })
    return rows, summaries


def resilience_table(full: bool = False):
    """Resilient serving under deterministic chaos (DESIGN.md §20.7).

    One paraphrase-heavy workload served twice through the SAME seeded
    ``FaultSchedule`` — a hard-error window, a 50% brownout, a latency
    spike, all keyed by backend call index so the sync batch partitioning
    replays the faults bit-identically:

      * ``resilience_off`` — plain engine: per-row containment only; every
        miss row whose backend call faulted resolves with ``error`` set.
      * ``resilience_on``  — deadline-budgeted retries (deterministic
        backoff, no real sleeps), a zero-cooldown circuit breaker, and
        degraded cache serving above ``BandPolicy.degraded_lo``.

    The ``fault/*`` rows CI asserts on: availability on strictly above
    off, and the breaker both tripping and recovering.
    """
    from repro.generative import BandPolicy
    from repro.serving import (CircuitBreaker, FaultSchedule, FaultWindow,
                               FaultyBackend, ResilienceConfig, RetryPolicy,
                               build_workload)

    n = 300 if full else 100
    batch = 32 if full else 16
    pairs = build_corpus(n, seed=0)
    reqs = build_workload(pairs, 12 * batch, paraphrase_ratio=0.9,
                          burst_prob=0.0, seed=43)
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key

    schedule = FaultSchedule(windows=(
        FaultWindow("error", 2, 7),
        FaultWindow("brownout", 8, 11, error_rate=0.5),
        FaultWindow("latency_spike", 11, 13, extra_latency_s=0.02),
    ), seed=5)
    policy = BandPolicy(tau_lo=0.70, tau_hi=0.80, degraded_lo=0.60)

    rows, out = [], {}
    avail, engines, configs = {}, {}, {}
    for tag, resilient in (("resilience_off", False),
                           ("resilience_on", True)):
        backend = FaultyBackend(SimulatedLLMBackend(pairs), schedule)
        res = None
        if resilient:
            res = ResilienceConfig(
                retry=RetryPolicy(max_attempts=3, base_backoff_s=0.001,
                                  max_backoff_s=0.002, seed=3),
                breaker=CircuitBreaker(failure_threshold=3, window=8,
                                       cooldown_s=0.0),
                sleep=lambda s: None)
        cfg = CacheConfig(dim=384, capacity=8 * n, value_len=48,
                          ttl=None, threshold=0.8)
        eng = CachedEngine(cfg, backend, judge=judge, batch_size=batch,
                           policy=policy, resilience=res)
        eng.warm(pairs)
        eng.serve_batch([Request(query="resilience warmup")])  # fault idx 0
        t0 = time.perf_counter()
        resps = eng.process(reqs)
        wall = time.perf_counter() - t0
        avail[tag] = sum(1 for r in resps if not r.error) / len(resps)
        engines[tag], configs[tag] = eng, res
        rows.append({
            "name": f"fault/{tag}/serving",
            "us_per_call": 1e6 * wall / len(reqs),
            "derived": (f"availability={avail[tag]:.4f}"
                        f" faults_injected={backend.faults_injected}"
                        f" degraded={sum(r.degraded for r in resps)}"
                        f" errors={sum(bool(r.error) for r in resps)}"),
        })
    rm = engines["resilience_on"].metrics.resilience
    br = configs["resilience_on"].breaker
    rows.append({
        "name": "fault/availability",
        "us_per_call": 0.0,
        "derived": (f"on={avail['resilience_on']:.4f}"
                    f" off={avail['resilience_off']:.4f}"
                    f" delta={avail['resilience_on'] - avail['resilience_off']:.4f}"),
    })
    rows.append({
        "name": "fault/retries",
        "us_per_call": 0.0,
        "derived": (f"retries={rm.retries}"
                    f" retry_successes={rm.retry_successes}"
                    f" backend_failures={rm.backend_failures}"
                    f" deadline_exhausted={rm.deadline_exhausted}"),
    })
    rows.append({
        "name": "fault/breaker",
        "us_per_call": 0.0,
        "derived": (f"trips={br.trips} recoveries={br.recoveries}"
                    f" short_circuits={br.short_circuits} state={br.state}"),
    })
    rows.append({
        "name": "fault/degraded",
        "us_per_call": 0.0,
        "derived": (f"served={rm.degraded_served}"
                    f" failed={rm.degraded_failed}"
                    f" precision={rm.degraded_precision:.3f}"),
    })
    out["availability"] = avail
    out["resilience"] = rm.row()
    return rows, out


def ttl_behaviour():
    """TTL mechanism (paper §2.7): hit rate collapses after expiry."""

    def run(ttl, tick):
        pairs = build_corpus(300, seed=0)
        queries = build_test_queries(pairs, n_per_category=75, seed=1)
        cfg = CacheConfig(dim=384, capacity=4096, value_len=48, ttl=ttl,
                          threshold=0.8)
        eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), batch_size=128)
        eng.warm(pairs)
        eng.tick(tick)      # advance the clock past (or not past) the TTL
        eng.process([Request(query=q.query, category=q.category)
                     for q in queries])
        return sum(eng.metrics.per_category[c].hits for c in CATEGORIES)

    hit_fresh = run(ttl=3600.0, tick=60.0)     # within TTL
    hit_expired = run(ttl=30.0, tick=60.0)     # past TTL: warm cache useless
    rows = [{"name": "sec2.7/ttl", "us_per_call": 0.0,
             "derived": f"hits_within_ttl={hit_fresh} "
                        f"hits_after_expiry={hit_expired}"}]
    return rows, {}


def obs_table(full: bool = False):
    """Observability plane (beyond-paper, DESIGN.md §18.6).

    The ``obs/*`` stage-breakdown rows: per-stage latency quantiles from a
    fully-traced (sample rate 1.0) serving run, the span-sum-vs-e2e
    invariant, and the tracing overhead (traced vs untraced best-of-3
    walls on the identical workload — the <5% bound the serve-bench smoke
    asserts).
    """
    from repro.obs import STAGES, TraceConfig, Tracer

    n = 300 if full else 100
    pairs = build_corpus(n, seed=0)
    queries = build_test_queries(pairs, n_per_category=100 if full else 60,
                                 seed=1)
    reqs = [Request(query=q.query, category=q.category,
                    source_id=q.source_id, semantic_key=q.semantic_key)
            for q in queries]
    cfg = CacheConfig(dim=384, capacity=8 * n, value_len=48,
                      ttl=None, threshold=0.8)

    walls = {}
    engines = {}
    for tag, tracer in (("off", None),
                        ("on", Tracer(TraceConfig(sample_rate=1.0, head=0,
                                                  max_traces=65536)))):
        eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), batch_size=32,
                           tracer=tracer)
        eng.warm(pairs)
        eng.process(reqs[:32])             # compile before the clock
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            eng.process(reqs)
            best = min(best, time.perf_counter() - t0)
        walls[tag] = best
        engines[tag] = eng

    rows = []
    eng = engines["on"]
    decomp = eng.tracer.stage_decomposition()
    for stage in STAGES:
        if stage not in decomp:
            continue                       # queue-side stages: async only
        r = decomp[stage]
        rows.append({
            "name": f"obs/stage/{stage}",
            "us_per_call": 1e6 * r["p50_s"],
            "derived": (f"p95_us={1e6 * r['p95_s']:.1f}"
                        f" p99_us={1e6 * r['p99_s']:.1f}"
                        f" count={r['count']}"),
        })
    traces = eng.tracer.traces()
    ratios = [t.span_sum_s / t.e2e_s for t in traces if t.e2e_s]
    rows.append({
        "name": "obs/span_sum",
        "us_per_call": 0.0,
        "derived": (f"min_ratio={min(ratios):.4f}"
                    f" max_ratio={max(ratios):.4f}"
                    f" traces={len(traces)}"),
    })
    overhead_pct = 100.0 * (walls["on"] / walls["off"] - 1.0)
    rows.append({
        "name": "obs/trace_overhead",
        "us_per_call": 1e6 * (walls["on"] - walls["off"]) / len(reqs),
        "derived": (f"traced_wall_s={walls['on']:.4f}"
                    f" untraced_wall_s={walls['off']:.4f}"
                    f" overhead_pct={overhead_pct:.2f}"),
    })
    return rows, {"decomposition": decomp, "overhead_pct": overhead_pct}


# child program for shard_table: timed local-vs-sharded fused steps on a
# forced-8-device CPU topology (the parent process is single-device)
_SHARD_CHILD = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core import SemanticCache, CacheConfig, DistributedCache

rows = []
mesh = jax.make_mesh((4,), ("data",))
B = 64
for cap in @CAPS@:
    cfg = CacheConfig(dim=64, capacity=cap, value_len=16, ttl=None,
                      threshold=0.8)
    local = SemanticCache(cfg)
    dc = DistributedCache(SemanticCache(cfg), mesh)
    mv = jnp.zeros((B, 16), jnp.int32)
    mvl = jnp.full((B,), 16, jnp.int32)
    lstep = jax.jit(lambda rt, q, t: local.step(rt, q, mv, mvl, t))
    dstep = jax.jit(lambda rt, q, t: dc.step(rt, q, mv, mvl, t))
    walls, parity = {}, True
    for tag, cache, step in (("local", local, lstep),
                             ("sharded", dc, dstep)):
        rt = cache.init()
        hits = []
        for i in range(3):                       # compile + fill
            q = jax.random.normal(jax.random.PRNGKey(i % 2), (B, 64))
            res, rt = step(rt, q, jnp.float32(i))
            hits.append(np.asarray(res.hit).copy())
        jax.block_until_ready(rt.state.keys)
        n = 10
        t0 = time.perf_counter()
        for i in range(n):
            res, rt = step(rt, jax.random.normal(
                jax.random.PRNGKey(i % 2), (B, 64)), jnp.float32(3 + i))
        jax.block_until_ready(res.score)
        walls[tag] = (time.perf_counter() - t0) / n
        if tag == "local":
            ref_hits = hits
        else:
            parity = all(np.array_equal(a, b)
                         for a, b in zip(ref_hits, hits))
    for tag in ("local", "sharded"):
        rows.append({
            "name": f"shard/step_{tag}_cap{cap}",
            "us_per_call": 1e6 * walls[tag],
            "derived": (f"batch={B} dim=64 shards="
                        f"{1 if tag == 'local' else 4} parity={parity}"
                        f" ratio={walls['sharded'] / walls['local']:.2f}"),
        })
print("ROWS-JSON " + json.dumps(rows))
"""


def shard_table(full: bool = False):
    """Sharded-step rows (beyond-paper, DESIGN.md §19.6).

    ``shard/*`` rows: the fused step's us/call, local single-device vs the
    4-shard ``DistributedCache`` on the same capacity, plus the hit-mask
    parity of the two paths on identical traffic. Runs in a subprocess
    with XLA_FLAGS forcing 8 CPU devices — same machinery as
    ``tests/test_distributed.py``.
    """
    import json
    import os
    import subprocess
    import sys

    caps = [1 << 16] + ([1 << 20] if full else [])
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-c", _SHARD_CHILD.replace("@CAPS@", repr(caps))],
        capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"shard child failed:\n{r.stderr[-2000:]}")
    rows = None
    for line in r.stdout.splitlines():
        if line.startswith("ROWS-JSON "):
            rows = json.loads(line[len("ROWS-JSON "):])
    if rows is None:
        raise RuntimeError("shard child produced no ROWS-JSON line")
    return rows, {"caps": caps}
