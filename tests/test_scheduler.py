"""Async serving subsystem tests (DESIGN.md §12): continuous micro-batch
scheduler, in-flight coalescing, backpressure, async-vs-sync equivalence,
partial-batch padding hygiene, and the extended serving metrics."""
import asyncio
import json
import time

import numpy as np
import pytest

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.serving import (AsyncCacheServer, BackendError, Batcher,
                           CachedEngine, FaultSchedule, FaultWindow,
                           FaultyBackend, Request, Response, SchedulerConfig,
                           SimulatedLLMBackend, build_workload,
                           run_closed_loop, run_open_loop, run_waves)
from repro.serving.engine import PAD_REQUEST


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(120, seed=0)


# Mutually dissimilar novel queries (share almost no n-grams), so each one
# is guaranteed to miss independently — numbered variants of one template
# would legitimately hit each other's fresh inserts at threshold 0.8.
DISTINCT_QUERIES = [
    "why is the sky blue at noon",
    "best sourdough starter feeding schedule",
    "how tall is mount kilimanjaro",
    "difference between alligators and crocodiles",
    "what causes aurora borealis displays",
    "recommend a jazz album from 1959",
    "do tides depend on the moon",
    "boiling point of ethanol at altitude",
    "who invented the mechanical clock",
    "explain photosynthesis light reactions",
    "how many strings does a cello have",
    "what year did the berlin wall fall",
]


def make_engine(pairs, *, batch_size=16, judge=True, latency_s=0.0,
                block=False, **kw):
    by_id = {p.qa_id: p for p in pairs}

    def _judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    cfg = kw.pop("config", CacheConfig(dim=384, capacity=4096, value_len=48,
                                       ttl=None, threshold=0.8))
    backend = SimulatedLLMBackend(pairs, latency_per_call_s=latency_s,
                                  block=block)
    return CachedEngine(cfg, backend, judge=_judge if judge else None,
                        batch_size=batch_size, **kw)


class TestCoalescing:
    def test_concurrent_identical_misses_one_backend_call(self, pairs):
        eng = make_engine(pairs)
        q = "what is the airspeed velocity of an unladen swallow"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q) for _ in range(16)))

        responses = asyncio.run(herd())
        # one leader miss, fifteen waiters: ONE backend call total
        assert eng.backend.calls == 1
        assert len({r.answer for r in responses}) == 1
        assert sum(r.coalesced for r in responses) == 15
        assert eng.metrics.coalesced_calls == 15
        # only the leader performed a lookup
        assert int(eng.stats.lookups) == 1

    def test_coalesce_off_pays_per_duplicate(self, pairs):
        eng = make_engine(pairs)
        q = "tell me about the warranty on the quantum flux capacitor"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0,
                                    coalesce=False)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q) for _ in range(8)))

        responses = asyncio.run(herd())
        # all 8 land in one micro-batch; the fused peek runs before any
        # insert, so every duplicate misses and pays a backend call
        assert eng.backend.calls == 8
        assert eng.metrics.coalesced_calls == 0
        assert len({r.answer for r in responses}) == 1

    def test_coalesced_hits_inherit_cached_flag(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs)
        q = pairs[0].question         # byte-identical to a warm entry -> hit

        async def herd():
            sched = SchedulerConfig(max_batch=4, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q) for _ in range(6)))

        responses = asyncio.run(herd())
        assert eng.backend.calls == 0
        assert all(r.cached for r in responses)
        assert sum(r.coalesced for r in responses) == 5


class TestBackpressure:
    def test_full_queue_forces_oldest_deadline_flush(self, pairs):
        eng = make_engine(pairs)
        eng.serve_batch([Request(query="compile warmup")])  # pre-trace jit
        # the deadline (2.5s) is far beyond the test's fast path: only the
        # full-queue backpressure flush can serve the first batches quickly.
        # The last ragged group has no submitter pushing behind it, so it
        # legitimately waits out the deadline — that's the deadline path.
        sched = SchedulerConfig(max_batch=16, max_queue=4,
                                max_wait_ms=2_500.0, coalesce=False)
        reqs = [Request(query=q) for q in DISTINCT_QUERIES]
        calls_before = eng.backend.calls
        done_at: list[float] = []

        async def flood():
            async with AsyncCacheServer(eng, sched) as server:
                t0 = time.perf_counter()

                async def timed(r):
                    resp = await server.submit_request(r)
                    done_at.append(time.perf_counter() - t0)
                    return resp

                return await asyncio.gather(*(timed(r) for r in reqs))

        responses = asyncio.run(flood())
        assert len(responses) == 12
        assert all(r.answer for r in responses)
        assert eng.backend.calls - calls_before == 12
        # >= 8 responses (two forced flushes of 4) landed before the 2.5s
        # admission deadline could have fired even once
        assert sorted(done_at)[7] < 2.0, sorted(done_at)
        # ... and the ragged remainder was flushed by the deadline
        assert max(done_at) < 6.0, sorted(done_at)

    def test_stop_drains_queue(self, pairs):
        eng = make_engine(pairs)
        sched = SchedulerConfig(max_batch=64, max_wait_ms=10_000.0)
        reqs = [Request(query=f"drain question {i}") for i in range(5)]

        async def submit_then_stop():
            server = AsyncCacheServer(eng, sched)
            await server.start()
            tasks = [asyncio.create_task(server.submit_request(r))
                     for r in reqs]
            await asyncio.sleep(0.05)   # all queued, none flushed (64/10s)
            await server.stop()         # drain must serve them
            return await asyncio.gather(*tasks)

        responses = asyncio.run(submit_then_stop())
        assert len(responses) == 5 and all(r.answer for r in responses)

    def test_submit_after_stop_raises(self, pairs):
        eng = make_engine(pairs)

        async def go():
            server = AsyncCacheServer(eng)
            await server.start()
            await server.stop()
            with pytest.raises(RuntimeError):
                await server.submit("too late")

        asyncio.run(go())

    def test_restart_after_stop(self, pairs):
        eng = make_engine(pairs)
        sched = SchedulerConfig(max_batch=4, max_wait_ms=5.0)

        async def go():
            server = AsyncCacheServer(eng, sched)
            await server.start()
            r1 = await server.submit(DISTINCT_QUERIES[0])
            await server.stop()
            await server.start()          # drained scheduler restarts cleanly
            r2 = await server.submit(DISTINCT_QUERIES[0])
            await server.stop()
            return r1, r2

        r1, r2 = asyncio.run(go())
        assert not r1.cached and r2.cached     # second run reuses the slab
        assert r1.answer == r2.answer


class TestAsyncSyncEquivalence:
    def test_same_decisions_and_answers(self, pairs):
        queries = build_test_queries(pairs, n_per_category=24, seed=5)
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries]
        batch = 16

        sync_eng = make_engine(pairs, batch_size=batch)
        sync_eng.warm(pairs)
        sync_resp = sync_eng.process(reqs)

        async_eng = make_engine(pairs, batch_size=batch)
        async_eng.warm(pairs)

        async def drive():
            sched = SchedulerConfig(max_batch=batch, max_wait_ms=50.0,
                                    coalesce=False)
            async with AsyncCacheServer(async_eng, sched) as server:
                # lockstep waves of max_batch reproduce the sync engine's
                # batch partitioning exactly
                return await run_waves(server.submit_request, reqs,
                                       wave=batch)

        async_resp = asyncio.run(drive()).responses
        assert len(async_resp) == len(sync_resp)
        for s, a in zip(sync_resp, async_resp):
            assert s.cached == a.cached
            assert s.answer == a.answer
        # aggregate parity: hit rate, backend spend, device counters
        assert sync_eng.backend.calls == async_eng.backend.calls
        assert int(sync_eng.stats.lookups) == int(async_eng.stats.lookups)
        assert int(sync_eng.stats.hits) == int(async_eng.stats.hits)
        s_sum = sync_eng.metrics.summary()
        a_sum = async_eng.metrics.summary()
        for cat, row in s_sum["categories"].items():
            assert a_sum["categories"][cat]["hit_rate"] == row["hit_rate"]

    def test_closed_loop_serves_everything(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs)
        wl = build_workload(pairs, 60, burst_prob=0.2, burst_size=3, seed=9)

        async def drive():
            async with AsyncCacheServer(eng) as server:
                return await run_closed_loop(server.submit_request, wl,
                                             concurrency=8)

        res = asyncio.run(drive())
        assert len(res.responses) == 60
        assert all(r is not None and r.answer for r in res.responses)
        assert eng.metrics.queries + eng.metrics.coalesced_calls == 60


class TestBatcherPadding:
    """Satellite: padded rows never touch metrics or the slab."""

    def test_pad_shapes(self):
        b = Batcher(batch_size=8)
        padded, n_valid = b.pad([Request(query="x")] * 3)
        assert len(padded) == 8 and n_valid == 3
        assert all(r is PAD_REQUEST for r in padded[3:])
        full, n = b.pad([Request(query="y")] * 8)
        assert len(full) == 8 and n == 8

    def test_partial_batch_counters_clean(self, pairs):
        eng = make_engine(pairs, batch_size=8)
        n = 11                        # not a multiple of 8 -> one padded batch
        reqs = [Request(query=q, category="python_basics")
                for q in DISTINCT_QUERIES[:n]]
        responses = eng.process(reqs)
        assert len(responses) == n
        # ServingMetrics: exactly n queries, no __pad__ category
        s = eng.metrics.summary()
        assert s["queries"] == n
        assert "__pad__" not in s["categories"]
        assert s["categories"]["python_basics"]["lookups"] == n
        # device counters: pads neither looked up nor inserted
        assert int(eng.stats.lookups) == n
        assert int(eng.stats.inserts) == n        # all novel -> all inserted
        assert int(np.sum(np.asarray(eng.state.valid))) == n
        # the cost model charged n backend calls, not the padded 16
        assert s["baseline_cost_usd"] == pytest.approx(
            n * eng.backend.cost_per_call_usd)
        # second pass: every real row is served from cache, pads never poison
        responses2 = eng.process(reqs)
        assert all(r.cached for r in responses2)
        assert int(eng.stats.lookups) == 2 * n

    def test_padded_and_exact_batches_share_one_compiled_step(self, pairs):
        eng = make_engine(pairs, batch_size=8)
        eng.process([Request(query=f"trace probe a{i}") for i in range(8)])
        traces = eng._step_jit._cache_size()
        eng.process([Request(query=f"trace probe b{i}") for i in range(3)])
        assert eng._step_jit._cache_size() == traces


class TestServingMetricsExtensions:
    def test_percentiles_and_coalesced_in_summary(self, pairs):
        eng = make_engine(pairs)
        eng.process([Request(query=f"metrics probe {i}") for i in range(6)])
        eng.metrics.record_coalesced(2)
        eng.metrics.record_latency("coalesced", 0.001)
        s = eng.metrics.summary()
        # new keys ride along ...
        assert s["coalesced_calls"] == 2
        pct = s["latency_percentiles"]
        assert set(pct) >= {"miss", "coalesced"}
        for row in pct.values():
            assert row["p50_s"] <= row["p95_s"] <= row["p99_s"]
        # ... and the paper-table rows are unchanged
        for key in ("categories", "queries", "total_cost_usd",
                    "baseline_cost_usd", "cost_saving_pct",
                    "avg_latency_with_cache_s",
                    "avg_latency_without_cache_s"):
            assert key in s

    def test_percentile_math(self):
        from repro.serving.metrics import percentiles
        xs = [float(i) for i in range(1, 101)]
        p = percentiles(xs)
        assert p["count"] == 100
        assert p["p50_s"] == pytest.approx(
            float(np.percentile(xs, 50)), abs=1e-9)
        assert p["p95_s"] == pytest.approx(
            float(np.percentile(xs, 95)), abs=1e-9)
        assert p["p99_s"] == pytest.approx(
            float(np.percentile(xs, 99)), abs=1e-9)
        assert percentiles([])["count"] == 0


class TestCoalescedPathSplit:
    """Satellite (§18.5): a coalesced waiter's end-to-end latency files
    under its OWN "coalesced" path bucket — folding N near-zero waiter
    latencies into the leader's hit/miss path would skew those paths'
    percentiles exactly when coalescing works best."""

    def test_waiters_never_pollute_leader_path(self, pairs):
        eng = make_engine(pairs)
        q = "one novel question sixteen clients ask at once"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q) for _ in range(16)))

        asyncio.run(herd())
        pct = eng.metrics.summary()["latency_percentiles"]
        # exactly one leader miss; all fifteen waiters in "coalesced"
        assert pct["miss"]["count"] == 1
        assert pct["coalesced"]["count"] == 15
        assert "hit" not in pct
        assert eng.metrics.latency_samples["miss"].count == 1
        assert eng.metrics.latency_samples["coalesced"].count == 15

    def test_split_holds_per_tenant(self, pairs):
        from repro.tenancy import TenantRegistry
        eng = make_engine(pairs,
                          registry=TenantRegistry.uniform(["acme", "globex"]),
                          config=CacheConfig(dim=384, capacity=4096,
                                             value_len=48, ttl=None,
                                             threshold=0.8))
        q = "identical question from both tenants"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q, tenant=t)
                      for t in ("acme", "globex") for _ in range(4)))

        asyncio.run(herd())
        tenants = eng.metrics.summary()["tenants"]
        for name in ("acme", "globex"):       # coalescing never crosses
            row = tenants[name]               # tenants: one leader each
            assert row["latency_percentiles"]["miss"]["count"] == 1
            assert row["latency_percentiles"]["coalesced"]["count"] == 3
            assert row["coalesced_calls"] == 3

    def test_scheduler_traces_split_leader_vs_waiter(self, pairs):
        from repro.obs import TraceConfig, Tracer
        eng = make_engine(pairs, tracer=Tracer(
            TraceConfig(sample_rate=1.0, head=0)))
        q = "a herd question for trace attribution"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q, explain=True) for _ in range(6)))

        responses = asyncio.run(herd())
        assert eng.tracer.retained == 6
        by_stage = {}
        for t in eng.tracer.traces():
            names = tuple(s.name for s in t.spans)
            by_stage.setdefault("coalesce_attach" in names, []).append(t)
        leader_traces, waiter_traces = by_stage[False], by_stage[True]
        assert len(leader_traces) == 1 and len(waiter_traces) == 5
        lt = leader_traces[0]
        # the leader's trace carries the queue-side spans AND the engine's
        # contiguous stage spans; its span sum reconstructs its e2e
        lnames = [s.name for s in lt.spans]
        assert lnames[:2] == ["queue_wait", "batch_form"]
        assert {"embed", "device_step", "respond"} <= set(lnames)
        assert lt.span_sum_s == pytest.approx(lt.e2e_s, rel=0.10)
        # a waiter's whole life is attach -> respond, annotated with its
        # leader, and its why record is demoted leader attribution
        for wt in waiter_traces:
            assert [s.name for s in wt.spans] == \
                ["coalesce_attach", "respond"]
            assert wt.meta["leader"]
            assert wt.span_sum_s == pytest.approx(wt.e2e_s, rel=0.10)
        whys = {r.why["decision"] for r in responses}
        assert whys == {"miss", "coalesced"}
        w = next(r.why for r in responses if r.why["decision"] == "coalesced")
        assert w["leader_decision"] == "miss"
        assert w["coalesced_into"]


class TestTCPServer:
    def test_json_lines_roundtrip(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs)
        known = pairs[0].question

        async def client():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                try:
                    port = await server.serve_tcp("127.0.0.1", 0)
                except OSError as exc:       # sandboxed CI without sockets
                    pytest.skip(f"cannot bind loopback: {exc}")
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", port)
                for i in range(4):
                    writer.write(json.dumps(
                        {"id": i, "query": known}).encode() + b"\n")
                writer.write(b"not json\n")
                await writer.drain()
                lines = [json.loads(await reader.readline())
                         for _ in range(5)]
                writer.close()
                return lines

        lines = asyncio.run(client())
        answers = [l for l in lines if "answer" in l]
        errors = [l for l in lines if "error" in l]
        assert len(answers) == 4 and len(errors) == 1
        assert all(l["cached"] for l in answers)
        assert sum(l["coalesced"] for l in answers) >= 3
        # client-supplied ids are echoed, so pipelined (and possibly
        # reordered) responses stay correlatable
        assert sorted(l["id"] for l in answers) == [0, 1, 2, 3]


# every backend call faults — used to exercise the §20.2 failure domain
ALL_ERRORS = FaultSchedule((FaultWindow("error", 0, 10_000),))


class TestFailureDomainSplit:
    def test_only_failed_rows_reject_in_a_mixed_batch(self, pairs):
        # regression (§20.2): a throwing backend used to fail the WHOLE
        # admission batch; now the hit row of the same flush serves
        # normally and only the true-miss row rejects
        eng = make_engine(pairs)
        eng.warm(pairs)
        eng.backend = FaultyBackend(eng.backend, ALL_ERRORS)
        hit_q = pairs[0].question
        miss_q = DISTINCT_QUERIES[0]

        async def drive():
            sched = SchedulerConfig(max_batch=4, max_wait_ms=20.0,
                                    coalesce=False)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(server.submit(hit_q),
                                            server.submit(miss_q),
                                            return_exceptions=True)

        r_hit, r_miss = asyncio.run(drive())
        assert isinstance(r_hit, Response)
        assert r_hit.cached and r_hit.error == "" and r_hit.answer
        assert isinstance(r_miss, BackendError)
        assert "injected error" in str(r_miss)
        assert eng.metrics.resilience.backend_failures == 1

    def test_waiters_inherit_leader_failure_and_state_unwinds(self, pairs):
        # a failed leader must reject its coalesced waiters too — and leave
        # no pending entry, leader embedding, or LSH bucket behind
        eng = make_engine(pairs)
        eng.backend = FaultyBackend(eng.backend, ALL_ERRORS)
        q = DISTINCT_QUERIES[1]

        async def drive():
            sched = SchedulerConfig(max_batch=4, max_wait_ms=10.0,
                                    coalesce_sim=0.9)
            server = AsyncCacheServer(eng, sched)
            async with server:
                results = await asyncio.gather(
                    *(server.submit(q) for _ in range(5)),
                    return_exceptions=True)
            return results, server.scheduler

        results, sched = asyncio.run(drive())
        assert len(results) == 5
        assert all(isinstance(r, BackendError) for r in results)
        assert eng.backend.calls_started == 1    # ONE failed call for all 5
        assert sched._pending == {}
        assert sched._leader_emb == {}
        assert sched._sim_buckets == {}


class TestShutdownUnderFire:
    def test_stop_mid_execute_resolves_every_future(self, pairs):
        eng = make_engine(pairs, latency_s=0.15, block=True, batch_size=4)
        eng.serve_batch([Request(query="compile warmup")])

        async def drive():
            sched = SchedulerConfig(max_batch=4, max_wait_ms=1.0)
            server = AsyncCacheServer(eng, sched)
            await server.start()
            tasks = [asyncio.create_task(server.submit(q))
                     for q in DISTINCT_QUERIES[:8]]
            await asyncio.sleep(0.05)     # first batch is mid-execute now
            await server.stop()           # drain: serve the backlog, exit
            results = await asyncio.gather(*tasks, return_exceptions=True)
            return results, server.scheduler

        results, sched = asyncio.run(drive())
        assert len(results) == 8
        # drain semantics: every accepted request is SERVED, none stranded
        assert all(isinstance(r, Response) for r in results)
        assert sched._pending == {}

    def test_stop_with_inflight_waiters_strands_nothing(self, pairs):
        eng = make_engine(pairs, latency_s=0.15, block=True, batch_size=4)
        eng.serve_batch([Request(query="compile warmup")])
        q = DISTINCT_QUERIES[2]

        async def drive():
            sched = SchedulerConfig(max_batch=4, max_wait_ms=1.0)
            server = AsyncCacheServer(eng, sched)
            await server.start()
            tasks = [asyncio.create_task(server.submit(q))
                     for _ in range(6)]
            await asyncio.sleep(0.05)     # leader mid-execute, 5 attached
            await server.stop()
            with pytest.raises(RuntimeError, match="not running"):
                await server.submit("too late")
            results = await asyncio.gather(*tasks, return_exceptions=True)
            return results, server.scheduler

        results, sched = asyncio.run(drive())
        assert all(isinstance(r, Response) for r in results)
        assert sum(r.coalesced for r in results) == 5
        assert sched._pending == {}
