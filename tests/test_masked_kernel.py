"""Per-row-masked cosine_topk kernel variants (DESIGN.md §14) vs the jnp
oracles, in interpret mode on CPU: interval operands (the tenancy fast
path), the dense blocked (B, N) mask path, int8 slabs (uniform and per-row
scales), the (-inf, -1) all-masked contract across every lookup path, and
the ops-level dispatch under REPRO_PALLAS_INTERPRET=1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.cosine_topk import (cosine_topk_interval_pallas,
                                       cosine_topk_masked_pallas,
                                       cosine_topk_pallas,
                                       quant_cosine_topk_interval_pallas,
                                       quant_cosine_topk_masked_pallas,
                                       quantize_keys)


def _unit(rng, shape):
    x = jax.random.normal(rng, shape)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def _slab_int8(keys):
    """The cache slab's uniform symmetric quantization (store.insert)."""
    return jnp.clip(jnp.round(keys * 127.0), -127, 127).astype(jnp.int8)


def _random_intervals(rng, b, n, *, empty_every=4):
    """Random per-row (start, size) pairs; every ``empty_every``-th row gets
    an empty interval (size 0) — the empty-region / padded-row edge."""
    k1, k2 = jax.random.split(rng)
    starts = jax.random.randint(k1, (b,), 0, n, dtype=jnp.int32)
    sizes = jax.random.randint(k2, (b,), 1, n + 1, dtype=jnp.int32)
    sizes = jnp.minimum(sizes, n - starts)
    if empty_every:
        rows = jnp.arange(b)
        sizes = jnp.where(rows % empty_every == empty_every - 1, 0, sizes)
    return starts, sizes


def _check(expected, got, rtol=1e-5, atol=1e-5):
    (rs, ri), (ps, pi) = expected, got
    np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                               rtol=rtol, atol=atol)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))


class TestIntervalKernel:
    @pytest.mark.parametrize("b,n,d,k", [
        (1, 64, 16, 1),
        (4, 100, 32, 4),      # non-multiple N
        (3, 517, 64, 2),      # awkward everything
        (16, 256, 384, 4),    # MiniLM dim
        (33, 128, 128, 8),    # B > block_b: intervals cross batch blocks
    ])
    def test_matches_oracle_mixed_intervals(self, b, n, d, k):
        r = jax.random.PRNGKey(b * 7919 + n)
        kq, kk, kv, ki = jax.random.split(r, 4)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        valid = jax.random.bernoulli(kv, 0.8, (n,))
        starts, sizes = _random_intervals(ki, b, n)
        exp = ref.cosine_topk_interval_ref(q, keys, valid, starts, sizes, k)
        got = cosine_topk_interval_pallas(q, keys, valid, starts, sizes,
                                          k=k, block_b=8, block_n=64,
                                          interpret=True)
        _check(exp, got)

    def test_tenant_layout_intervals(self):
        """Contiguous disjoint regions, exactly the PartitionMap layout:
        rows of tenant t see only [start_t, start_t + size_t)."""
        b, n, d, k = 12, 192, 32, 4
        r = jax.random.PRNGKey(0)
        kq, kk = jax.random.split(r)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        valid = jnp.ones((n,), bool)
        region = jnp.array([(0, 64), (64, 96), (160, 32)], dtype=jnp.int32)
        tid = jnp.arange(b, dtype=jnp.int32) % 3
        starts, sizes = region[tid, 0], region[tid, 1]
        exp = ref.cosine_topk_interval_ref(q, keys, valid, starts, sizes, k)
        got = cosine_topk_interval_pallas(q, keys, valid, starts, sizes,
                                          k=k, block_b=8, block_n=64,
                                          interpret=True)
        _check(exp, got)
        # structural isolation: every returned slot is inside the row's region
        _, pi = got
        pi = np.asarray(pi)
        st_, sz = np.asarray(starts), np.asarray(sizes)
        for row in range(b):
            hits = pi[row][pi[row] >= 0]
            assert ((hits >= st_[row]) & (hits < st_[row] + sz[row])).all()

    def test_empty_interval_rows_return_neg_inf_minus_one(self):
        """Satellite: a row whose region has zero visible slots returns
        exactly (-inf, -1) — kernel == oracle, bit for bit."""
        b, n, d = 6, 96, 16
        q = _unit(jax.random.PRNGKey(0), (b, d))
        keys = _unit(jax.random.PRNGKey(1), (n, d))
        valid = jnp.ones((n,), bool).at[32:64].set(False)
        starts = jnp.array([0, 32, 0, 32, 90, 0], dtype=jnp.int32)
        sizes = jnp.array([32, 32, 0, 0, 6, 96], dtype=jnp.int32)
        # rows 1-3: empty (region fully dead / size 0); rows 0, 4, 5: live
        exp = ref.cosine_topk_interval_ref(q, keys, valid, starts, sizes, 3)
        got = cosine_topk_interval_pallas(q, keys, valid, starts, sizes,
                                          k=3, block_b=8, block_n=32,
                                          interpret=True)
        _check(exp, got)
        ps, pi = got
        for row in (1, 2, 3):
            assert bool(jnp.all(pi[row] == -1))
            assert bool(jnp.all(ps[row] == -jnp.inf))
        assert bool(jnp.all(pi[0] >= 0))

    def test_int8_slab_uniform_dequant(self):
        """Satellite regression: int8 slab keys must score dequantized
        (x 1/127) — raw-int8 scoring would inflate scores x127."""
        b, n, d, k = 5, 160, 48, 4
        q = _unit(jax.random.PRNGKey(2), (b, d))
        keys = _unit(jax.random.PRNGKey(3), (n, d))
        keys8 = _slab_int8(keys)
        valid = jax.random.bernoulli(jax.random.PRNGKey(4), 0.9, (n,))
        starts, sizes = _random_intervals(jax.random.PRNGKey(5), b, n)
        exp = ref.cosine_topk_interval_ref(q, keys8, valid, starts, sizes, k)
        got = cosine_topk_interval_pallas(q, keys8, valid, starts, sizes,
                                          k=k, block_b=8, block_n=32,
                                          interpret=True)
        _check(exp, got)
        ps, _ = got
        finite = np.asarray(ps)[np.isfinite(np.asarray(ps))]
        assert (np.abs(finite) <= 1.01).all()   # cosine range, not x127

    def test_per_row_scale_int8(self):
        b, n, d, k = 4, 128, 64, 2
        q = _unit(jax.random.PRNGKey(6), (b, d))
        keys = _unit(jax.random.PRNGKey(7), (n, d))
        keys8, scales = quantize_keys(keys)
        valid = jnp.ones((n,), bool)
        starts, sizes = _random_intervals(jax.random.PRNGKey(8), b, n)
        exp = ref.quant_cosine_topk_interval_ref(q, keys8, scales, valid,
                                                 starts, sizes, k)
        got = quant_cosine_topk_interval_pallas(q, keys8, scales, valid,
                                                starts, sizes, k=k,
                                                block_b=8, block_n=64,
                                                interpret=True)
        _check(exp, got, rtol=1e-4, atol=1e-4)

    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 9), st.integers(8, 150), st.integers(8, 48),
           st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
    def test_property_sweep(self, b, n, d, k, seed):
        r = jax.random.PRNGKey(seed)
        kq, kk, kv, ki = jax.random.split(r, 4)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        valid = jax.random.bernoulli(kv, 0.7, (n,))
        starts, sizes = _random_intervals(ki, b, n, empty_every=3)
        exp = ref.cosine_topk_interval_ref(q, keys, valid, starts, sizes, k)
        got = cosine_topk_interval_pallas(q, keys, valid, starts, sizes,
                                          k=k, block_b=8, block_n=64,
                                          interpret=True)
        _check(exp, got, rtol=1e-4, atol=1e-4)


class TestDenseMaskKernel:
    """The general blocked (BB, BN) mask path — non-contiguous visibility."""

    @pytest.mark.parametrize("b,n,d,k", [
        (4, 100, 32, 4),
        (9, 256, 64, 2),
        (17, 96, 128, 4),     # B > block_b
    ])
    def test_matches_oracle_random_mask(self, b, n, d, k):
        r = jax.random.PRNGKey(b * 31 + n)
        kq, kk, km = jax.random.split(r, 3)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        mask = jax.random.bernoulli(km, 0.6, (b, n))
        mask = mask.at[0].set(False)            # one all-masked row
        exp = ref.cosine_topk_ref(q, keys, mask, k)
        got = cosine_topk_masked_pallas(q, keys, mask, k=k, block_b=8,
                                        block_n=32, interpret=True)
        _check(exp, got)
        ps, pi = got
        assert bool(jnp.all(pi[0] == -1)) and bool(jnp.all(ps[0] == -jnp.inf))

    def test_int8_slab(self):
        b, n, d, k = 6, 128, 32, 3
        q = _unit(jax.random.PRNGKey(0), (b, d))
        keys8 = _slab_int8(_unit(jax.random.PRNGKey(1), (n, d)))
        mask = jax.random.bernoulli(jax.random.PRNGKey(2), 0.7, (b, n))
        exp = ref.cosine_topk_ref(q, keys8, mask, k)
        got = cosine_topk_masked_pallas(q, keys8, mask, k=k, block_b=8,
                                        block_n=64, interpret=True)
        _check(exp, got)

    def test_per_row_scale_int8(self):
        b, n, d, k = 4, 96, 48, 2
        q = _unit(jax.random.PRNGKey(3), (b, d))
        keys = _unit(jax.random.PRNGKey(4), (n, d))
        keys8, scales = quantize_keys(keys)
        mask = jax.random.bernoulli(jax.random.PRNGKey(5), 0.5, (b, n))
        keysf = keys8.astype(jnp.float32) * scales[:, None]
        exp = ref.cosine_topk_ref(q, keysf, mask, k)
        got = quant_cosine_topk_masked_pallas(q, keys8, scales, mask, k=k,
                                              block_b=8, block_n=32,
                                              interpret=True)
        _check(exp, got, rtol=1e-4, atol=1e-4)


class TestOpsDispatch:
    """REPRO_PALLAS_INTERPRET=1 must route every ops entry point through the
    Pallas kernels (interpret mode) and still match the oracles — this is
    what the CPU CI kernel job exercises."""

    @pytest.fixture(autouse=True)
    def _interpret(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")

    def test_shared_mask(self):
        from repro.kernels import ops
        q = _unit(jax.random.PRNGKey(0), (4, 32))
        keys = _unit(jax.random.PRNGKey(1), (64, 32))
        valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.8, (64,))
        _check(ref.cosine_topk_ref(q, keys, valid, 2),
               ops.cosine_topk(q, keys, valid, k=2))

    def test_shared_mask_int8_slab(self):
        """Satellite regression at the dispatch level: an int8 slab through
        ops.cosine_topk returns cosine-range scores, not x127."""
        from repro.kernels import ops
        q = _unit(jax.random.PRNGKey(3), (4, 32))
        keys8 = _slab_int8(_unit(jax.random.PRNGKey(4), (64, 32)))
        valid = jnp.ones((64,), bool)
        exp = ref.cosine_topk_ref(q, keys8, valid, 2)
        got = ops.cosine_topk(q, keys8, valid, k=2)
        _check(exp, got)
        assert float(jnp.max(jnp.abs(got[0]))) <= 1.01

    def test_per_row_dense_mask(self):
        from repro.kernels import ops
        q = _unit(jax.random.PRNGKey(5), (5, 32))
        keys = _unit(jax.random.PRNGKey(6), (64, 32))
        mask = jax.random.bernoulli(jax.random.PRNGKey(7), 0.6, (5, 64))
        _check(ref.cosine_topk_ref(q, keys, mask, 3),
               ops.cosine_topk(q, keys, mask, k=3))

    def test_interval(self):
        from repro.kernels import ops
        q = _unit(jax.random.PRNGKey(8), (6, 32))
        keys = _unit(jax.random.PRNGKey(9), (96, 32))
        valid = jnp.ones((96,), bool)
        starts, sizes = _random_intervals(jax.random.PRNGKey(10), 6, 96)
        _check(ref.cosine_topk_interval_ref(q, keys, valid, starts, sizes, 2),
               ops.cosine_topk_interval(q, keys, valid, starts, sizes, k=2))

    def test_quant_per_row_dense_mask(self):
        """(B, N) valid through ops.quant_cosine_topk routes to the masked
        quant kernel instead of crashing on a rank-3 operand."""
        from repro.kernels import ops
        q = _unit(jax.random.PRNGKey(11), (4, 32))
        keys8, scales = quantize_keys(_unit(jax.random.PRNGKey(12), (64, 32)))
        mask = jax.random.bernoulli(jax.random.PRNGKey(13), 0.6, (4, 64))
        _check(ref.quant_cosine_topk_ref(q, keys8, scales, mask, 2),
               ops.quant_cosine_topk(q, keys8, scales, mask, k=2),
               rtol=1e-4, atol=1e-4)


class TestIntervalComposesWithDenseMask:
    """interval= on top of an already-per-row (B, N) alive mask must be
    folded in, not dropped — ExactIndex (both backends) and IVF agree."""

    def test_exact_both_backends(self, monkeypatch):
        from repro.core.index import ExactIndex, ExactState
        from repro.core.similarity import interval_visibility
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        q = _unit(jax.random.PRNGKey(0), (5, 16))
        keys = _unit(jax.random.PRNGKey(1), (64, 16))
        alive2d = jax.random.bernoulli(jax.random.PRNGKey(2), 0.8, (5, 64))
        starts, sizes = _random_intervals(jax.random.PRNGKey(3), 5, 64)
        composed = interval_visibility(alive2d, starts, sizes)
        for backend in ("jnp", "pallas"):
            idx = ExactIndex(topk=3, backend=backend)
            got = idx.search(ExactState(), q, keys, alive2d,
                             interval=(starts, sizes))
            exp = idx.search(ExactState(), q, keys, composed)
            _check(exp, got)
            # the restriction actually bites: every id is inside its interval
            pi = np.asarray(got[1])
            st_, sz = np.asarray(starts), np.asarray(sizes)
            for row in range(5):
                hits = pi[row][pi[row] >= 0]
                assert ((hits >= st_[row])
                        & (hits < st_[row] + sz[row])).all(), backend


class TestEmptyRegionContractAcrossPaths:
    """Satellite: zero live slots in a row's region -> (-inf, -1) from the
    Pallas kernel, the jnp ExactIndex path, and IVF — identically."""

    def _setup(self):
        from repro.core.types import CacheConfig
        d, n, b = 32, 128, 4
        keys = _unit(jax.random.PRNGKey(0), (n, d))
        q = _unit(jax.random.PRNGKey(1), (b, d))
        valid = jnp.ones((n,), bool).at[64:].set(False)  # second half dead
        starts = jnp.array([0, 64, 0, 64], dtype=jnp.int32)
        sizes = jnp.array([64, 64, 64, 64], dtype=jnp.int32)
        # rows 1 and 3 see only the dead half -> empty
        return CacheConfig(dim=d, capacity=n), q, keys, valid, starts, sizes

    def test_three_way_agreement(self):
        from repro.core.index import ExactIndex, ExactState, IVFIndex
        cfg, q, keys, valid, starts, sizes = self._setup()
        interval = (starts, sizes)

        kern = cosine_topk_interval_pallas(q, keys, valid, starts, sizes,
                                           k=2, block_b=8, block_n=32,
                                           interpret=True)
        exact = ExactIndex(topk=2, backend="jnp").search(
            ExactState(), q, keys, valid, interval=interval)
        ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=128, topk=2)
        ist = ivf.fit(keys, valid, jax.random.PRNGKey(2))
        ivf_out = ivf.search(ist, q, keys, valid, interval=interval)

        for name, (s, i) in {"kernel": kern, "exact_jnp": exact,
                             "ivf": ivf_out}.items():
            s, i = np.asarray(s), np.asarray(i)
            assert (i[1] == -1).all() and (i[3] == -1).all(), name
            assert np.isneginf(s[1]).all() and np.isneginf(s[3]).all(), name
            assert (i[0] >= 0).all() and (i[2] >= 0).all(), name
        # live rows agree across all three paths (nprobe covers all buckets)
        np.testing.assert_allclose(np.asarray(kern[0])[[0, 2]],
                                   np.asarray(exact[0])[[0, 2]], atol=1e-5)
        np.testing.assert_array_equal(np.asarray(kern[1])[[0, 2]],
                                      np.asarray(exact[1])[[0, 2]])
        np.testing.assert_array_equal(np.asarray(kern[1])[[0, 2]],
                                      np.asarray(ivf_out[1])[[0, 2]])


class TestTenancyLookupOnKernelPath:
    """Acceptance: with a multi-tenant partition, ExactIndex no longer falls
    back to the jnp path — the interval kernel (interpret mode here, TPU in
    prod) produces lookups identical to the jnp backend, on f32 and int8
    slabs, mixed-tenant batches, and empty-region tenants."""

    @pytest.fixture(autouse=True)
    def _interpret(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")

    @pytest.mark.parametrize("key_dtype", [jnp.float32, jnp.int8])
    def test_lookup_parity_mixed_tenants(self, key_dtype):
        from repro.core import CacheConfig, SemanticCache
        from repro.core.index import ExactIndex
        from repro.tenancy import TenantRegistry

        d, cap, b = 32, 96, 8
        reg = TenantRegistry.uniform(["a", "b", "c"])
        cfg = CacheConfig(dim=d, capacity=cap, value_len=8, ttl=None,
                          key_dtype=key_dtype)
        part = reg.partition(cap)
        emb = jax.random.normal(jax.random.PRNGKey(0), (b, d))
        vals = jnp.zeros((b, 8), jnp.int32)
        lens = jnp.full((b,), 8)
        # tenants a and b get entries; c stays empty
        tid_seed = jnp.asarray([0, 1, 0, 1, 0, 1, 0, 1], jnp.int32)
        probe = emb + 0.1 * jax.random.normal(jax.random.PRNGKey(1), emb.shape)
        tid_mix = jnp.asarray([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)

        results = {}
        for backend in ("pallas", "jnp"):
            cache = SemanticCache(cfg, index=ExactIndex(topk=4,
                                                        backend=backend),
                                  partition=part)
            rt = cache.init()
            rt = cache.insert(rt, emb, vals, lens, 0.0, tenant_id=tid_seed)
            res, rt = cache.lookup(rt, probe, 1.0, tenant_id=tid_mix)
            results[backend] = res

        pl_res, jnp_res = results["pallas"], results["jnp"]
        np.testing.assert_allclose(np.asarray(pl_res.score),
                                   np.asarray(jnp_res.score),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(pl_res.index),
                                      np.asarray(jnp_res.index))
        np.testing.assert_array_equal(np.asarray(pl_res.hit),
                                      np.asarray(jnp_res.hit))
        # tenant c's region is empty: those rows are structural misses
        c_rows = np.asarray(tid_mix) == 2
        assert np.isneginf(np.asarray(pl_res.score)[c_rows]).all()
        assert not np.asarray(pl_res.hit)[c_rows].any()
        # cross-checks: scores are cosine-range (int8 x127 bug regression)
        finite = np.asarray(pl_res.score)[~c_rows]
        assert (np.abs(finite) <= 1.01).all()
