"""Fused Pallas IVF search (DESIGN.md §15) vs the jnp oracles.

Three-way parity — fused kernel vs jnp IVF vs the exact oracle on
fully-probed configs — plus the visibility contract on every edge the
serving path produces: int8 slabs, per-row tenancy intervals, empty-region
tenants, B > block_b, all-dead buckets, and recycled-slot duplicates.
Runs on CPU (kernel in interpret mode) and under REPRO_PALLAS_INTERPRET=1,
where ops.ivf_topk and IVFIndex(backend='auto') dispatch to the kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import (ExactIndex, ExactState, IVFIndex, IVFState,
                              _absorb_serial, dedup_candidates)
from repro.kernels import ops, ref
from repro.kernels.ivf_topk import ivf_topk_pallas


def _unit(rng, shape):
    x = jax.random.normal(rng, shape)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def _slab_int8(keys):
    """The cache slab's uniform symmetric quantization (store.insert)."""
    return jnp.clip(jnp.round(keys * 127.0), -127, 127).astype(jnp.int8)


def _fitted(ivf, keys, valid, seed=2):
    return ivf.fit(keys, valid, jax.random.PRNGKey(seed))


def _near_queries(keys, b, noise_seed=1, noise=0.05):
    q = keys[:b] + noise * jax.random.normal(jax.random.PRNGKey(noise_seed),
                                             (b, keys.shape[1]))
    return q / jnp.linalg.norm(q, axis=1, keepdims=True)


def _check(expected, got, rtol=1e-5, atol=1e-5):
    (rs, ri), (ps, pi) = expected, got
    np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                               rtol=rtol, atol=atol)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))


class TestKernelVsOracle:
    """ivf_topk_pallas (interpret) vs ref.ivf_topk_ref on shared candidate
    sets — the kernel's numerical contract, independent of the index."""

    @pytest.mark.parametrize("b,n,m,d,k", [
        (1, 64, 16, 16, 1),
        (4, 100, 48, 32, 4),      # non-multiple M
        (7, 300, 130, 64, 2),     # M > block_m: merge across candidate tiles
        (20, 256, 96, 48, 4),     # B > block_b: row blocks
        (33, 512, 256, 128, 8),   # B and M both cross blocks
    ])
    @pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
    def test_matches_oracle(self, b, n, m, d, k, dtype):
        r = jax.random.PRNGKey(b * 7919 + m)
        k1, k2, k3, k4 = jax.random.split(r, 4)
        q = _unit(k1, (b, d))
        keys = _unit(k2, (n, d))
        if dtype == "bf16":
            keys = keys.astype(jnp.bfloat16)
        elif dtype == "int8":
            keys = _slab_int8(keys)
        cand = jax.random.randint(k3, (b, m), 0, n, dtype=jnp.int32)
        visible = jax.random.bernoulli(k4, 0.8, (b, m))
        visible = dedup_candidates(cand, visible)
        cand = jnp.where(visible, cand, -1)
        exp = ref.ivf_topk_ref(q, keys, cand, k)
        got = ivf_topk_pallas(q, keys, cand, k=k, interpret=True)
        tol = 2e-2 if dtype == "bf16" else 1e-5
        _check(exp, got, rtol=tol, atol=tol)

    def test_all_masked_rows_return_empty_contract(self):
        q = _unit(jax.random.PRNGKey(0), (5, 16))
        keys = _unit(jax.random.PRNGKey(1), (64, 16))
        cand = jnp.full((5, 24), -1, jnp.int32)  # nothing visible anywhere
        s, i = ivf_topk_pallas(q, keys, cand, k=3, interpret=True)
        assert np.all(np.asarray(s) == -np.inf)
        assert np.all(np.asarray(i) == -1)
        _check(ref.ivf_topk_ref(q, keys, cand, 3), (s, i))


class TestThreeWayParity:
    """Fused IVF == jnp IVF == exact oracle when every bucket is probed and
    capacity holds the whole slab (recall is exactly 1 by construction)."""

    @pytest.mark.parametrize("dtype", ["f32", "int8"])
    def test_fully_probed_equals_exact(self, dtype):
        d, n, b, k = 32, 300, 12, 4
        keys = _unit(jax.random.PRNGKey(0), (n, d))
        valid = jax.random.bernoulli(jax.random.PRNGKey(5), 0.9, (n,))
        q = _near_queries(keys, b)
        slab = _slab_int8(keys) if dtype == "int8" else keys
        st = _fitted(IVFIndex(ncentroids=8, nprobe=8, bucket_cap=512,
                              topk=k), keys, valid)
        exact = ExactIndex(topk=k, backend="jnp").search(
            ExactState(), q, slab, valid)
        for backend in ("jnp", "pallas"):
            ivf = IVFIndex(ncentroids=8, nprobe=8, bucket_cap=512, topk=k,
                           backend=backend)
            got = ivf.search(st, q, slab, valid)
            # candidate *order* differs (bucket-major vs slot-major) so
            # equal-score permutations are legal; compare as sorted sets
            np.testing.assert_array_equal(np.sort(np.asarray(got[1]), 1),
                                          np.sort(np.asarray(exact[1]), 1))
            np.testing.assert_allclose(np.sort(np.asarray(got[0]), 1),
                                       np.sort(np.asarray(exact[0]), 1),
                                       rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
    @pytest.mark.parametrize("with_interval", [False, True])
    def test_backend_parity_partial_probe(self, dtype, with_interval):
        """The acceptance sweep: fused vs jnp IVF, bit-for-bit ids and
        1e-5 scores, across slab dtypes x interval/no-interval."""
        d, n, b, k = 48, 400, 20, 4        # b=20 > block_b=8
        keys = _unit(jax.random.PRNGKey(7), (n, d))
        valid = jax.random.bernoulli(jax.random.PRNGKey(8), 0.85, (n,))
        q = _near_queries(keys, b, noise_seed=9)
        slab = keys
        if dtype == "bf16":
            slab = keys.astype(jnp.bfloat16)
        elif dtype == "int8":
            slab = _slab_int8(keys)
        interval = None
        if with_interval:
            starts = jnp.where(jnp.arange(b) % 2 == 0, 0, n // 2
                               ).astype(jnp.int32)
            sizes = jnp.full((b,), n // 2, jnp.int32)
            # every 5th row: empty region (the §14.4 contract edge)
            sizes = jnp.where(jnp.arange(b) % 5 == 4, 0, sizes)
            interval = (starts, sizes)
        st = _fitted(IVFIndex(ncentroids=8, nprobe=4, bucket_cap=64,
                              topk=k), keys, valid)
        ivf_j = IVFIndex(ncentroids=8, nprobe=4, bucket_cap=64, topk=k,
                         backend="jnp")
        ivf_p = IVFIndex(ncentroids=8, nprobe=4, bucket_cap=64, topk=k,
                         backend="pallas")
        exp = ivf_j.search(st, q, slab, valid, interval=interval)
        got = ivf_p.search(st, q, slab, valid, interval=interval)
        tol = 2e-2 if dtype == "bf16" else 1e-5
        _check(exp, got, rtol=tol, atol=tol)
        if with_interval:
            # interval restriction actually bites on both paths
            ids = np.asarray(got[1])
            st_, sz = np.asarray(interval[0]), np.asarray(interval[1])
            for row in range(b):
                hits = ids[row][ids[row] >= 0]
                assert ((hits >= st_[row]) & (hits < st_[row] + sz[row])).all()
            empty = np.arange(b) % 5 == 4
            assert np.all(np.asarray(got[0])[empty] == -np.inf)
            assert np.all(ids[empty] == -1)


class TestEdgeCases:
    def _base(self, d=24, n=128, b=6):
        keys = _unit(jax.random.PRNGKey(0), (n, d))
        valid = jnp.ones((n,), bool)
        q = _near_queries(keys, b)
        return keys, valid, q

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_all_dead_buckets(self, backend):
        """Pre-refit index (or fully expired slab): every bucket slot
        invalid -> every row returns exactly (-inf, -1)."""
        keys, valid, q = self._base()
        ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=32, topk=3,
                       backend=backend)
        st = ivf.init(type("C", (), {"dim": keys.shape[1]})())
        s, i = ivf.search(st, q, keys, valid)
        assert np.all(np.asarray(s) == -np.inf)
        assert np.all(np.asarray(i) == -1)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_empty_region_tenant(self, backend):
        """A tenant with a zero-size region sees an empty cache even when
        the slab is full and every bucket is live."""
        keys, valid, q = self._base()
        ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=64, topk=2,
                       backend=backend)
        st = _fitted(ivf, keys, valid)
        b = q.shape[0]
        starts = jnp.zeros((b,), jnp.int32)
        sizes = jnp.zeros((b,), jnp.int32)
        s, i = ivf.search(st, q, keys, valid, interval=(starts, sizes))
        assert np.all(np.asarray(s) == -np.inf)
        assert np.all(np.asarray(i) == -1)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_per_row_dense_valid(self, backend):
        """(B, N) per-row aliveness composes with the candidate gather on
        both backends identically."""
        keys, _, q = self._base()
        b, n = q.shape[0], keys.shape[0]
        valid2d = jax.random.bernoulli(jax.random.PRNGKey(3), 0.7, (b, n))
        ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=64, topk=3,
                       backend=backend)
        st = _fitted(ivf, keys, jnp.ones((n,), bool))
        s, i = ivf.search(st, q, keys, valid2d)
        ids, vis = np.asarray(i), np.asarray(valid2d)
        for row in range(b):
            for slot in ids[row][ids[row] >= 0]:
                assert vis[row, slot]


class TestDuplicateCandidates:
    """Satellite regression: a slot recycled across buckets must occupy at
    most one of the k result rows (previously documented as 'harmless' —
    it wasn't: it wasted top-k slots on copies of one entry)."""

    def _dup_state(self, d, n):
        """Hand-built index where slot 5 appears in BOTH buckets."""
        keys = _unit(jax.random.PRNGKey(0), (n, d))
        buckets = jnp.full((2, 4), -1, jnp.int32)
        bucket_valid = jnp.zeros((2, 4), bool)
        buckets = buckets.at[0, :3].set(jnp.array([5, 1, 2]))
        buckets = buckets.at[1, :3].set(jnp.array([5, 3, 4]))  # stale pointer
        bucket_valid = bucket_valid.at[0, :3].set(True)
        bucket_valid = bucket_valid.at[1, :3].set(True)
        centroids = _unit(jax.random.PRNGKey(1), (2, d))
        return keys, IVFState(centroids=centroids, buckets=buckets,
                              bucket_valid=bucket_valid)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_recycled_slot_fills_one_result_row(self, backend):
        d, n = 16, 8
        keys, st = self._dup_state(d, n)
        # query = slot 5's key: without dedup its two occurrences would
        # take result rows 1 AND 2 with identical (maximal) scores
        q = keys[5:6]
        ivf = IVFIndex(ncentroids=2, nprobe=2, bucket_cap=4, topk=3,
                       backend=backend)
        s, i = ivf.search(st, q, keys, jnp.ones((n,), bool))
        ids = np.asarray(i)[0]
        real = ids[ids >= 0]
        assert len(set(real.tolist())) == len(real), ids
        assert real[0] == 5
        assert np.count_nonzero(real == 5) == 1

    def test_absorb_recycling_end_to_end(self):
        """Force the duplicate through the real lifecycle: absorb indexes a
        slot near centroid A, the slot is recycled (new key near centroid
        B) and absorbed again — both buckets now reference it; search with
        both buckets probed returns it once."""
        d, n = 16, 32
        centroids = jnp.eye(2, d, dtype=jnp.float32)         # orthogonal
        st = IVFState(centroids=centroids,
                      buckets=jnp.full((2, 8), -1, jnp.int32),
                      bucket_valid=jnp.zeros((2, 8), bool))
        ivf = IVFIndex(ncentroids=2, nprobe=2, bucket_cap=8, topk=4,
                       backend="jnp")
        slot = jnp.array([7])
        key_a = jnp.eye(1, d, dtype=jnp.float32)              # -> bucket 0
        key_b = jnp.zeros((1, d)).at[0, 1].set(1.0)           # -> bucket 1
        st = ivf.absorb(st, slot, key_a, jnp.array([True]))
        st = ivf.absorb(st, slot, key_b, jnp.array([True]))   # recycled
        assert int(jnp.sum((st.buckets == 7) & st.bucket_valid)) == 2
        keys = jnp.zeros((n, d)).at[7].set(key_b[0])          # live key = b
        for backend in ("jnp", "pallas"):
            s, i = IVFIndex(ncentroids=2, nprobe=2, bucket_cap=8, topk=4,
                            backend=backend).search(
                st, key_b, keys, jnp.ones((n,), bool).at[0].set(True))
            ids = np.asarray(i)[0]
            assert np.count_nonzero(ids == 7) == 1, (backend, ids)

    def test_dedup_keeps_first_visible_occurrence(self):
        cand = jnp.array([[5, 7, 5, 9, -1, 5],
                          [1, 1, 1, 1, 1, 1]], jnp.int32)
        vis = jnp.array([[False, True, True, True, False, True],
                         [True, False, True, True, True, True]])
        out = np.asarray(dedup_candidates(cand, vis))
        # row 0: first occurrence of 5 is invisible -> position 2 survives
        assert out[0].tolist() == [False, True, True, True, False, False]
        # row 1: only the first visible 1 survives
        assert out[1].tolist() == [True, False, False, False, False, False]


class TestAbsorbVectorized:
    """Satellite parity: the sort-by-centroid vectorized absorb must equal
    the serial fori_loop scatter bit-for-bit, including bucket overflow
    (clamped tail, last writer wins) and masked-out rows."""

    @pytest.mark.parametrize("seed", range(6))
    def test_parity_random(self, seed):
        d, n, c, cap, b = 24, 200, 6, 8, 32
        r = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5 = jax.random.split(r, 5)
        ivf = IVFIndex(ncentroids=c, nprobe=2, bucket_cap=cap, topk=2)
        centroids = _unit(k1, (c, d))
        # random pre-fill levels, incl. full and empty buckets
        fill = jax.random.randint(k2, (c,), 0, cap + 1)
        col = jnp.arange(cap)[None, :]
        bucket_valid = col < fill[:, None]
        buckets = jnp.where(bucket_valid,
                            jax.random.randint(k3, (c, cap), 0, n), -1
                            ).astype(jnp.int32)
        st = IVFState(centroids=centroids, buckets=buckets,
                      bucket_valid=bucket_valid)
        new_keys = jax.random.normal(k4, (b, d))
        slots = jax.random.randint(k5, (b,), 0, n)
        mask = jax.random.bernoulli(jax.random.PRNGKey(seed + 100), 0.7, (b,))

        got = ivf.absorb(st, slots, new_keys, mask)
        qn = new_keys / jnp.maximum(
            jnp.linalg.norm(new_keys, axis=1, keepdims=True), 1e-12)
        assign = jnp.argmax(jnp.einsum("bd,cd->bc", qn, centroids), axis=-1)
        exp_b, exp_v = _absorb_serial(st.buckets, st.bucket_valid, assign,
                                      slots, mask, cap)
        np.testing.assert_array_equal(np.asarray(got.buckets),
                                      np.asarray(exp_b))
        np.testing.assert_array_equal(np.asarray(got.bucket_valid),
                                      np.asarray(exp_v))

    def test_single_bucket_overflow_last_writer_wins(self):
        d, n, cap = 8, 64, 2
        centroids = jnp.eye(1, d, dtype=jnp.float32)
        st = IVFState(centroids=centroids,
                      buckets=jnp.full((1, cap), -1, jnp.int32),
                      bucket_valid=jnp.zeros((1, cap), bool))
        ivf = IVFIndex(ncentroids=1, nprobe=1, bucket_cap=cap, topk=1)
        keys = jnp.tile(jnp.eye(1, d, dtype=jnp.float32), (4, 1))
        slots = jnp.array([10, 11, 12, 13])
        got = ivf.absorb(st, slots, keys, jnp.ones((4,), bool))
        # fill order 10, 11; 12 and 13 clamp onto the tail; 13 wins
        np.testing.assert_array_equal(np.asarray(got.buckets[0]), [10, 13])
        assert bool(jnp.all(got.bucket_valid))


class TestOpsDispatch:
    """REPRO_PALLAS_INTERPRET=1 must route ops.ivf_topk — and the whole
    IVFIndex(backend='auto') search — through the interpret-mode kernel and
    still match the oracle; this is what the CPU CI kernel job exercises."""

    @pytest.fixture(autouse=True)
    def _interpret(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")

    def test_ops_ivf_topk(self):
        q = _unit(jax.random.PRNGKey(0), (4, 32))
        keys = _unit(jax.random.PRNGKey(1), (96, 32))
        cand = jax.random.randint(jax.random.PRNGKey(2), (4, 40), 0, 96,
                                  dtype=jnp.int32)
        vis = jax.random.bernoulli(jax.random.PRNGKey(3), 0.8, (4, 40))
        vis = dedup_candidates(cand, vis)
        cand = jnp.where(vis, cand, -1)
        _check(ref.ivf_topk_ref(q, keys, cand, 3),
               ops.ivf_topk(q, keys, cand, k=3))

    def test_auto_backend_search_matches_jnp(self):
        d, n, b = 32, 200, 5
        keys = _unit(jax.random.PRNGKey(0), (n, d))
        valid = jnp.ones((n,), bool)
        q = _near_queries(keys, b)
        st = _fitted(IVFIndex(ncentroids=4, nprobe=2, bucket_cap=64,
                              topk=3), keys, valid)
        auto = IVFIndex(ncentroids=4, nprobe=2, bucket_cap=64, topk=3)
        jnp_ = IVFIndex(ncentroids=4, nprobe=2, bucket_cap=64, topk=3,
                        backend="jnp")
        _check(jnp_.search(st, q, keys, valid),
               auto.search(st, q, keys, valid))
