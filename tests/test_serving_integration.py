"""End-to-end serving tests: the paper's workflow (§2.5, §3) in miniature,
plus the reproduction-band assertion against the paper's own numbers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import CacheConfig
from repro.data.qa_dataset import (CATEGORIES, build_corpus,
                                   build_test_queries)
from repro.data.tokenizer import HashTokenizer
from repro.embedding.hash_embedder import HashEmbedder
from repro.serving import CachedEngine, Request, SimulatedLLMBackend


@pytest.fixture(scope="module")
def small_world():
    pairs = build_corpus(300, seed=0)
    queries = build_test_queries(pairs, n_per_category=60, seed=1)
    return pairs, queries


def make_engine(pairs, **kw):
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    cfg = kw.pop("config", CacheConfig(dim=384, capacity=4096, value_len=48,
                                       ttl=None, threshold=0.8))
    return CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                        batch_size=32, **kw), judge


class TestWorkflow:
    def test_repeat_query_becomes_hit(self, small_world):
        pairs, _ = small_world
        eng, _ = make_engine(pairs)
        r = Request(query="how do i print the current time in python",
                    category="python_basics")
        first = eng.process([r])[0]
        assert not first.cached
        second = eng.process([r])[0]      # identical query -> cache hit
        assert second.cached
        assert second.score > 0.999
        assert second.answer == first.answer

    def test_warm_cache_serves_paraphrases(self, small_world):
        pairs, queries = small_world
        eng, _ = make_engine(pairs)
        eng.warm(pairs)
        para = [q for q in queries if q.source_id >= 0][:20]
        resp = eng.process([Request(query=q.query, category=q.category,
                                    source_id=q.source_id,
                                    semantic_key=q.semantic_key)
                            for q in para])
        hit_rate = sum(r.cached for r in resp) / len(resp)
        assert hit_rate >= 0.5

    def test_miss_inserts_and_next_hit(self, small_world):
        pairs, _ = small_world
        eng, _ = make_engine(pairs)
        novel = Request(query="what is the airspeed velocity of a laden swallow")
        r1 = eng.process([novel])[0]
        assert not r1.cached
        r2 = eng.process([novel])[0]
        assert r2.cached and r2.answer == r1.answer

    def test_ttl_expiry_in_serving(self, small_world):
        pairs, _ = small_world
        cfg = CacheConfig(dim=384, capacity=1024, value_len=48, ttl=60.0,
                          threshold=0.8)
        eng, _ = make_engine(pairs, config=cfg)
        q = Request(query="does the blender come with a warranty")
        eng.process([q])
        assert eng.process([q])[0].cached
        eng.tick(61.0)                      # advance past TTL
        assert not eng.process([q])[0].cached

    def test_cost_accounting(self, small_world):
        pairs, _ = small_world
        eng, _ = make_engine(pairs)
        qs = [Request(query=f"completely unique question number {i} about {i}")
              for i in range(10)]
        eng.process(qs)            # all miss
        eng.process(qs)            # all hit
        s = eng.metrics.summary()
        assert s["queries"] == 20
        assert s["total_cost_usd"] == pytest.approx(
            10 * eng.backend.cost_per_call_usd)
        assert s["baseline_cost_usd"] == pytest.approx(
            20 * eng.backend.cost_per_call_usd)
        assert s["cost_saving_pct"] == pytest.approx(50.0)
        assert s["avg_latency_with_cache_s"] < s["avg_latency_without_cache_s"]


@pytest.mark.slow
class TestPaperReproduction:
    """The headline claim: hit rates in the paper's band with >88% accuracy."""

    def test_paper_band(self):
        pairs = build_corpus(2000, seed=0)          # 8,000 QA pairs (§3.1)
        queries = build_test_queries(pairs, n_per_category=500, seed=1)
        eng, _ = make_engine(pairs, config=CacheConfig(
            dim=384, capacity=16384, value_len=48, ttl=None, threshold=0.8))
        eng.warm(pairs)
        eng.process([Request(query=q.query, category=q.category,
                             source_id=q.source_id,
                             semantic_key=q.semantic_key) for q in queries])
        s = eng.metrics.summary()
        for cat in CATEGORIES:
            m = s["categories"][cat]
            # paper band (Table 1): 61.6%..68.8% hits, positive > 92.5%;
            # assert a tolerant envelope around it
            assert 0.55 <= m["hit_rate"] <= 0.78, (cat, m)
            assert m["positive_rate"] >= 0.85, (cat, m)
        assert s["cost_saving_pct"] >= 55.0


class TestEngineInternals:
    def test_stats_consistency(self, small_world):
        pairs, queries = small_world
        eng, _ = make_engine(pairs)
        eng.warm(pairs[:100])
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries[:64]]
        resp = eng.process(reqs)
        assert int(eng.stats.lookups) == 64
        assert int(eng.stats.hits) == sum(r.cached for r in resp)
        # every miss called the backend exactly once
        assert eng.backend.calls == sum(not r.cached for r in resp)

    def test_batcher_splits(self):
        from repro.serving.engine import Batcher
        b = Batcher(batch_size=8)
        reqs = [Request(query=str(i)) for i in range(20)]
        sizes = [len(x) for x in b.batches(reqs)]
        assert sizes == [8, 8, 4]


class TestAdaptiveThresholdEngine:
    """Paper §2.10 'Dynamic Threshold Adjustment' — closed control loop."""

    def test_threshold_rises_when_precision_low(self, small_world):
        from repro.core.policy import AdaptiveThreshold
        import numpy as np
        pairs, queries = small_world
        by_id = {p.qa_id: p for p in pairs}

        def judge(req, sid):
            return sid >= 0 and sid in by_id and \
                by_id[sid].semantic_key == req.semantic_key

        from repro.core.types import CacheConfig
        cfg = CacheConfig(dim=384, capacity=4096, value_len=48, ttl=None,
                          threshold=0.6)
        eng = CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                           batch_size=32,
                           policy=AdaptiveThreshold(
                               init=0.6, target_precision=0.99, lr=0.1,
                               ema=0.5))
        eng.warm(pairs)
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries]
        eng.process(reqs * 2)   # enough batches for the controller to move
        final_thr = float(np.asarray(eng.policy_state)[0])
        # at 0.6 the cache over-hits with imperfect precision; the controller
        # must push the threshold up toward the paper's knee
        assert final_thr > 0.62, final_thr


class TestIVFEngine:
    """IVF-indexed engine (TPU-native sub-linear ANN + periodic rebuild —
    the paper's HNSW rebalancing analogue) must track the exact engine."""

    def test_ivf_hits_match_exact(self, small_world):
        from repro.core.index import IVFIndex
        pairs, queries = small_world
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries]
        hits = {}
        for name, idx in [("exact", None),
                          ("ivf", IVFIndex(ncentroids=32, nprobe=8,
                                           bucket_cap=128, topk=4))]:
            eng, _ = make_engine(pairs, index=idx)
            eng.warm(pairs)
            resp = eng.process(reqs)
            hits[name] = sum(r.cached for r in resp)
        assert hits["ivf"] >= 0.85 * hits["exact"], hits


class TestCachePersistence:
    """Redis-persistence analogue: slab snapshot + warm restart."""

    def test_save_load_roundtrip(self, small_world, tmp_path):
        import os
        pairs, queries = small_world
        eng, _ = make_engine(pairs)
        eng.warm(pairs)
        path = os.path.join(str(tmp_path), "slab.npz")
        eng.save_cache(path)

        eng2, _ = make_engine(pairs)        # fresh engine, empty slab
        para = [q for q in queries if q.source_id >= 0][:16]
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in para]
        cold = sum(r.cached for r in eng2.process(reqs))
        eng3, _ = make_engine(pairs)
        eng3.load_cache(path)               # warm restart from the snapshot
        warm = sum(r.cached for r in eng3.process(reqs))
        assert warm > cold
        assert warm >= 8
