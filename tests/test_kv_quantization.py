"""int8 KV-cache quantization (§Perf pair 4): accuracy + mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.attention import dequantize_kv, quantize_kv
from repro.models.model import Model


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 64)) * 3.0
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s)
    # error bounded by scale/2 = absmax/254 per row
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert bool(jnp.all(jnp.abs(back - x) <= absmax / 127.0 + 1e-6))


@pytest.mark.parametrize("arch", ["yi-6b", "qwen2-vl-2b"])
def test_int8_kv_decode_matches_bf16(arch):
    """Greedy rollout with int8 KV must track the f32/bf16 cache.

    Argmax agreement is only a well-posed demand on rows whose full-precision
    top-2 logit margin exceeds the quantization-induced logit error: a row
    whose top two logits sit closer than the error is a genuine near-tie —
    either token is a faithful greedy choice, and which one wins is decided
    by sub-error noise, not by a quantization bug (qwen2-vl-2b's reduced
    config lands one such row: margin ~0.005 vs error ~0.04). So the check
    is margin-aware: decisive rows must agree exactly, the absolute logit
    error stays bounded for every row, and at least one row must be decisive
    so the agreement check can never pass vacuously.
    """
    cfg = get_arch(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg.vocab)
    step = jax.jit(m.decode_step)

    logits = {}
    for quant in (False, True):
        caches = m.init_decode_caches(batch=2, cache_size=48,
                                      kv_quantized=quant)
        for t in range(tokens.shape[1]):
            dl, caches = step(params, caches, tokens[:, t:t + 1])
        logits[quant] = dl
    err = float(jnp.max(jnp.abs(logits[False] - logits[True])))
    full = np.asarray(logits[False], dtype=np.float32).reshape(-1, cfg.vocab)
    quant = np.asarray(logits[True], dtype=np.float32).reshape(-1, cfg.vocab)
    top2 = np.sort(full, axis=-1)[:, -2:]
    margin = top2[:, 1] - top2[:, 0]            # bf16 top-2 gap per row
    per_row_err = np.max(np.abs(full - quant), axis=-1)
    decisive = margin > per_row_err
    assert decisive.any(), "every row is a near-tie; widen the rollout"
    agree = (np.argmax(full, -1) == np.argmax(quant, -1))[decisive]
    assert agree.all(), \
        f"{arch}: argmax diverged on a decisive row (err {err})"
    assert err < 0.2, err


def test_quantized_cache_memory_layout():
    cfg = get_arch("yi-6b").reduced()
    m = Model(cfg)
    c = m.init_decode_caches(batch=2, cache_size=16, kv_quantized=True)
    assert c.kv.k.dtype == jnp.int8 and c.kv.quantized
    assert c.kv.k_scale.shape == c.kv.k.shape[:-1]
    c2 = m.init_decode_caches(batch=2, cache_size=16)
    assert not c2.kv.quantized and c2.kv.k_scale.size == 0
