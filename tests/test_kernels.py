"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in ``repro.kernels.ref`` (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.cosine_topk import (cosine_topk_pallas,
                                       quant_cosine_topk_pallas,
                                       quantize_keys)
from repro.kernels.flash_attention import flash_attention_pallas


def _unit(rng, shape):
    x = jax.random.normal(rng, shape)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


class TestCosineTopK:
    @pytest.mark.parametrize("b,n,d,k", [
        (1, 64, 16, 1),
        (4, 100, 32, 4),      # non-multiple N
        (16, 1024, 384, 4),   # MiniLM dim
        (3, 517, 64, 2),      # awkward everything
        (8, 256, 1536, 4),    # ada-002 dim
        (33, 128, 128, 8),    # B > block
    ])
    def test_matches_oracle(self, b, n, d, k):
        r = jax.random.PRNGKey(b * 1000 + n)
        kq, kk, kv = jax.random.split(r, 3)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        valid = jax.random.bernoulli(kv, 0.8, (n,))
        rs, ri = ref.cosine_topk_ref(q, keys, valid, k)
        ps, pi = cosine_topk_pallas(q, keys, valid, k=k, block_b=8,
                                    block_n=128, interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))

    def test_all_invalid(self):
        q = _unit(jax.random.PRNGKey(0), (2, 16))
        keys = _unit(jax.random.PRNGKey(1), (32, 16))
        valid = jnp.zeros((32,), dtype=bool)
        ps, pi = cosine_topk_pallas(q, keys, valid, k=2, block_b=8,
                                    block_n=16, interpret=True)
        assert bool(jnp.all(pi == -1))
        assert bool(jnp.all(ps == -jnp.inf))

    def test_int8_slab_keys_dequant_in_kernel(self):
        """Regression: the exact kernel on an int8 slab (uniform symmetric
        round(normalized * 127) from store.insert) must dequant in-kernel —
        scoring raw int8 inflates every score x127 and makes every
        threshold comparison spuriously hit."""
        q = _unit(jax.random.PRNGKey(0), (4, 64))
        keys = _unit(jax.random.PRNGKey(1), (128, 64))
        keys8 = jnp.clip(jnp.round(keys * 127.0), -127, 127).astype(jnp.int8)
        valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.9, (128,))
        rs, ri = ref.cosine_topk_ref(q, keys8, valid, 2)
        ps, pi = cosine_topk_pallas(q, keys8, valid, k=2, block_b=8,
                                    block_n=64, interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))
        assert float(jnp.max(jnp.abs(ps))) <= 1.01  # cosine range, not x127

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_key_dtypes(self, dtype):
        q = _unit(jax.random.PRNGKey(0), (4, 64))
        keys = _unit(jax.random.PRNGKey(1), (128, 64)).astype(dtype)
        valid = jnp.ones((128,), dtype=bool)
        rs, ri = ref.cosine_topk_ref(q, keys, valid, 2)
        ps, pi = cosine_topk_pallas(q, keys, valid, k=2, block_b=8,
                                    block_n=64, interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                                   rtol=2e-3, atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 9), st.integers(8, 200), st.integers(8, 64),
           st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
    def test_property_sweep(self, b, n, d, k, seed):
        r = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(r, 3)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        valid = jax.random.bernoulli(kv, 0.7, (n,))
        rs, ri = ref.cosine_topk_ref(q, keys, valid, k)
        ps, pi = cosine_topk_pallas(q, keys, valid, k=k, block_b=8,
                                    block_n=64, interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                                   rtol=1e-4, atol=1e-4)


class TestQuantCosineTopK:
    @pytest.mark.parametrize("b,n,d,k", [(4, 128, 64, 4), (8, 300, 384, 2)])
    def test_matches_oracle(self, b, n, d, k):
        r = jax.random.PRNGKey(7)
        kq, kk, kv = jax.random.split(r, 3)
        q = _unit(kq, (b, d))
        keys = _unit(kk, (n, d))
        kq8, sc = quantize_keys(keys)
        valid = jax.random.bernoulli(kv, 0.9, (n,))
        rs, ri = ref.quant_cosine_topk_ref(q, kq8, sc, valid, k)
        ps, pi = quant_cosine_topk_pallas(q, kq8, sc, valid, k=k, block_b=8,
                                          block_n=64, interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(ps),
                                   rtol=1e-4, atol=1e-4)

    def test_quantization_error_bounded(self):
        keys = _unit(jax.random.PRNGKey(0), (256, 384))
        kq8, sc = quantize_keys(keys)
        deq = kq8.astype(jnp.float32) * sc[:, None]
        err = jnp.max(jnp.abs(deq - keys))
        assert float(err) < 1.0 / 127.0  # symmetric int8 bound on unit rows


class TestFlashAttention:
    @pytest.mark.parametrize("b,lq,lk,h,hkv,d,causal,window", [
        (2, 128, 128, 4, 2, 64, True, None),
        (1, 64, 256, 8, 4, 32, True, None),    # decode-ish lq < lk
        (2, 128, 128, 4, 1, 64, True, 64),     # sliding window, MQA
        (1, 128, 128, 2, 2, 64, False, None),  # bidirectional
        (1, 256, 256, 4, 4, 128, True, 128),
    ])
    def test_matches_oracle(self, b, lq, lk, h, hkv, d, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(lq * lk + h), 3)
        q = jax.random.normal(ks[0], (b, lq, h, d)) * 0.3
        k = jax.random.normal(ks[1], (b, lk, hkv, d)) * 0.3
        v = jax.random.normal(ks[2], (b, lk, hkv, d))
        g = h // hkv
        r = ref.flash_attention_ref(q, jnp.repeat(k, g, axis=2),
                                    jnp.repeat(v, g, axis=2),
                                    causal=causal, window=window)
        p = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                   block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = (jax.random.normal(ks[0], (1, 128, 2, 64)) * 0.3).astype(jnp.bfloat16)
        k = (jax.random.normal(ks[1], (1, 128, 2, 64)) * 0.3).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(jnp.bfloat16)
        r = ref.flash_attention_ref(q, k, v, causal=True)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(r, dtype=np.float32),
                                   np.asarray(p, dtype=np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestBlockwiseAttentionVsKernel:
    """The jnp blockwise path (models/attention.py) must agree with the
    Pallas kernel contract — they are interchangeable backends."""

    def test_agreement(self):
        from repro.models.attention import blockwise_attention
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 64)) * 0.3
        k = jax.random.normal(ks[1], (2, 128, 2, 64)) * 0.3
        v = jax.random.normal(ks[2], (2, 128, 2, 64))
        a = blockwise_attention(q, k, v, causal=True, block_q=32, block_k=32)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(p),
                                   rtol=2e-4, atol=2e-4)


class TestDecodeAttentionKernel:
    """Single-token decode kernel vs the model's decode_attention path,
    across GQA / window / sink / int8 configurations."""

    @pytest.mark.parametrize("b,s,h,hkv,d,window,sink,quant", [
        (2, 128, 4, 2, 64, None, 0, False),
        (1, 256, 8, 8, 64, 64, 0, False),
        (2, 128, 4, 1, 128, None, 0, True),
        (1, 256, 6, 2, 64, 32, 8, True),
        (3, 64, 2, 2, 64, None, 0, True),
    ])
    def test_matches_model_path(self, b, s, h, hkv, d, window, sink, quant):
        from repro.kernels.decode_attention import decode_attention_pallas
        from repro.models.attention import decode_attention, quantize_kv
        ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d)) * 0.3
        kc = jax.random.normal(ks[1], (b, s, hkv, d)) * 0.3
        vc = jax.random.normal(ks[2], (b, s, hkv, d))
        pos = jnp.asarray(s - 1, jnp.int32)
        slot_pos = jnp.arange(s, dtype=jnp.int32)
        if quant:
            kq, kscale = quantize_kv(kc)
            vq, vscale = quantize_kv(vc)
            ref_out = decode_attention(
                q, kq, vq, slot_pos, pos, window=window, n_sink=sink,
                k_scale=kscale, v_scale=vscale)
            out = decode_attention_pallas(
                q, kq, vq, slot_pos, pos, k_scale=kscale, v_scale=vscale,
                window=window, n_sink=sink, block_s=64, interpret=True)
        else:
            ref_out = decode_attention(q, kc, vc, slot_pos, pos,
                                       window=window, n_sink=sink)
            out = decode_attention_pallas(q, kc, vc, slot_pos, pos,
                                          window=window, n_sink=sink,
                                          block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(ref_out), np.asarray(out),
                                   rtol=3e-4, atol=3e-4)

    def test_ring_cache_with_empty_slots(self):
        from repro.kernels.decode_attention import decode_attention_pallas
        from repro.models.attention import decode_attention
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        b, s, h, d = 1, 64, 2, 64
        q = jax.random.normal(ks[0], (b, 1, h, d)) * 0.3
        kc = jax.random.normal(ks[1], (b, s, h, d)) * 0.3
        vc = jax.random.normal(ks[2], (b, s, h, d))
        # half-full ring: slots 0..31 hold positions 0..31, rest empty
        slot_pos = jnp.where(jnp.arange(s) < 32, jnp.arange(s), -1)
        pos = jnp.asarray(31, jnp.int32)
        ref_out = decode_attention(q, kc, vc, slot_pos, pos)
        out = decode_attention_pallas(q, kc, vc, slot_pos, pos, block_s=32,
                                      interpret=True)
        np.testing.assert_allclose(np.asarray(ref_out), np.asarray(out),
                                   rtol=3e-4, atol=3e-4)
