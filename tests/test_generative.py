"""Generative near-hit cache tests (DESIGN.md §17): band-edge semantics
(scores exactly at τ_lo/τ_hi), empty-slab near requests, per-tenant band
overrides, fused-vs-separate parity with bands enabled, synthesizer
gating/abstention, admission of synthesized answers, judged band-edge
feedback, metrics/wire surfacing, and LSH similarity coalescing (§12.3)
including the distinct-meaning-never-share-a-leader guarantee."""
import asyncio
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import SemanticCache
from repro.core.types import CacheConfig, LookupResult
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.embedding.lsh import SimHashLSH, cosine
from repro.generative import (BandPolicy, Neighbour, SmallModelRewrite,
                              SmallRewriteBackend, Synthesis, TemplateSplice)
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, SimulatedLLMBackend)
from repro.serving.scheduler import AsyncScheduler
from repro.tenancy import TenantRegistry, TenantSpec


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(80, seed=0)


@pytest.fixture(scope="module")
def queries(pairs):
    return build_test_queries(pairs, 50, paraphrase_ratio=0.8, seed=2)


def mk_judge(pairs):
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key
    return judge


def mk_engine(pairs, *, synthesizer=None, policy=None, use_fused_step=True,
              batch_size=8, threshold=0.8, **kw):
    cfg = CacheConfig(dim=384, capacity=2048, value_len=48, ttl=None,
                      threshold=threshold)
    backend = SimulatedLLMBackend(pairs)
    return CachedEngine(cfg, backend, judge=mk_judge(pairs),
                        batch_size=batch_size, synthesizer=synthesizer,
                        policy=policy, use_fused_step=use_fused_step,
                        **kw), backend


def requests_of(queries):
    return [Request(query=q.query, category=q.category,
                    source_id=q.source_id, semantic_key=q.semantic_key)
            for q in queries]


def peeked_result(scores, k=4):
    """Hand-built LookupResult so commit() sees exact score bit patterns."""
    b = len(scores)
    s = jnp.asarray(scores, dtype=jnp.float32)
    return LookupResult(
        index=jnp.zeros((b,), dtype=jnp.int32), score=s,
        hit=jnp.zeros((b,), dtype=bool),
        values=jnp.zeros((b, 8), dtype=jnp.int32),
        value_lens=jnp.zeros((b,), dtype=jnp.int32),
        source_id=jnp.full((b,), -1, dtype=jnp.int32),
        topk_index=jnp.full((b, k), -1, dtype=jnp.int32),
        topk_score=jnp.full((b, k), -jnp.inf, dtype=jnp.float32),
        near=jnp.zeros((b,), dtype=bool))


# --------------------------------------------------------------------- #
# band policy + edge semantics
# --------------------------------------------------------------------- #
class TestBandPolicy:
    def test_edges_closed_open(self):
        p = BandPolicy(tau_lo=0.7, tau_hi=0.8)
        st = p.init_state()
        lo = jnp.float32(0.7)
        hi = jnp.float32(0.8)
        scores = jnp.asarray([lo, hi, 0.75, 0.6, 0.9], dtype=jnp.float32)
        near = np.asarray(p.near(scores, st))
        hit = np.asarray(p.decide(scores, st)[0])
        # exactly τ_lo -> near; exactly τ_hi -> hit, never near
        assert near.tolist() == [True, False, True, False, False]
        assert hit.tolist() == [False, True, False, False, True]
        assert not (near & hit).any()

    def test_decide_matches_fixed_threshold(self):
        from repro.core.policy import FixedThreshold
        p = BandPolicy(tau_lo=0.7, tau_hi=0.8)
        f = FixedThreshold(threshold=0.8)
        scores = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 64),
                             dtype=jnp.float32)
        assert np.array_equal(
            np.asarray(p.decide(scores, p.init_state())[0]),
            np.asarray(f.decide(scores, f.init_state())[0]))

    def test_validation(self):
        with pytest.raises(ValueError):
            BandPolicy(tau_lo=0.9, tau_hi=0.8)
        with pytest.raises(ValueError):
            BandPolicy(tau_lo=0.7, tau_hi=1.5)
        with pytest.raises(ValueError):
            BandPolicy(tau_lo=0.6, lo_min=0.65)

    def test_update_band_feedback_direction(self):
        p = BandPolicy(tau_lo=0.7, tau_hi=0.8, lr=0.05, ema=0.5)
        st = p.init_state()
        bad = p.update_band(st,
                            was_positive=jnp.zeros((8,), dtype=bool),
                            was_near=jnp.ones((8,), dtype=bool))
        assert float(bad[0]) > float(st[0])      # poor precision -> shrink
        good = p.update_band(st,
                             was_positive=jnp.ones((8,), dtype=bool),
                             was_near=jnp.ones((8,), dtype=bool))
        assert float(good[0]) < float(st[0])     # surplus precision -> widen
        # no near evidence -> edge untouched
        none = p.update_band(st,
                             was_positive=jnp.zeros((8,), dtype=bool),
                             was_near=jnp.zeros((8,), dtype=bool))
        assert float(none[0]) == pytest.approx(float(st[0]))

    def test_update_band_clips(self):
        p = BandPolicy(tau_lo=0.7, tau_hi=0.8, lr=0.5, ema=0.0,
                       lo_min=0.55, min_width=0.01)
        st = p.init_state()
        for _ in range(50):
            st = p.update_band(st,
                               was_positive=jnp.zeros((8,), dtype=bool),
                               was_near=jnp.ones((8,), dtype=bool))
        assert float(st[0]) <= 0.8 - 0.01 + 1e-6     # never crosses τ_hi
        st = p.init_state()
        for _ in range(50):
            st = p.update_band(st,
                               was_positive=jnp.ones((8,), dtype=bool),
                               was_near=jnp.ones((8,), dtype=bool))
        assert float(st[0]) >= 0.55 - 1e-6           # floor


class TestCacheBandEdges:
    def test_commit_band_edges_exact(self):
        cache = SemanticCache(CacheConfig(dim=16, capacity=32, value_len=8,
                                          threshold=0.8),
                              policy=BandPolicy(tau_lo=0.7, tau_hi=0.8))
        rt = cache.init()
        scores = [jnp.float32(0.7), jnp.float32(0.8), 0.6999, 0.7999,
                  -np.inf]
        res, _ = cache.commit(rt, peeked_result(scores), 0.0)
        assert np.asarray(res.near).tolist() == \
            [True, False, False, True, False]
        assert np.asarray(res.hit).tolist() == \
            [False, True, False, False, False]

    def test_bandless_policy_near_all_false(self):
        cache = SemanticCache(CacheConfig(dim=16, capacity=32, value_len=8))
        rt = cache.init()
        res, _ = cache.commit(rt, peeked_result([0.75, 0.9, 0.1]), 0.0)
        assert not np.asarray(res.near).any()

    def test_tenant_band_lo_override(self):
        reg = TenantRegistry((TenantSpec(name="strict"),
                              TenantSpec(name="loose", band_lo=0.6)))
        part = reg.partition(64)
        cache = SemanticCache(CacheConfig(dim=16, capacity=64, value_len=8,
                                          threshold=0.8),
                              policy=BandPolicy(tau_lo=0.7, tau_hi=0.8),
                              partition=part)
        rt = cache.init()
        # same 0.65 score: in-band only for the tenant that lowered τ_lo
        tid = jnp.asarray([0, 1], dtype=jnp.int32)
        res, _ = cache.commit(rt, peeked_result([0.65, 0.65]), 0.0,
                              tenant_id=tid)
        assert np.asarray(res.near).tolist() == [False, True]
        # ... and the override is the lower edge, closed: exactly 0.6 is in
        res, _ = cache.commit(rt, peeked_result([0.6, 0.6]), 0.0,
                              tenant_id=tid)
        assert np.asarray(res.near).tolist() == [False, True]

    def test_tenant_tau_hi_override_moves_upper_edge(self):
        # a tenant with a stricter hit threshold keeps band rows up to it:
        # 0.85 is a hit for the default tenant but near for the strict one
        reg = TenantRegistry((TenantSpec(name="default"),
                              TenantSpec(name="strict", threshold=0.9)))
        part = reg.partition(64)
        cache = SemanticCache(CacheConfig(dim=16, capacity=64, value_len=8,
                                          threshold=0.8),
                              policy=BandPolicy(tau_lo=0.7, tau_hi=0.8),
                              partition=part)
        rt = cache.init()
        tid = jnp.asarray([0, 1], dtype=jnp.int32)
        res, _ = cache.commit(rt, peeked_result([0.85, 0.85]), 0.0,
                              tenant_id=tid)
        assert np.asarray(res.hit).tolist() == [True, False]
        # strict tenant's 0.85 is not near under the global band ([0.7,0.8))
        # unless it also lowers band_lo to keep a band below its τ_hi
        reg2 = TenantRegistry((TenantSpec(name="default"),
                               TenantSpec(name="strict", threshold=0.9,
                                          band_lo=0.7)))
        cache2 = dataclasses.replace(cache, partition=reg2.partition(64))
        res2, _ = cache2.commit(cache2.init(), peeked_result([0.85, 0.85]),
                                0.0, tenant_id=tid)
        assert np.asarray(res2.near).tolist() == [False, True]

    def test_manifest_band_compat(self):
        plain = TenantRegistry.uniform(("a", "b")).partition(64)
        assert "band_lo" not in plain.manifest()   # old checkpoints verify
        banded = TenantRegistry(
            (TenantSpec(name="a"), TenantSpec(name="b", band_lo=0.6))
        ).partition(64)
        assert banded.manifest()["band_lo"] == [-1.0, 0.6]

    def test_tenant_spec_band_validation(self):
        with pytest.raises(ValueError):
            TenantSpec(name="x", band_lo=1.5)
        with pytest.raises(ValueError):
            TenantSpec(name="x", threshold=0.8, band_lo=0.9)


# --------------------------------------------------------------------- #
# synthesizers
# --------------------------------------------------------------------- #
class TestSynthesizers:
    def nb(self, slot, score, sid, answer="cached answer"):
        return Neighbour(slot=slot, score=score, source_id=sid,
                         answer=answer)

    def test_splice_serves_dominant(self):
        syn = TemplateSplice(rival_margin=0.1).synthesize(
            "q", [self.nb(0, 0.78, 7, "seven"), self.nb(1, 0.60, 9)])
        assert syn is not None and syn.answer == "seven" \
            and syn.source_id == 7 and syn.cost_usd == 0.0

    def test_splice_abstains_on_rival(self):
        # different-provenance rival within the margin -> ambiguous
        assert TemplateSplice(rival_margin=0.1).synthesize(
            "q", [self.nb(0, 0.78, 7), self.nb(1, 0.72, 9)]) is None

    def test_splice_same_provenance_not_rival(self):
        syn = TemplateSplice(rival_margin=0.1).synthesize(
            "q", [self.nb(0, 0.78, 7, "a"), self.nb(1, 0.77, 7, "b")])
        assert syn is not None and syn.source_id == 7

    def test_splice_unknown_provenance_is_rival(self):
        assert TemplateSplice(rival_margin=0.1).synthesize(
            "q", [self.nb(0, 0.78, -1), self.nb(1, 0.77, -1)]) is None

    def test_splice_empty_neighbours(self):
        assert TemplateSplice().synthesize("q", []) is None

    def test_small_model_rewrite_charges_fractional_cost(self):
        be = SmallRewriteBackend(latency_per_call_s=0.08,
                                 cost_per_call_usd=0.0002)
        rw = SmallModelRewrite(backend=be)
        syn = rw.synthesize("q", [self.nb(0, 0.78, 7, "the answer")])
        assert syn is not None and syn.answer == "the answer"
        assert syn.source_id == 7
        assert syn.cost_usd == pytest.approx(0.0002)
        assert be.calls == 1
        # abstention never touches the rewrite backend
        assert rw.synthesize("q", [self.nb(0, 0.78, 7),
                                   self.nb(1, 0.76, 9)]) is None
        assert be.calls == 1


# --------------------------------------------------------------------- #
# engine integration
# --------------------------------------------------------------------- #
class TestEngineNearHits:
    def test_near_hits_reduce_backend_calls(self, pairs, queries):
        eng, be = mk_engine(pairs, synthesizer=TemplateSplice(),
                            policy=BandPolicy(tau_lo=0.75, tau_hi=0.8))
        eng.warm(pairs)
        resps = eng.process(requests_of(queries))
        base_eng, base_be = mk_engine(pairs)
        base_eng.warm(pairs)
        base_resps = base_eng.process(requests_of(queries))
        assert sum(r.near_hit for r in resps) > 0
        assert be.calls < base_be.calls          # strictly beyond exact reuse
        s = eng.metrics.summary()["near"]
        assert s["near_hits_served"] > 0
        assert s["near_precision"] > 0.9
        # exact-reuse rows are untouched by the band machinery
        for r, b in zip(resps, base_resps):
            if b.cached:
                assert r.cached and r.answer == b.answer \
                    and r.score == b.score

    def test_bands_disabled_byte_identical(self, pairs, queries):
        eng, _ = mk_engine(pairs)               # no synthesizer
        eng.warm(pairs)
        resps = eng.process(requests_of(queries))
        assert all(not r.near_hit for r in resps)
        assert eng.metrics.summary()["near"] == {}

    def test_fused_vs_separate_parity_with_bands(self, pairs, queries):
        eng_f, _ = mk_engine(pairs, synthesizer=TemplateSplice())
        eng_s, _ = mk_engine(pairs, synthesizer=TemplateSplice(),
                             use_fused_step=False)
        eng_f.warm(pairs)
        eng_s.warm(pairs)
        rf = eng_f.process(requests_of(queries))
        rs = eng_s.process(requests_of(queries))
        for a, b in zip(rf, rs):
            assert (a.answer, a.cached, a.near_hit) == \
                (b.answer, b.cached, b.near_hit)
        assert np.array_equal(np.asarray(eng_f.state.keys),
                              np.asarray(eng_s.state.keys))
        assert np.array_equal(np.asarray(eng_f.state.values),
                              np.asarray(eng_s.state.values))
        assert np.array_equal(np.asarray(eng_f.state.source_id),
                              np.asarray(eng_s.state.source_id))

    def test_empty_slab_near_request(self, pairs):
        calls = []

        class Spy:
            def synthesize(self, query, neighbours):
                calls.append((query, neighbours))
                return None

        eng, be = mk_engine(pairs, synthesizer=Spy())
        # one batch of distinct questions against a cold slab
        resps = eng.process([Request(query=p.question,
                                     source_id=p.qa_id,
                                     semantic_key=p.semantic_key)
                             for p in pairs[:6]])
        # empty slab: every score is -inf, no row is in the band, the
        # synthesizer is never consulted, every row pays the backend
        assert not calls
        assert all(not r.near_hit and not r.cached for r in resps)
        assert be.calls == len(resps)

    def test_synthesized_answer_admitted_under_own_key(self, pairs, queries):
        eng, be = mk_engine(pairs, synthesizer=TemplateSplice())
        eng.warm(pairs)
        resps = eng.process(requests_of(queries))
        near_i = next(i for i, r in enumerate(resps) if r.near_hit)
        calls_before = be.calls
        again = eng.process([requests_of(queries)[near_i]])
        # the synthesized answer is now a first-class entry: the repeat is
        # an exact hit serving the same bytes, with no backend call
        assert again[0].cached and not again[0].near_hit
        assert again[0].answer == resps[near_i].answer
        assert be.calls == calls_before

    def test_near_hit_judged_with_synthesis_provenance(self, pairs, queries):
        eng, _ = mk_engine(pairs, synthesizer=TemplateSplice())
        eng.warm(pairs)
        eng.process(requests_of(queries))
        near = eng.metrics.near
        assert near.judged == near.served        # judge saw every near-hit
        assert near.band >= near.served

    def test_default_policy_band_rides_config_threshold(self, pairs):
        eng, _ = mk_engine(pairs, synthesizer=TemplateSplice(),
                           threshold=0.85)
        assert isinstance(eng.cache.policy, BandPolicy)
        assert eng.cache.policy.tau_hi == pytest.approx(0.85)

    def test_band_edge_adapts_from_judged_outcomes(self, pairs):
        # a judge that rejects every synthesis must shrink the band
        eng, _ = mk_engine(pairs, synthesizer=TemplateSplice(
            rival_margin=0.0))
        eng.judge = lambda req, sid: False
        eng.warm(pairs)
        lo0 = float(eng.policy_state[0])
        eng.process(requests_of(
            build_test_queries(pairs, 50, paraphrase_ratio=0.9, seed=5)))
        assert eng.metrics.near.served > 0
        assert float(eng.policy_state[0]) > lo0


# --------------------------------------------------------------------- #
# wire + server
# --------------------------------------------------------------------- #
class TestWire:
    def _roundtrip(self, engine, lines):
        async def run():
            server = AsyncCacheServer(engine, SchedulerConfig(
                max_batch=8, max_wait_ms=5.0))
            async with server:
                port = await server.serve_tcp()
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", port)
                for obj in lines:
                    writer.write(json.dumps(obj).encode() + b"\n")
                await writer.drain()
                out = [json.loads(await reader.readline())
                       for _ in lines]
                writer.close()
                return out
        return asyncio.run(run())

    def test_near_hit_flag_additive(self, pairs):
        eng, _ = mk_engine(pairs, synthesizer=TemplateSplice())
        eng.warm(pairs)
        [resp] = self._roundtrip(eng, [{"id": 1, "query":
                                        pairs[0].question}])
        assert "near_hit" in resp
        plain, _ = mk_engine(pairs)
        plain.warm(pairs)
        [resp] = self._roundtrip(plain, [{"id": 1, "query":
                                          pairs[0].question}])
        assert "near_hit" not in resp           # band-less payload unchanged


# --------------------------------------------------------------------- #
# LSH similarity coalescing (§12.3 seam)
# --------------------------------------------------------------------- #
class TestSimilarityCoalescing:
    def test_lsh_deterministic_and_near_duplicates_collide(self):
        lsh = SimHashLSH(384)
        from repro.embedding import HashEmbedder
        emb = HashEmbedder(dim=384)
        a = emb.embed("how do I reset my password please")
        b = emb.embed("how do I reset my password, please")
        assert lsh.buckets(a) == lsh.buckets(a)      # deterministic
        assert cosine(a, b) > 0.9
        assert any(x == y for x, y in zip(lsh.buckets(a), lsh.buckets(b)))

    def test_verification_rejects_forced_collision(self, pairs,
                                                   monkeypatch):
        # even if every query hashed to one bucket, the exact cosine check
        # must keep distinct-meaning queries from sharing a leader
        eng, _ = mk_engine(pairs)
        sched = AsyncScheduler(eng, SchedulerConfig(coalesce_sim=0.9))
        monkeypatch.setattr(
            SimHashLSH, "buckets",
            lambda self, v: tuple(0 for _ in range(self.n_tables)))
        q1 = Request(query="how do I cancel my subscription")
        q2 = Request(query="what is the weather like in antarctica")
        e1 = np.asarray(eng.embedder.embed(q1.query), dtype=np.float32)
        e2 = np.asarray(eng.embedder.embed(q2.query), dtype=np.float32)
        from repro.serving.scheduler import coalesce_key
        k1 = coalesce_key(q1)
        sched._pending[k1] = []
        sched._register_leader(q1, k1, e1)
        assert sched._similar_leader(q2, e2) is None       # verified out
        # a true paraphrase passes the same gate
        q3 = Request(query="how do i cancel my subscription ?")
        e3 = np.asarray(eng.embedder.embed(q3.query), dtype=np.float32)
        assert cosine(e1, e3) >= 0.9
        assert sched._similar_leader(q3, e3) == k1

    def test_distinct_meaning_never_share_leader_end_to_end(self, pairs):
        eng, be = mk_engine(pairs, batch_size=8)

        async def run():
            server = AsyncCacheServer(eng, SchedulerConfig(
                max_batch=8, max_wait_ms=100.0, coalesce_sim=0.9))
            async with server:
                return await asyncio.gather(
                    server.submit("how do I reset my password please"),
                    server.submit("how do I reset my password, please"),
                    server.submit("what is the airspeed of a swallow"),
                    server.submit("my invoice seems wrong, who do I ask"),
                )
        r = asyncio.run(run())
        # the paraphrase coalesced onto its leader; the distinct-meaning
        # queries each paid their own way
        assert r[1].coalesced and r[1].answer == r[0].answer
        assert not r[2].coalesced and not r[3].coalesced
        assert be.calls == 3

    def test_coalesce_sim_none_is_text_equality_only(self, pairs):
        eng, be = mk_engine(pairs, batch_size=8)

        async def run():
            server = AsyncCacheServer(eng, SchedulerConfig(
                max_batch=8, max_wait_ms=100.0))
            async with server:
                return await asyncio.gather(
                    server.submit("how do I reset my password please"),
                    server.submit("how do I reset my password, please"),
                )
        r = asyncio.run(run())
        assert not r[0].coalesced and not r[1].coalesced
        assert be.calls == 2

    def test_tenant_scoped_buckets(self, pairs):
        from repro.serving.scheduler import coalesce_key
        eng, _ = mk_engine(pairs)
        sched = AsyncScheduler(eng, SchedulerConfig(coalesce_sim=0.9))
        qa = Request(query="reset my password", tenant="acme")
        qb = Request(query="reset my password", tenant="globex")
        ea = np.asarray(eng.embedder.embed(qa.query), dtype=np.float32)
        ka = coalesce_key(qa)
        sched._pending[ka] = []
        sched._register_leader(qa, ka, ea)
        # identical embedding, different tenant scope -> no candidate
        assert sched._similar_leader(qb, ea) is None

    def test_unregister_cleans_buckets(self, pairs):
        from repro.serving.scheduler import coalesce_key
        eng, _ = mk_engine(pairs)
        sched = AsyncScheduler(eng, SchedulerConfig(coalesce_sim=0.9))
        q = Request(query="reset my password")
        e = np.asarray(eng.embedder.embed(q.query), dtype=np.float32)
        k = coalesce_key(q)
        sched._pending[k] = []
        sched._register_leader(q, k, e)
        assert sched._sim_buckets
        sched._unregister_leader(k)
        assert not sched._sim_buckets and not sched._leader_emb \
            and not sched._leader_buckets
