"""Observability subsystem tests (DESIGN.md §18): request tracing with
retention sampling, decision attribution (``why`` records + ``explain``),
the metrics export plane (event ring, Prometheus exposition, /metrics
endpoint), the bounded latency reservoirs, and the additive wire
discipline on the TCP front-end."""
import asyncio
import json
import time

import numpy as np
import pytest

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.generative import BandPolicy, TemplateSplice
from repro.obs import (NULL_TRACE, REQUIRED_FAMILIES, STAGES, EventLog,
                       MetricsExporter, RequestTrace, StageClock,
                       TraceConfig, Tracer, effective_edges,
                       prometheus_text)
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, SimulatedLLMBackend)
from repro.serving.metrics import (LATENCY_BUCKETS_S, LatencyReservoir,
                                   NearHitMetrics, ServingMetrics,
                                   percentiles)
from repro.tenancy import TenantRegistry, TenantSpec


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(80, seed=0)


def make_engine(pairs, *, batch_size=8, latency_s=0.0, **kw):
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    cfg = kw.pop("config", CacheConfig(dim=384, capacity=4096, value_len=48,
                                       ttl=None, threshold=0.8))
    backend = SimulatedLLMBackend(pairs, latency_per_call_s=latency_s)
    return CachedEngine(cfg, backend, judge=judge,
                        batch_size=batch_size, **kw)


def collect_all() -> Tracer:
    return Tracer(TraceConfig(sample_rate=1.0, head=0))


def finished_trace(tracer, e2e_s=0.0):
    t = tracer.start()
    tracer.finish(t, e2e_s=e2e_s)
    return t


# --------------------------------------------------------------------- #
# tracer: retention sampling (§18.2)
# --------------------------------------------------------------------- #
class TestTracerRetention:
    def test_head_always_retained(self):
        tr = Tracer(TraceConfig(sample_rate=0.0, head=3))
        kept = [finished_trace(tr) for _ in range(10)]
        assert tr.started == tr.finished == 10
        assert tr.retained == 3
        assert [t.trace_id for t in tr.traces()] == \
            [t.trace_id for t in kept[:3]]

    def test_rate_sampling_is_deterministic(self):
        tr = Tracer(TraceConfig(sample_rate=0.25, head=0, max_traces=1024))
        for _ in range(100):
            finished_trace(tr)
        # counter-accumulator, no RNG: exactly one in four, every run
        assert tr.retained == 25
        tr2 = Tracer(TraceConfig(sample_rate=0.25, head=0, max_traces=1024))
        for _ in range(100):
            finished_trace(tr2)
        assert [t.trace_id for t in tr2.traces()] == \
            [t.trace_id for t in tr.traces()]

    def test_slow_outliers_kept_despite_zero_rate(self):
        tr = Tracer(TraceConfig(sample_rate=0.0, head=0,
                                slow_threshold_s=0.5))
        finished_trace(tr, e2e_s=0.01)
        slow = finished_trace(tr, e2e_s=0.75)
        finished_trace(tr, e2e_s=0.1)
        assert tr.retained == 1
        assert tr.traces()[0].trace_id == slow.trace_id

    def test_ring_keeps_most_recent(self):
        tr = Tracer(TraceConfig(sample_rate=1.0, head=0, max_traces=4))
        kept = [finished_trace(tr) for _ in range(10)]
        assert tr.retained == 10            # retention counter is total ...
        assert [t.trace_id for t in tr.traces()] == \
            [t.trace_id for t in kept[-4:]]  # ... ring holds the tail

    def test_off_allocates_nothing(self):
        tr = Tracer(TraceConfig.off())
        assert not tr.collecting
        t = tr.start()
        assert t is NULL_TRACE and not t
        assert t.trace_id == ""
        t.add("embed", 0.0, 1.0)            # all hooks are no-ops
        t.annotate(row=3)
        assert t.spans == [] and t.meta == {}
        assert tr.stage_clock() is None
        tr.finish(t, e2e_s=1.0)
        assert tr.started == tr.finished == tr.retained == 0

    def test_drain_clears_ring(self):
        tr = collect_all()
        finished_trace(tr)
        finished_trace(tr)
        out = tr.drain()
        assert len(out) == 2 and all("trace_id" in d for d in out)
        assert tr.traces() == [] and tr.drain() == []

    def test_config_validation(self):
        with pytest.raises(ValueError):
            TraceConfig(sample_rate=1.5)
        with pytest.raises(ValueError):
            TraceConfig(head=-1)
        with pytest.raises(ValueError):
            TraceConfig(max_traces=0)
        with pytest.raises(ValueError):
            TraceConfig(slow_threshold_s=-0.1)

    def test_stage_decomposition_orders_canonically(self):
        tr = collect_all()
        t = tr.start()
        t.add("respond", 0.0, 0.1)
        t.add("embed", 0.1, 0.3)
        t.add("zz_custom", 0.3, 0.4)
        tr.finish(t)
        d = tr.stage_decomposition()
        assert list(d) == ["embed", "respond", "zz_custom"]
        assert d["embed"]["count"] == 1
        assert d["embed"]["p50_s"] == pytest.approx(0.2, abs=1e-6)
        assert d["embed"]["total_s"] == pytest.approx(0.2, abs=1e-6)


class TestStageClockAndTrace:
    def test_clock_spans_are_contiguous(self):
        clock = StageClock()
        for name in ("embed", "device_step", "respond"):
            time.sleep(0.001)
            clock.tick(name)
        spans = clock.spans
        assert [s.name for s in spans] == ["embed", "device_step", "respond"]
        for a, b in zip(spans, spans[1:]):
            assert a.t1 == b.t0            # no gaps, no overlaps
        assert all(s.duration_s > 0 for s in spans)

    def test_trace_round_trip(self):
        t = RequestTrace("rt-test")
        t.add("embed", 1.0, 1.5)
        t.add("embed", 2.0, 2.25)
        t.add("respond", 3.0, 3.1)
        t.annotate(path="hit", row=0)
        t.e2e_s = 0.85
        assert t.span_sum_s == pytest.approx(0.85)
        assert t.stage_seconds() == pytest.approx(
            {"embed": 0.75, "respond": 0.1})
        d = t.to_dict()
        assert d["trace_id"] == "rt-test"
        assert d["e2e_s"] == pytest.approx(0.85)
        assert d["meta"] == {"path": "hit", "row": 0}
        assert [s["name"] for s in d["spans"]] == \
            ["embed", "embed", "respond"]
        json.dumps(d)                      # JSON-able for /traces


# --------------------------------------------------------------------- #
# engine integration: sync serve path (§18.1)
# --------------------------------------------------------------------- #
class TestEngineTracing:
    def test_sync_process_traces_every_row(self, pairs):
        eng = make_engine(pairs, tracer=collect_all())
        eng.warm(pairs[:20])
        reqs = [Request(query=pairs[i].question, category=pairs[i].category,
                        source_id=pairs[i].qa_id,
                        semantic_key=pairs[i].semantic_key)
                for i in range(6)]
        eng.process(reqs)
        assert eng.tracer.retained == len(reqs)
        for t in eng.tracer.traces():
            assert set(s.name for s in t.spans) <= set(STAGES)
            assert t.meta["path"] in ("hit", "near", "miss")
            assert t.e2e_s is not None and t.e2e_s > 0
            # contiguous engine spans tile the batch wall time: the span
            # sum reconstructs the measured e2e (the serve-bench invariant)
            assert t.span_sum_s == pytest.approx(t.e2e_s, rel=0.10)
        decomp = eng.tracer.stage_decomposition()
        assert {"embed", "device_step", "respond"} <= set(decomp)

    def test_tracing_off_by_default_and_allocation_free(self, pairs):
        eng = make_engine(pairs)               # no tracer argument
        assert not eng.tracer.collecting
        eng.process([Request(query="off-path probe")])
        assert eng.tracer.started == 0
        assert eng.tracer.finished == 0
        assert eng.tracer.traces() == []


# --------------------------------------------------------------------- #
# decision attribution (§18.3)
# --------------------------------------------------------------------- #
class TestExplain:
    def test_explain_hit_record(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs[:20])
        lookups0 = int(eng.stats.lookups)
        why = eng.explain(pairs[0].question)
        assert why["decision"] == "hit"
        assert why["dry_run"] is True
        assert why["effective_threshold"] == pytest.approx(0.8)
        assert why["threshold_source"] == "policy"
        assert why["band"] is None             # band-less policy
        assert why["score"] >= why["effective_threshold"]
        assert why["matched_source_id"] == pairs[0].qa_id
        assert why["topk"], "top-k neighbours must be attributed"
        assert why["topk"][0]["score"] == pytest.approx(why["score"])
        assert all(t["slot"] >= 0 for t in why["topk"])
        # pure peek: no counters moved, nothing inserted
        assert int(eng.stats.lookups) == lookups0

    def test_explain_miss_record(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs[:20])
        why = eng.explain("entirely unrelated question about submarines")
        assert why["decision"] == "miss"
        assert why["score"] < why["effective_threshold"]

    def test_tenant_threshold_override_attributed(self, pairs):
        registry = TenantRegistry((TenantSpec(name="acme", threshold=0.95),
                                   TenantSpec(name="globex")))
        eng = make_engine(pairs, registry=registry,
                          config=CacheConfig(dim=384, capacity=4096,
                                             value_len=48, ttl=None,
                                             threshold=0.8))
        eng.warm(pairs[:10], tenant="acme")
        why = eng.explain(pairs[0].question, tenant="acme")
        assert why["threshold_source"] == "tenant"
        assert why["effective_threshold"] == pytest.approx(0.95)
        assert why["tenant"] == "acme"
        why_g = eng.explain(pairs[0].question, tenant="globex")
        assert why_g["threshold_source"] == "policy"
        assert why_g["effective_threshold"] == pytest.approx(0.8)

    def test_band_edges_attributed(self, pairs):
        eng = make_engine(pairs, policy=BandPolicy(tau_lo=0.7, tau_hi=0.8),
                          synthesizer=TemplateSplice())
        eng.warm(pairs[:10])
        why = eng.explain(pairs[0].question)
        assert why["band"] == {"lo": pytest.approx(0.7),
                               "hi": pytest.approx(0.8),
                               "lo_source": "policy"}

    def test_effective_edges_tenant_band_lo(self, pairs):
        registry = TenantRegistry(
            (TenantSpec(name="acme", threshold=0.9, band_lo=0.8),
             TenantSpec(name="globex")))
        policy = BandPolicy(tau_lo=0.7, tau_hi=0.85)
        partition = registry.partition(1024)
        edges = effective_edges(policy, policy.init_state(), partition, 0)
        assert edges == {"threshold": pytest.approx(0.9),
                         "threshold_source": "tenant",
                         "band": {"lo": pytest.approx(0.8),
                                  "hi": pytest.approx(0.9),
                                  "lo_source": "tenant"}}
        edges_g = effective_edges(policy, policy.init_state(), partition, 1)
        assert edges_g["threshold_source"] == "policy"
        assert edges_g["band"]["lo_source"] == "policy"

    def test_request_explain_opt_in_per_row(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs[:20])
        reqs = [Request(query=pairs[0].question, explain=True),
                Request(query=pairs[1].question)]
        r_opt, r_plain = eng.process(reqs)
        assert r_opt.why is not None
        assert r_opt.why["decision"] == "hit"
        assert r_opt.why["session_fused"] is False
        assert r_plain.why is None and r_plain.trace_id == ""

    def test_explain_responses_forces_every_row(self, pairs):
        eng = make_engine(pairs, explain_responses=True)
        eng.warm(pairs[:20])
        rs = eng.process([Request(query=pairs[0].question),
                          Request(query="novel submarine question")])
        assert rs[0].why["decision"] == "hit"
        assert rs[1].why["decision"] == "miss"


# --------------------------------------------------------------------- #
# event ring + Prometheus exposition (§18.4)
# --------------------------------------------------------------------- #
class TestEventLog:
    def test_bounded_ring_with_total_count(self):
        log = EventLog(capacity=4)
        for i in range(10):
            log.emit("step", n=i)
        assert len(log) == 4
        assert log.emitted == 10
        assert [e["n"] for e in log.events()] == [6, 7, 8, 9]
        assert [e["seq"] for e in log.events()] == [6, 7, 8, 9]

    def test_jsonl_and_drain(self):
        log = EventLog(capacity=8)
        log.emit("a", x=1)
        log.emit("b", y="two")
        lines = log.to_jsonl().splitlines()
        assert [json.loads(ln)["kind"] for ln in lines] == ["a", "b"]
        drained = log.drain()
        assert len(drained) == 2 and len(log) == 0
        assert log.to_jsonl() == ""

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            EventLog(capacity=0)

    def test_engine_emits_serve_events_with_stats_delta(self, pairs):
        eng = make_engine(pairs, events=EventLog(capacity=16))
        eng.warm(pairs[:10])
        eng.process([Request(query=pairs[0].question),
                     Request(query="a brand new submarine question")])
        evs = [e for e in eng.events.events() if e["kind"] == "serve_batch"]
        assert evs, "serve_batch events must be emitted"
        ev = evs[-1]
        assert ev["rows"] == 2
        assert ev["hits"] == 1 and ev["backend_calls"] == 1
        assert ev["stats_delta"]["lookups"] == 2
        assert ev["stats_delta"]["inserts"] == 1


def scrape_families(text: str) -> set:
    fams = set()
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            fams.add(line.split()[2])
    return fams


def histogram_rows(text: str, family: str) -> dict:
    """path-label -> [(le, cumulative_count)] parsed off the exposition."""
    out: dict[str, list] = {}
    for line in text.splitlines():
        if not line.startswith(family + "_bucket{"):
            continue
        labels = line[line.index("{") + 1:line.index("}")]
        kv = dict(p.split("=", 1) for p in labels.split(","))
        path = kv.get("path", "").strip('"')
        le = kv["le"].strip('"')
        val = float(line.rsplit(" ", 1)[1])
        out.setdefault(path, []).append(
            (float("inf") if le == "+Inf" else float(le), val))
    return out


class TestPrometheusExposition:
    def test_required_families_always_present(self):
        # even a freshly-constructed stack (no traffic, no cache stats)
        # emits every contractual family — scrapers must never see a
        # family appear/disappear between scrapes
        text = prometheus_text(ServingMetrics())
        fams = scrape_families(text)
        missing = [f for f in REQUIRED_FAMILIES if f not in fams]
        assert not missing, missing

    def test_engine_scrape_histogram_invariants(self, pairs):
        eng = make_engine(pairs, tracer=collect_all())
        eng.warm(pairs[:10])
        eng.process([Request(query=pairs[i].question) for i in range(4)])
        text = MetricsExporter(eng).render()
        assert "# TYPE repro_latency_seconds histogram" in text
        hist = histogram_rows(text, "repro_latency_seconds")
        assert "hit" in hist
        for path, rows in hist.items():
            les = [le for le, _ in rows]
            counts = [c for _, c in rows]
            assert les == sorted(les) and les[-1] == float("inf")
            assert counts == sorted(counts), "buckets must be cumulative"
            # the +Inf bucket equals the series _count
            count_line = [ln for ln in text.splitlines()
                          if ln.startswith("repro_latency_seconds_count")
                          and f'path="{path}"' in ln]
            assert float(count_line[0].rsplit(" ", 1)[1]) == counts[-1]
        # device plane + trace plane ride along on a live engine
        assert "repro_slab_hits_total" in text
        assert "repro_trace_stage_seconds" in text
        assert 'stage="device_step"' in text

    def test_per_tenant_labels(self, pairs):
        registry = TenantRegistry.uniform(["acme", "globex"])
        eng = make_engine(pairs, registry=registry)
        eng.warm(pairs[:10], tenant="acme")
        eng.warm(pairs[:10], tenant="globex")
        eng.process([Request(query=pairs[0].question, tenant="acme"),
                     Request(query=pairs[1].question, tenant="globex")])
        eng.metrics.record_latency("hit", 0.002, tenant="acme")
        text = MetricsExporter(eng).render()
        assert 'repro_tenant_lookups_total{tenant="acme"}' in text
        assert 'repro_tenant_lookups_total{tenant="globex"}' in text
        assert 'repro_tenant_slab_inserts_total{tenant="acme"}' in text
        assert 'tenant="acme",path="hit",quantile="0.5"' in text

    def test_label_escaping(self):
        m = ServingMetrics()
        m.record_batch(['weird"cat\n'], [0], [0], judged=None,
                       cache_time_s=0.0, llm_time_s=0.0, llm_cost=0.0,
                       baseline_cost=0.0, baseline_time=0.0)
        text = prometheus_text(m)
        assert 'category="weird\\"cat\\n"' in text


# --------------------------------------------------------------------- #
# bounded latency reservoirs (§18.5, satellite: no unbounded buffers)
# --------------------------------------------------------------------- #
class TestLatencyReservoir:
    def test_memory_stays_bounded_under_sustained_load(self):
        res = LatencyReservoir(cap=64)
        n = 10_000
        for i in range(n):
            res.add(i / n)
        assert len(res) == 64, "reservoir must not grow past cap"
        assert res.count == n                 # exact scalars keep counting
        assert res.total_s == pytest.approx(sum(i / n for i in range(n)))
        assert res.summary()["count"] == n    # true stream length reported
        assert sum(c for _, c in res.bucket_rows()) == n

    def test_small_stream_is_exact(self):
        res = LatencyReservoir(cap=2048)
        xs = [0.001 * i for i in range(1, 101)]
        for x in xs:
            res.add(x)
        assert res.summary() == {**percentiles(xs), "count": 100}

    def test_reservoir_percentiles_track_distribution(self):
        res = LatencyReservoir(cap=256, seed=7)
        for i in range(20_000):
            res.add((i % 1000) / 1000.0)      # uniform on [0, 1)
        s = res.summary()
        assert abs(s["p50_s"] - 0.5) < 0.15   # statistical, seeded -> stable
        assert s["p95_s"] > s["p50_s"]

    def test_bucket_rows_shape(self):
        res = LatencyReservoir()
        res.add(0.0001)                        # first bucket
        res.add(100.0)                         # +Inf bucket
        rows = res.bucket_rows()
        assert len(rows) == len(LATENCY_BUCKETS_S) + 1
        assert rows[0] == (LATENCY_BUCKETS_S[0], 1)
        assert rows[-1] == (float("inf"), 1)

    def test_cap_validation(self):
        with pytest.raises(ValueError):
            LatencyReservoir(cap=0)

    def test_serving_metrics_buffers_are_bounded(self):
        # regression: record_latency used to append to an unbounded list
        m = ServingMetrics()
        for i in range(5000):
            m.record_latency("hit", 0.001, tenant="acme")
        res = m.latency_samples["hit"]
        assert isinstance(res, LatencyReservoir)
        assert len(res) <= res.cap < 5000
        t_res = m.per_tenant["acme"].latency_samples["hit"]
        assert len(t_res) <= t_res.cap < 5000
        assert m.summary()["latency_percentiles"]["hit"]["count"] == 5000


# --------------------------------------------------------------------- #
# summary() edge cases (satellite: zero-division / empty-path hygiene)
# --------------------------------------------------------------------- #
class TestSummaryEdgeCases:
    def test_fresh_metrics_summary_is_all_zeros(self):
        s = ServingMetrics().summary()
        assert s["queries"] == 0
        assert s["categories"] == {} and s["tenants"] == {}
        assert s["context"] == {} and s["near"] == {}
        assert s["latency_percentiles"] == {}
        assert s["avg_latency_with_cache_s"] == 0.0
        assert s["avg_latency_without_cache_s"] == 0.0

    def test_zero_sample_percentiles(self):
        assert percentiles([]) == {"count": 0, "p50_s": 0.0,
                                   "p95_s": 0.0, "p99_s": 0.0}
        assert LatencyReservoir().summary()["count"] == 0

    def test_unknown_path_names_open_fresh_reservoirs(self):
        m = ServingMetrics()
        m.record_latency("some_future_path", 0.01)
        row = m.summary()["latency_percentiles"]["some_future_path"]
        assert row["count"] == 1 and row["p50_s"] == pytest.approx(0.01)

    def test_tenant_with_only_coalesced_traffic_no_zero_division(self):
        m = ServingMetrics()
        m.record_coalesced(3, tenant="idle")
        row = m.summary()["tenants"]["idle"]
        assert row["lookups"] == 0 and row["hit_rate"] == 0.0
        assert row["coalesced_calls"] == 3

    def test_near_metrics_judged_zero_precision(self):
        nm = NearHitMetrics(band=5, served=2, judged=0)
        assert nm.precision == 0.0
        assert nm.row()["near_precision"] == 0.0
        # via the full record_batch path: band rows but nothing judged
        m = ServingMetrics()
        m.record_batch(["c"], [0], [0], judged=[0], cache_time_s=0.0,
                       llm_time_s=0.0, llm_cost=0.0, baseline_cost=0.0,
                       baseline_time=0.0, nears=[1], near_served=[0])
        assert m.summary()["near"]["near_precision"] == 0.0
        assert m.summary()["near"]["band_lookups"] == 1


# --------------------------------------------------------------------- #
# wire discipline: additive observability keys (§18 + server docstring)
# --------------------------------------------------------------------- #
class TestWireDiscipline:
    BASE_KEYS = {"answer", "cached", "score", "latency_s", "coalesced",
                 "id"}

    def run_client(self, eng, lines):
        async def client():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                try:
                    port = await server.serve_tcp("127.0.0.1", 0)
                except OSError as exc:       # sandboxed CI without sockets
                    pytest.skip(f"cannot bind loopback: {exc}")
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", port)
                for obj in lines:
                    writer.write(json.dumps(obj).encode() + b"\n")
                await writer.drain()
                out = [json.loads(await reader.readline())
                       for _ in range(len(lines))]
                writer.close()
                return out

        return asyncio.run(client())

    def test_non_opt_in_payload_is_byte_identical_shape(self, pairs):
        # the engine runs with tracing + events + attribution fully on;
        # a client that did not ask must still get exactly the
        # pre-observability payload keys — nothing rides along uninvited
        eng = make_engine(pairs, tracer=collect_all(),
                          events=EventLog(capacity=64))
        eng.warm(pairs[:10])
        out = self.run_client(eng, [
            {"id": 0, "query": pairs[0].question},
            {"id": 1, "query": pairs[1].question, "explain": False}])
        by_id = {o["id"]: o for o in out}
        for o in by_id.values():
            assert set(o) == self.BASE_KEYS
        # and the exact serialized line is reconstructible from those
        # keys alone: no observability value leaks into the bytes
        line = json.dumps(by_id[0])
        assert "why" not in line and "trace_id" not in line

    def test_explain_opt_in_rides_per_line(self, pairs):
        eng = make_engine(pairs, tracer=collect_all())
        eng.warm(pairs[:10])
        out = self.run_client(eng, [
            {"id": 0, "query": pairs[0].question, "explain": True},
            {"id": 1, "query": pairs[1].question}])
        by_id = {o["id"]: o for o in out}
        assert set(by_id[0]) == self.BASE_KEYS | {"why", "trace_id"}
        assert by_id[0]["why"]["decision"] == "hit"
        assert by_id[0]["trace_id"].startswith("rt-")
        assert set(by_id[1]) == self.BASE_KEYS

    def test_explain_without_tracer_has_empty_trace_id(self, pairs):
        eng = make_engine(pairs)              # tracing off
        eng.warm(pairs[:10])
        out = self.run_client(eng, [
            {"id": 0, "query": pairs[0].question, "explain": True}])
        assert out[0]["why"]["decision"] == "hit"
        assert out[0]["trace_id"] == ""


# --------------------------------------------------------------------- #
# /metrics endpoint (§18.4): dedicated listener + main-port GET sniff
# --------------------------------------------------------------------- #
async def http_get(port: int, path: str) -> tuple[str, str]:
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: x\r\n\r\n".encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, body = raw.decode().partition("\r\n\r\n")
    return head, body


class TestMetricsEndpoint:
    def test_dedicated_listener_serves_all_routes(self, pairs):
        eng = make_engine(pairs, tracer=collect_all(),
                          events=EventLog(capacity=64))
        eng.warm(pairs[:10])

        async def go():
            async with AsyncCacheServer(eng) as server:
                try:
                    port = await server.serve_metrics()
                except OSError as exc:
                    pytest.skip(f"cannot bind loopback: {exc}")
                await server.submit(pairs[0].question)
                return {
                    "metrics": await http_get(port, "/metrics"),
                    "traces": await http_get(port, "/traces"),
                    "events": await http_get(port, "/events"),
                    "missing": await http_get(port, "/nope"),
                }

        out = asyncio.run(go())
        head, body = out["metrics"]
        assert head.startswith("HTTP/1.1 200 OK")
        assert "text/plain; version=0.0.4" in head
        fams = scrape_families(body)
        assert all(f in fams for f in REQUIRED_FAMILIES)
        head, body = out["traces"]
        assert head.startswith("HTTP/1.1 200 OK")
        traces = [json.loads(ln) for ln in body.splitlines()]
        assert traces and all("spans" in t for t in traces)
        head, body = out["events"]
        assert head.startswith("HTTP/1.1 200 OK")
        assert any(json.loads(ln)["kind"] == "serve_batch"
                   for ln in body.splitlines())
        assert out["missing"][0].startswith("HTTP/1.1 404")

    def test_main_port_sniffs_http_scrape(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs[:10])

        async def go():
            async with AsyncCacheServer(eng) as server:
                try:
                    port = await server.serve_tcp("127.0.0.1", 0)
                except OSError as exc:
                    pytest.skip(f"cannot bind loopback: {exc}")
                # JSON-lines clients are unaffected ...
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", port)
                writer.write(json.dumps(
                    {"query": pairs[0].question}).encode() + b"\n")
                await writer.drain()
                resp = json.loads(await reader.readline())
                writer.close()
                # ... while a GET on the same port returns the exposition
                return resp, await http_get(port, "/metrics")

        resp, (head, body) = asyncio.run(go())
        assert resp["cached"] is True
        assert head.startswith("HTTP/1.1 200 OK")
        assert "repro_queries_total" in body
