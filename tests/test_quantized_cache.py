"""int8-slab cache (§Perf iteration 3.1): ranking and hit behaviour must
match the f32 slab within quantization tolerance."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CacheConfig, SemanticCache
from repro.data.qa_dataset import build_corpus, build_test_queries
from repro.embedding.hash_embedder import HashEmbedder


def test_int8_scores_close_to_f32():
    kq, kk = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (8, 64))
    emb = jax.random.normal(kk, (32, 64))
    vals = jnp.zeros((32, 4), jnp.int32)
    lens = jnp.full((32,), 4)

    res = {}
    for dtype in (jnp.float32, jnp.int8):
        c = SemanticCache(CacheConfig(dim=64, capacity=64, value_len=4,
                                      ttl=None, key_dtype=dtype))
        rt = c.init()
        rt = c.insert(rt, emb, vals, lens, 0.0)
        r, _ = c.lookup(rt, q, 1.0)
        res[str(dtype)] = (np.asarray(r.score), np.asarray(r.index))

    s32, i32 = res[str(jnp.float32)]
    s8, i8 = res[str(jnp.int8)]
    np.testing.assert_allclose(s8, s32, atol=0.01)     # ~0.4% quant error
    assert (i8 == i32).mean() >= 0.9                   # rankings preserved


def test_int8_hit_rate_parity_on_corpus():
    pairs = build_corpus(200, seed=0)
    queries = build_test_queries(pairs, n_per_category=40, seed=1)
    emb = HashEmbedder()
    e = jnp.asarray(emb.embed_batch([p.question for p in pairs]))
    q = jnp.asarray(emb.embed_batch([x.query for x in queries]))
    vals = jnp.zeros((len(pairs), 4), jnp.int32)
    lens = jnp.full((len(pairs),), 4)

    hits = {}
    for dtype in (jnp.float32, jnp.int8):
        c = SemanticCache(CacheConfig(dim=384, capacity=1024, value_len=4,
                                      ttl=None, key_dtype=dtype))
        rt = c.init()
        rt = c.insert(rt, e, vals, lens, 0.0)
        r, _ = c.lookup(rt, q, 1.0)
        hits[str(dtype)] = np.asarray(r.hit)

    h32 = hits[str(jnp.float32)]
    h8 = hits[str(jnp.int8)]
    # int8 may flip only borderline (score ~ threshold) decisions
    assert (h32 == h8).mean() >= 0.97, (h32.sum(), h8.sum())


def test_int8_memory_is_quarter():
    c8 = SemanticCache(CacheConfig(dim=384, capacity=256, value_len=4,
                                   key_dtype=jnp.int8))
    rt = c8.init()
    assert rt.state.keys.dtype == jnp.int8
    assert rt.state.keys.nbytes * 4 == 256 * 384 * 4
