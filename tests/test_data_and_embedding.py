"""Data substrate + embedders: tokenizer round-trips, corpus statistics,
paraphrase similarity structure, encoder contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.qa_dataset import (CATEGORIES, build_corpus,
                                   build_test_queries, paraphrase)
from repro.data.tokenizer import EOS_ID, HashTokenizer, PAD_ID
from repro.embedding import (MINILM_L6, HashEmbedder, encode,
                             init_encoder_params)

import random


class TestTokenizer:
    def test_roundtrip(self):
        tok = HashTokenizer()
        ids = tok.encode("how do I reverse a list in Python")
        assert tok.decode(ids) == "how do i reverse a list in python"

    def test_determinism(self):
        t1, t2 = HashTokenizer(), HashTokenizer()
        assert t1.encode("hello world cache") == t2.encode("hello world cache")

    def test_batch_padding(self):
        tok = HashTokenizer()
        out, lens = tok.encode_batch(["a b c", "a"], max_len=8)
        assert out.shape == (2, 8)
        assert out[1, int(lens[1]):].tolist() == [PAD_ID] * (8 - int(lens[1]))

    @settings(max_examples=30, deadline=None)
    @given(st.text(alphabet=st.characters(whitelist_categories=("Ll", "Nd"),
                                          max_codepoint=0x7f), max_size=40))
    def test_ids_in_range(self, text):
        tok = HashTokenizer(vocab_size=1024)
        for t in tok.encode(text):
            assert 0 <= t < 1024


class TestCorpus:
    def test_sizes_and_uniqueness(self):
        pairs = build_corpus(200, seed=0)
        assert len(pairs) == 800
        assert len({p.question for p in pairs}) == 800
        for c in CATEGORIES:
            assert sum(p.category == c for p in pairs) == 200

    def test_test_queries_mix(self):
        pairs = build_corpus(200, seed=0)
        qs = build_test_queries(pairs, n_per_category=50, seed=1)
        assert len(qs) == 200
        n_para = sum(q.source_id >= 0 for q in qs)
        assert 0.5 < n_para / len(qs) < 0.95     # paraphrase-dominated mix

    def test_paraphrase_changes_text(self):
        rng = random.Random(0)
        q = "how do i reverse a list in python"
        outs = {paraphrase(q, rng, 0.8) for _ in range(20)}
        assert any(o != q for o in outs)

    def test_determinism(self):
        a = build_corpus(50, seed=3)
        b = build_corpus(50, seed=3)
        assert [p.question for p in a] == [p.question for p in b]


class TestHashEmbedder:
    def test_unit_norm(self):
        e = HashEmbedder()
        v = e.embed("hello world")
        assert np.linalg.norm(v) == pytest.approx(1.0, abs=1e-5)

    def test_paraphrase_similarity_structure(self):
        """Paraphrases score well above unrelated queries — the property the
        cache depends on (DESIGN.md §9)."""
        e = HashEmbedder()
        rng = random.Random(0)
        base = "how do i track my package from last week"
        para = paraphrase(base, rng, 0.4)
        unrelated = "python code to flatten a numpy array"
        vb, vp, vu = e.embed(base), e.embed(para), e.embed(unrelated)
        assert float(vb @ vp) > 0.7
        assert float(vb @ vu) < 0.5
        assert float(vb @ vp) > float(vb @ vu) + 0.3

    def test_deterministic(self):
        assert np.allclose(HashEmbedder().embed("abc"),
                           HashEmbedder().embed("abc"))

    def test_dim(self):
        assert HashEmbedder(dim=512).embed("x").shape == (512,)


class TestEncoder:
    def test_output_contract(self):
        params = init_encoder_params(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 16), 0,
                                    MINILM_L6.vocab)
        lengths = jnp.asarray([16, 8, 1])
        emb = encode(params, tokens, lengths)
        assert emb.shape == (3, MINILM_L6.d_model)
        np.testing.assert_allclose(np.asarray(jnp.linalg.norm(emb, axis=-1)),
                                   1.0, rtol=1e-5)

    def test_padding_invariance(self):
        """Embedding must ignore positions beyond `length`."""
        params = init_encoder_params(jax.random.PRNGKey(0))
        t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 3, 1000)
        t2 = t1.at[:, 8:].set(999)       # garbage in the padded region
        l = jnp.asarray([8])
        e1 = encode(params, t1, l)
        e2 = encode(params, t2, l)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)

    def test_jit_compatible(self):
        params = init_encoder_params(jax.random.PRNGKey(0))
        f = jax.jit(lambda p, t, l: encode(p, t, l))
        out = f(params, jnp.ones((2, 8), jnp.int32), jnp.asarray([8, 4]))
        assert bool(jnp.all(jnp.isfinite(out)))
