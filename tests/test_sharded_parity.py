"""Decision parity of the sharded step vs the local step (DESIGN.md §19.6).

The tentpole contract: `DistributedCache.step` runs the SAME
`SemanticCache` body under `shard_map` with communication seams swapped
in, so on identical traffic it must make identical decisions — same
hit/near/miss masks, same served values/provenance, same counters, and a
bitwise-identical set of slab keys (entry *placement* differs by design:
shard-major round-robin vs the single global ring).

Everything runs in subprocesses on a forced >1-device CPU topology (see
tests/test_distributed.py); CI exports
XLA_FLAGS=--xla_force_host_platform_device_count=8 for the whole file.
"""
from test_distributed import run_with_devices

# Shared harness: drives identical multi-tenant band+fusion traffic
# through a local SemanticCache and a 4-shard DistributedCache, then
# asserts decision/counter/key parity. ``@INDEX@`` is substituted so the
# exact-index and sharded-IVF suites are literally the same program.
PARITY_HARNESS = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import SemanticCache, CacheConfig, DistributedCache
    from repro.context.fusion import DecayMeanFusion
    from repro.generative.policy import BandPolicy
    from repro.tenancy.registry import TenantRegistry, TenantSpec

    cfg = CacheConfig(dim=32, capacity=256, value_len=8, ttl=None,
                      threshold=0.8, topk=4)
    reg = TenantRegistry((
        TenantSpec(name="acme"),
        TenantSpec(name="zen", threshold=0.85, band_lo=0.65)))
    part = reg.partition(cfg.capacity)
    pol = BandPolicy(tau_lo=0.70, tau_hi=0.80)
    fus = DecayMeanFusion(window=3)
    index = @INDEX@
    make = lambda: SemanticCache(cfg, policy=pol, partition=part,
                                 fusion=fus, index=index)
    local = make()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    dc = DistributedCache(make(), mesh)
    assert dc.num_shards == 4
    lrt, drt = local.init(), dc.init()

    lstep = jax.jit(lambda rt, q, mv, mvl, t, sid, valid, tid, w, wl:
                    local.step(rt, q, mv, mvl, t, source_id=sid,
                               valid=valid, tenant_id=tid, window=w,
                               window_len=wl))
    dstep = jax.jit(lambda rt, q, mv, mvl, t, sid, valid, tid, w, wl:
                    dc.step(rt, q, mv, mvl, t, source_id=sid,
                            valid=valid, tenant_id=tid, window=w,
                            window_len=wl))

    B, D, W = 16, 32, 3
    rng = np.random.default_rng(7)
    inserted = []          # queries already admitted, for paraphrase traffic
    for r in range(6):
        fresh = rng.standard_normal((B, D)).astype(np.float32)
        q = fresh.copy()
        if inserted:                      # paraphrase half the batch
            pool = np.concatenate(inserted)
            pick = rng.integers(0, len(pool), size=B // 2)
            q[: B // 2] = pool[pick] + \\
                0.05 * rng.standard_normal((B // 2, D)).astype(np.float32)
        mv = rng.integers(0, 99, size=(B, 8)).astype(np.int32)
        mvl = np.full((B,), 8, dtype=np.int32)
        sid = np.arange(r * B, (r + 1) * B, dtype=np.int32)
        valid = np.ones((B,), dtype=bool)
        valid[-2:] = r % 2 == 0           # exercise pad rows
        tid = rng.integers(0, 2, size=B).astype(np.int32)
        w = rng.standard_normal((B, W, D)).astype(np.float32)
        wl = rng.integers(0, W + 1, size=B).astype(np.int32)
        args = [jnp.asarray(a) for a in
                (q, mv, mvl, np.float32(r), sid, valid, tid, w, wl)]
        lres, lrt = lstep(lrt, *args)
        dres, drt = dstep(drt, *args)

        hit = np.asarray(lres.hit)
        np.testing.assert_array_equal(np.asarray(dres.hit), hit,
                                      err_msg=f"hit mask, round {r}")
        np.testing.assert_array_equal(np.asarray(dres.near),
                                      np.asarray(lres.near),
                                      err_msg=f"near mask, round {r}")
        np.testing.assert_allclose(np.asarray(dres.score),
                                   np.asarray(lres.score), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(dres.values)[hit],
                                      np.asarray(lres.values)[hit])
        np.testing.assert_array_equal(np.asarray(dres.source_id)[hit],
                                      np.asarray(lres.source_id)[hit])
        # near-hit payloads: same neighbour sets (by provenance + score),
        # though under different global slot ids
        lpay = local.gather_topk(lrt, lres)
        dpay = dc.gather_topk(drt, dres)
        ls = np.sort(np.asarray(lpay["source_id"]), axis=1)
        ds = np.sort(np.asarray(dpay["source_id"]), axis=1)
        np.testing.assert_array_equal(ds, ls,
                                      err_msg=f"topk neighbours, round {r}")
        inserted.append(q[~hit & valid])

    # replicated stats: one global workload, counted once
    for f in ("lookups", "hits", "misses", "inserts"):
        assert int(getattr(drt.stats, f)) == int(getattr(lrt.stats, f)), f
    # sharded tenancy counters reduce to the local ones exactly
    red = drt.tenancy.reduced()
    for f in ("lookups", "hits", "inserts", "evictions"):
        np.testing.assert_array_equal(np.asarray(getattr(red, f)),
                                      np.asarray(getattr(lrt.tenancy, f)),
                                      err_msg=f)
    # the slabs hold the SAME entries (bitwise keys), placed differently
    lk = np.asarray(lrt.state.keys)[np.asarray(lrt.state.valid)]
    dk = np.asarray(drt.state.keys)[np.asarray(drt.state.valid)]
    assert sorted(r.tobytes() for r in lk) == \\
        sorted(r.tobytes() for r in dk)
    assert len(dk) == int(drt.stats.inserts)
    print("PARITY-OK", len(dk))
"""


class TestShardedParity:
    def test_full_feature_parity_exact_index(self):
        """Tenancy + per-tenant overrides + band policy + context fusion,
        exact index, 4-shard mesh: bitwise decision/key parity."""
        out = run_with_devices(PARITY_HARNESS.replace("@INDEX@", "None"))
        assert "PARITY-OK" in out

    def test_full_feature_parity_sharded_ivf(self):
        """The ExactIndex-only restriction is gone: a *leafy* IVF index
        runs per-shard over local slot ids. With nprobe == ncentroids the
        probe is exhaustive, so IVF must reproduce the exact-index
        decisions bit for bit — same parity suite, same assertions."""
        out = run_with_devices(PARITY_HARNESS.replace(
            "@INDEX@",
            "__import__('repro.core.index', fromlist=['IVFIndex'])"
                  ".IVFIndex(ncentroids=4, nprobe=4, bucket_cap=256, "
                  "topk=4, kmeans_iters=2)"))
        assert "PARITY-OK" in out

    def test_round_robin_balance_under_masked_inserts(self):
        """Regression for the raw-row-index routing bug: with insert masks
        selecting rows {0,4,8,12} of a 16-row batch on 4 shards, the old
        `(n_inserts + row) % num_shards` rule sends EVERY masked-in row of
        every batch to the same shard (row ≡ 0 mod 4 and n_inserts grows
        by 4 per batch). Routing by the cumulative count of masked-in rows
        keeps the shards balanced."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import SemanticCache, CacheConfig, \\
                DistributedCache
            cfg = CacheConfig(dim=16, capacity=128, value_len=4, ttl=None)
            mesh = jax.make_mesh((4,), ("data",))
            dc = DistributedCache(SemanticCache(cfg), mesh)
            rt = dc.init()
            ins = jax.jit(lambda rt, q, v, vl, t, m:
                          dc.insert(rt, q, v, vl, t, mask=m))
            mask = np.zeros((16,), dtype=bool)
            mask[::4] = True                     # adversarial: rows 0,4,8,12
            v = jnp.zeros((16, 4), jnp.int32)
            vl = jnp.full((16,), 4, jnp.int32)
            for b in range(8):
                q = jax.random.normal(jax.random.PRNGKey(b), (16, 16))
                rt = ins(rt, q, v, vl, jnp.float32(b), jnp.asarray(mask))
            per_shard = np.asarray(rt.state.valid).reshape(4, -1).sum(axis=1)
            assert int(rt.state.n_inserts) == 32
            assert (per_shard == 8).all(), per_shard   # 32 inserts / 4 shards
            print("BALANCE-OK", per_shard.tolist())
        """)
        assert "BALANCE-OK" in out

    def test_reshard_on_load(self, tmp_path):
        """Checkpoint round-trips across shard counts (§19.5): a snapshot
        taken single-device restores onto a 4-shard mesh (and back), keeps
        serving the same hits, preserves per-tenant accounting, and the
        strict path refuses the layout mismatch."""
        out = run_with_devices(f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.types import CacheConfig
            from repro.data.qa_dataset import build_corpus
            from repro.serving import (CachedEngine, Request,
                                       SimulatedLLMBackend)
            from repro.tenancy.registry import TenantRegistry

            pairs = build_corpus(80, seed=0)
            reg = TenantRegistry.uniform(("acme", "zen"))
            cfg = CacheConfig(dim=384, capacity=512, value_len=48,
                              ttl=None, threshold=0.8)
            mk = lambda mesh: CachedEngine(
                cfg, SimulatedLLMBackend(pairs), batch_size=8,
                registry=reg, mesh=mesh)

            e1 = mk(None)
            e1.warm(pairs[:40], tenant="acme")
            e1.warm(pairs[40:], tenant="zen")
            reqs = [Request(query=p.question, tenant="acme",
                            source_id=p.qa_id) for p in pairs[:8]]
            assert all(r.cached for r in e1.process(reqs))
            snap = {str(tmp_path / "snap")!r}
            e1.save_cache(snap)
            stats1 = e1.tenant_stats()

            mesh = jax.make_mesh((4,), ("data",))
            e2 = mk(mesh)
            try:
                e2.load_cache(snap, reshard=False)
                raise AssertionError("strict load accepted a 1->4 restore")
            except ValueError as err:
                assert "shard" in str(err)
            e2.load_cache(snap)                    # reshard 1 -> 4
            # entries really are spread over the 4 shard slices now
            per_shard = np.asarray(
                e2.runtime.state.valid).reshape(4, -1).sum(axis=1)
            assert (per_shard > 0).all(), per_shard
            assert all(r.cached for r in e2.process(reqs))
            stats2 = e2.tenant_stats()
            for t in ("acme", "zen"):
                assert stats2[t]["inserts"] == stats1[t]["inserts"], t

            # and back down: 4-shard snapshot onto a single device
            snap2 = {str(tmp_path / "snap4")!r}
            e2.save_cache(snap2)
            e3 = mk(None)
            e3.load_cache(snap2)                   # reshard 4 -> 1
            assert all(r.cached for r in e3.process(reqs))
            assert e3.tenant_stats()["zen"]["inserts"] == \\
                stats1["zen"]["inserts"]
            print("RESHARD-OK")
        """)
        assert "RESHARD-OK" in out
