"""Multi-tenant cache namespaces (DESIGN.md §13): partition map, isolation,
one-compiled-step acceptance, per-tenant accounting, DRR admission fairness,
tenant-scoped coalescing, checkpointing, and the multi-tenant loadgen."""
import asyncio
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CacheConfig, SemanticCache
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request, Response,
                           SchedulerConfig, ServingMetrics,
                           SimulatedLLMBackend, build_multi_tenant_workload,
                           coalesce_key, normalize_query, tenant_rng,
                           zipf_weights)
from repro.tenancy import (NO_OVERRIDE, PartitionMap, TenancyState,
                           TenantRegistry, TenantSpec)


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(80, seed=0)


def mk_registry(*specs):
    return TenantRegistry(tuple(specs))


def mk_cache(capacity=256, dim=32, registry=None, **kw):
    kw.setdefault("ttl", None)
    cfg = CacheConfig(dim=dim, capacity=capacity, value_len=8, **kw)
    part = registry.partition(capacity) if registry else None
    return SemanticCache(cfg, partition=part), cfg


def corpus(rng, n, dim):
    k1, k2 = jax.random.split(rng)
    emb = jax.random.normal(k1, (n, dim))
    vals = jax.random.randint(k2, (n, 8), 0, 100)
    return emb, vals, jnp.full((n,), 8)


def mk_engine(pairs, registry, *, batch_size=16, capacity=None, **kw):
    by_id = {p.qa_id: p for p in pairs}

    def judge(req, sid):
        return sid >= 0 and sid in by_id and \
            by_id[sid].semantic_key == req.semantic_key

    cfg = CacheConfig(
        dim=384,
        capacity=capacity or 2048 * (len(registry) if registry else 1),
        value_len=48, ttl=None, threshold=0.8)
    return CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                        batch_size=batch_size, registry=registry, **kw)


# --------------------------------------------------------------------- #
# registry + partition map
# --------------------------------------------------------------------- #
class TestPartitionMap:
    def test_shares_quotas_cover_slab_exactly(self):
        reg = mk_registry(TenantSpec("a", share=2.0),
                          TenantSpec("b", share=1.0),
                          TenantSpec("c", quota=100))
        part = reg.partition(1000)
        assert part.sizes[part.index("c")] == 100
        a, b = part.sizes[part.index("a")], part.sizes[part.index("b")]
        assert a + b == 900 and abs(a - 2 * b) <= 2
        # contiguous, ordered, exact cover (enforced by PartitionMap too)
        assert sum(part.sizes) == part.capacity == 1000
        assert part.starts == (0, part.sizes[0],
                               part.sizes[0] + part.sizes[1])
        owner = part.slot_owner()
        for t, (s, z) in enumerate(zip(part.starts, part.sizes)):
            assert (owner[s:s + z] == t).all()

    def test_allocation_is_order_independent(self):
        """Regression: a quota tenant declared after a share tenant must
        not starve it to zero slots — slot reservation counts every unsized
        tenant, wherever it appears in the declaration order."""
        ab = mk_registry(TenantSpec("a", share=1.0),
                         TenantSpec("b", quota=100)).partition(100)
        ba = mk_registry(TenantSpec("b", quota=100),
                         TenantSpec("a", share=1.0)).partition(100)
        assert ab.sizes[ab.index("a")] == ba.sizes[ba.index("a")] == 1
        assert ab.sizes[ab.index("b")] == ba.sizes[ba.index("b")] == 99

    def test_thresholds_and_weights_round_trip(self):
        reg = mk_registry(TenantSpec("a", threshold=0.9, weight=3.0),
                          TenantSpec("b"))
        part = reg.partition(64)
        assert part.thresholds == (0.9, NO_OVERRIDE)
        assert reg.weights() == {"a": 3.0, "b": 1.0}

    def test_validation(self):
        with pytest.raises(ValueError):
            mk_registry(TenantSpec("a"), TenantSpec("a"))      # dup name
        with pytest.raises(ValueError):
            TenantSpec("x", share=0.0)
        with pytest.raises(ValueError):
            TenantSpec("x", threshold=1.5)
        with pytest.raises(ValueError):
            mk_registry(TenantSpec("a"), TenantSpec("b")).partition(1)
        with pytest.raises(ValueError):
            PartitionMap(names=("a",), starts=(1,), sizes=(3,),
                         thresholds=(-1.0,), capacity=4)       # gap at 0

    def test_partitioned_cache_rejects_lru(self):
        reg = TenantRegistry.uniform(["a", "b"])
        with pytest.raises(ValueError, match="ring"):
            mk_cache(registry=reg, eviction="lru")


# --------------------------------------------------------------------- #
# core isolation + accounting (raw SemanticCache)
# --------------------------------------------------------------------- #
class TestIsolation:
    def test_identical_query_cached_by_a_misses_for_b(self):
        """Acceptance criterion: cosine similarity 1.0 across tenants is
        still a miss — other tenants' entries are invisible, not merely
        sub-threshold."""
        reg = TenantRegistry.uniform(["a", "b"])
        c, cfg = mk_cache(registry=reg)
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 8, cfg.dim)
        ta = jnp.zeros((8,), jnp.int32)
        tb = jnp.ones((8,), jnp.int32)
        _, rt = c.step(rt, emb, vals, lens, 0.0, tenant_id=ta)
        res_a, rt = c.lookup(rt, emb, 1.0, tenant_id=ta)
        assert bool(res_a.hit.all())
        np.testing.assert_allclose(np.asarray(res_a.score), 1.0, atol=1e-5)
        res_b, rt = c.lookup(rt, emb, 1.0, tenant_id=tb)
        assert not bool(res_b.hit.any())
        # the B rows saw an empty region: score is -inf, not ~1.0
        assert bool((np.asarray(res_b.score) == -np.inf).all())

    def test_adversarial_identical_queries_in_one_batch(self, pairs):
        """Same bytes, different tenants, same micro-batch: each tenant
        pays its own miss, then hits only its own region's entry."""
        reg = TenantRegistry.uniform(["a", "b"])
        eng = mk_engine(pairs, reg, batch_size=8)
        q = "is there a student discount on the tenancy test plan"
        batch = [Request(query=q, tenant="a"), Request(query=q, tenant="b")]
        first = eng.process(batch)
        assert [r.cached for r in first] == [False, False]
        again = eng.process(batch)
        assert [r.cached for r in again] == [True, True]
        # each hit resolved inside its own region
        part = eng.cache.partition
        owner = part.slot_owner()
        valid = np.asarray(eng.state.valid)
        assert valid[owner == 0].sum() == 1 and valid[owner == 1].sum() == 1

    def test_per_tenant_threshold_override(self):
        reg = mk_registry(TenantSpec("lax"),
                          TenantSpec("strict", threshold=0.99))
        c, cfg = mk_cache(registry=reg, threshold=0.8)
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        for tid in (jnp.zeros((4,), jnp.int32), jnp.ones((4,), jnp.int32)):
            rt = c.insert(rt, emb, vals, lens, 0.0, tenant_id=tid)
        # perturb so cosine lands between 0.8 and 0.99
        noisy = emb + 0.25 * jax.random.normal(jax.random.PRNGKey(1),
                                               emb.shape)
        res_l, rt = c.lookup(rt, noisy, 1.0,
                             tenant_id=jnp.zeros((4,), jnp.int32))
        res_s, rt = c.lookup(rt, noisy, 1.0,
                             tenant_id=jnp.ones((4,), jnp.int32))
        score = np.asarray(res_l.score)
        assert (score > 0.8).all() and (score < 0.99).all(), score
        assert bool(res_l.hit.all())        # cache-wide 0.8 applies
        assert not bool(res_s.hit.any())    # 0.99 override applies

    def test_ring_eviction_stays_inside_own_region(self):
        reg = mk_registry(TenantSpec("small", quota=16), TenantSpec("big"))
        c, cfg = mk_cache(capacity=64, registry=reg)
        rt = c.init()
        bemb, bvals, blens = corpus(jax.random.PRNGKey(0), 8, cfg.dim)
        big = jnp.ones((8,), jnp.int32)
        rt = c.insert(rt, bemb, bvals, blens, 0.0, tenant_id=big)
        # flood 'small' with 48 distinct rows through its 16-slot region
        small = jnp.zeros((8,), jnp.int32)
        for i in range(6):
            semb, svals, slens = corpus(jax.random.PRNGKey(10 + i), 8,
                                        cfg.dim)
            rt = c.insert(rt, semb, svals, slens, 1.0 + i, tenant_id=small)
        # big's entries are untouched by the neighbour's churn
        res, rt = c.lookup(rt, bemb, 10.0, tenant_id=big)
        assert bool(res.hit.all())
        owner = reg.partition(64).slot_owner()
        valid = np.asarray(rt.state.valid)
        assert valid[owner == 0].sum() == 16      # region full, wrapped
        assert valid[owner == 1].sum() == 8
        t = rt.tenancy
        assert int(t.inserts[0]) == 48
        assert int(t.evictions[0]) == 32          # 48 inserts - 16 slots
        assert int(t.evictions[1]) == 0

    def test_partitioned_cache_requires_tenant_id(self):
        reg = TenantRegistry.uniform(["a", "b"])
        c, cfg = mk_cache(registry=reg)
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        with pytest.raises(ValueError, match="tenant_id"):
            c.lookup(rt, emb, 0.0)
        with pytest.raises(ValueError, match="tenant_id"):
            c.insert(rt, emb, vals, lens, 0.0)

    def test_unpartitioned_cache_ignores_tenancy(self):
        c, cfg = mk_cache()
        rt = c.init()
        assert rt.tenancy is None
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        res, rt = c.step(rt, emb, vals, lens, 0.0)
        assert rt.tenancy is None and int(res.hit.sum()) == 0

    def test_empty_region_tenant_is_structural_miss(self):
        """Satellite: a tenant whose region has zero live slots gets
        (-inf, -1, no hit) — not an arbitrary slot with a masked score."""
        reg = TenantRegistry.uniform(["seeded", "empty"])
        c, cfg = mk_cache(registry=reg)
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0,
                      tenant_id=jnp.zeros((4,), jnp.int32))
        res, rt = c.lookup(rt, emb, 1.0,
                           tenant_id=jnp.ones((4,), jnp.int32))
        assert bool((np.asarray(res.score) == -np.inf).all())
        assert not bool(res.hit.any())
        assert bool((np.asarray(res.topk_index) == -1).all())
        assert bool((np.asarray(res.topk_score) == -np.inf).all())

    def test_ivf_index_under_tenancy_matches_exact(self):
        """The interval operands flow through ANY Index plugin: IVF with
        full probing agrees with exact search on a partitioned cache —
        isolation included (a cosine-1.0 duplicate in the other region is
        invisible on both paths)."""
        from repro.core.index import IVFIndex
        reg = TenantRegistry.uniform(["a", "b"])
        cap, dim = 128, 32
        cfg = CacheConfig(dim=dim, capacity=cap, value_len=8, ttl=None)
        part = reg.partition(cap)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 8, dim)
        ta = jnp.zeros((8,), jnp.int32)
        tb = jnp.ones((8,), jnp.int32)
        probe = emb + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                               emb.shape)
        results = {}
        for name, index in (
                ("exact", None),
                ("ivf", IVFIndex(ncentroids=4, nprobe=4, bucket_cap=cap,
                                 topk=4))):
            c = SemanticCache(cfg, index=index, partition=part)
            rt = c.init()
            rt = c.insert(rt, emb, vals, lens, 0.0, tenant_id=ta)
            rt = c.refit(rt, 0.0, jax.random.PRNGKey(2))
            res_a, rt = c.lookup(rt, probe, 1.0, tenant_id=ta)
            res_b, rt = c.lookup(rt, probe, 1.0, tenant_id=tb)
            results[name] = (res_a, res_b)
        ex_a, ex_b = results["exact"]
        iv_a, iv_b = results["ivf"]
        np.testing.assert_array_equal(np.asarray(ex_a.index),
                                      np.asarray(iv_a.index))
        np.testing.assert_allclose(np.asarray(ex_a.score),
                                   np.asarray(iv_a.score), rtol=1e-5,
                                   atol=1e-5)
        # tenant b sees nothing on either path: cross-tenant isolation
        for res in (ex_b, iv_b):
            assert bool((np.asarray(res.score) == -np.inf).all())
            assert not bool(res.hit.any())


# --------------------------------------------------------------------- #
# one compiled program + padding hygiene (engine)
# --------------------------------------------------------------------- #
class TestCompiledStepSharing:
    def test_no_recompile_across_tenant_mixes(self, pairs):
        """Acceptance criterion: the tenant_id vector is traced, so every
        tenant mix shares ONE compiled fused step."""
        reg = TenantRegistry.uniform(["a", "b", "c"])
        eng = mk_engine(pairs, reg, batch_size=8)
        eng.process([Request(query=f"probe a{i}", tenant="a")
                     for i in range(8)])
        traces = eng._step_jit._cache_size()
        assert traces == 1
        eng.process([Request(query=f"probe m{i}",
                             tenant=["a", "b", "c"][i % 3])
                     for i in range(8)])
        eng.process([Request(query=f"probe c{i}", tenant="c")
                     for i in range(3)])      # padded partial batch
        assert eng._step_jit._cache_size() == traces
        assert eng._peek_jit._cache_size() == 1

    # mutually dissimilar (share almost no n-grams): numbered variants of
    # one template would legitimately hit each other at threshold 0.8
    DISTINCT = [
        "why is the sky blue at noon",
        "best sourdough starter feeding schedule",
        "how tall is mount kilimanjaro",
        "difference between alligators and crocodiles",
        "what causes aurora borealis displays",
        "recommend a jazz album from 1959",
        "do tides depend on the moon",
        "boiling point of ethanol at altitude",
        "who invented the mechanical clock",
        "explain photosynthesis light reactions",
        "how many strings does a cello have",
    ]

    def test_padded_mixed_batch_counters_clean(self, pairs):
        reg = TenantRegistry.uniform(["a", "b"])
        eng = mk_engine(pairs, reg, batch_size=8)
        tenants = ["a", "b", "a", "b", "a", "b", "a", "b", "a", "b", "a"]
        reqs = [Request(query=q, category="python_basics", tenant=t)
                for q, t in zip(self.DISTINCT, tenants)]  # 11: one padded
        responses = eng.process(reqs)
        assert len(responses) == 11
        s = eng.metrics.summary()
        assert s["queries"] == 11
        assert "__pad__" not in s["categories"]
        # host-side per-tenant == device-side per-tenant == request counts
        dev = eng.tenant_stats()
        assert dev["a"]["lookups"] == 6 and dev["b"]["lookups"] == 5
        assert s["tenants"]["a"]["lookups"] == 6
        assert s["tenants"]["b"]["lookups"] == 5
        assert int(eng.stats.lookups) == 11
        assert dev["a"]["inserts"] == 6 and dev["b"]["inserts"] == 5
        assert int(np.sum(np.asarray(eng.state.valid))) == 11
        # second pass: all hits, each within its own tenant
        again = eng.process(reqs)
        assert all(r.cached for r in again)
        dev = eng.tenant_stats()
        assert dev["a"]["hits"] == 6 and dev["b"]["hits"] == 5

    def test_fused_and_separate_paths_agree_with_tenants(self, pairs):
        reg = TenantRegistry.uniform(["a", "b"])
        wl = build_multi_tenant_workload(pairs, 48, tenants=["a", "b"],
                                         skew=0.5, seed=3)
        results = {}
        for fused in (True, False):
            eng = mk_engine(pairs, reg, batch_size=16, use_fused_step=fused)
            for t in ("a", "b"):
                eng.warm(pairs[:40], tenant=t)
            resp = eng.process(wl)
            results[fused] = (
                [(r.answer, r.cached, round(r.score, 5)) for r in resp],
                eng.tenant_stats())
        assert results[True] == results[False]

    def test_engine_rejects_region_smaller_than_batch(self, pairs):
        reg = mk_registry(TenantSpec("tiny", quota=4), TenantSpec("rest"))
        with pytest.raises(ValueError, match="region"):
            mk_engine(pairs, reg, batch_size=16, capacity=4096)

    def test_engine_rejects_oversized_admission_batch(self, pairs):
        """Regression: a mis-aligned scheduler max_batch could hand a
        partitioned engine more rows than a region holds — the per-tenant
        ring would silently collide slots, so serve_batch fails loudly."""
        reg = TenantRegistry.uniform(["a", "b"])
        eng = mk_engine(pairs, reg, batch_size=8)
        with pytest.raises(ValueError, match="max_batch"):
            eng.serve_batch([Request(query=f"q{i}", tenant="a")
                             for i in range(9)])
        # single-tenant engines keep accepting oversized batches (they
        # just retrace): the guard is tenancy-only
        eng1 = mk_engine(pairs, None, batch_size=8)
        assert len(eng1.serve_batch(
            [Request(query=f"q{i}") for i in range(9)])) == 9


# --------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------- #
class TestTenancyCheckpoint:
    def test_roundtrip_restores_tenancy_and_partition(self, pairs, tmp_path):
        reg = mk_registry(TenantSpec("a", share=2.0),
                          TenantSpec("b", threshold=0.9))
        eng = mk_engine(pairs, reg, batch_size=8)
        eng.warm(pairs[:30], tenant="a")
        eng.process([Request(query=p.question, tenant="a")
                     for p in pairs[:8]])
        eng.process([Request(query="b tenant novel question", tenant="b")])
        path = os.path.join(str(tmp_path), "tenancy.npz")
        eng.save_cache(path)

        eng2 = mk_engine(pairs, reg, batch_size=8)
        eng2.load_cache(path)
        for a, b in zip(jax.tree_util.tree_leaves(eng.runtime.tenancy),
                        jax.tree_util.tree_leaves(eng2.runtime.tenancy)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert eng2.tenant_stats() == eng.tenant_stats()
        # restored engine serves tenant-a hits, tenant-b still isolated
        hit = eng2.process([Request(query=pairs[0].question, tenant="a")])[0]
        miss = eng2.process([Request(query=pairs[0].question, tenant="b")])[0]
        assert hit.cached and not miss.cached

    def test_partition_mismatch_rejected(self, pairs, tmp_path):
        reg = TenantRegistry.uniform(["a", "b"])
        eng = mk_engine(pairs, reg, batch_size=8)
        path = os.path.join(str(tmp_path), "part.npz")
        eng.save_cache(path)
        other = mk_registry(TenantSpec("a", share=3.0), TenantSpec("b"))
        eng2 = mk_engine(pairs, other, batch_size=8)
        with pytest.raises(ValueError, match="partition"):
            eng2.load_cache(path)


# --------------------------------------------------------------------- #
# scheduler: DRR fairness, per-tenant backpressure, tenant coalescing
# --------------------------------------------------------------------- #
class _FakeEngine:
    """Duck-typed stand-in recording batch compositions; the scheduler only
    touches ``serve_batch``, ``metrics``, ``tracer`` and (optionally)
    ``registry``."""

    def __init__(self, delay_s=0.0):
        from repro.obs import Tracer
        self.metrics = ServingMetrics()
        self.registry = None
        self.tracer = Tracer()          # collection off, like the engine's
        self.delay_s = delay_s          # default
        self.batches: list[list[str]] = []

    def serve_batch(self, batch, record_path_latency=True, traces=None):
        self.batches.append([r.tenant for r in batch])
        if self.delay_s:
            time.sleep(self.delay_s)
        return [Response(answer=f"ok:{r.query}", cached=False, score=0.0,
                         latency_s=0.0) for r in batch]


class TestDRRFairness:
    def test_flooding_tenant_cannot_monopolize_batches(self):
        """One tenant floods 64 requests; a second tenant's 8 arrive after.
        DRR must interleave: the mouse finishes within a couple of batches
        instead of queueing behind the whole flood."""
        eng = _FakeEngine(delay_s=0.02)
        sched = SchedulerConfig(max_batch=8, max_wait_ms=1000.0,
                                coalesce=False)
        done_order: list[str] = []

        async def drive():
            async with AsyncCacheServer(eng, sched) as server:
                async def timed(r):
                    await server.submit_request(r)
                    done_order.append(r.tenant)
                hog = [asyncio.create_task(timed(
                    Request(query=f"hog {i}", tenant="hog")))
                    for i in range(64)]
                await asyncio.sleep(0.015)   # first batch dispatched, rest queued
                mouse = [asyncio.create_task(timed(
                    Request(query=f"mouse {i}", tenant="mouse")))
                    for i in range(8)]
                await asyncio.gather(*hog, *mouse)

        asyncio.run(drive())
        assert len(done_order) == 72
        # every mouse request completed before the last 16 hog requests
        last_mouse = max(i for i, t in enumerate(done_order) if t == "mouse")
        hogs_after = sum(1 for t in done_order[last_mouse + 1:]
                         if t == "hog")
        assert hogs_after >= 16, (last_mouse, hogs_after)
        # contended batches are split, not hog-only
        mixed = [b for b in eng.batches if "mouse" in b]
        assert mixed and all(b.count("mouse") <= 5 for b in mixed)

    def test_weights_bias_the_split(self):
        """Weight-3 tenant takes ~3x the slots of a weight-1 tenant while
        both are backlogged."""
        eng = _FakeEngine(delay_s=0.02)
        sched = SchedulerConfig(max_batch=8, max_wait_ms=1000.0,
                                coalesce=False,
                                tenant_weights={"vip": 3.0, "std": 1.0})

        async def drive():
            async with AsyncCacheServer(eng, sched) as server:
                tasks = [asyncio.create_task(server.submit_request(
                    Request(query=f"v{i}", tenant="vip")))
                    for i in range(32)]
                tasks += [asyncio.create_task(server.submit_request(
                    Request(query=f"s{i}", tenant="std")))
                    for i in range(32)]
                await asyncio.gather(*tasks)

        asyncio.run(drive())
        contended = [b for b in eng.batches
                     if "vip" in b and "std" in b and len(b) == 8]
        assert contended
        vip = sum(b.count("vip") for b in contended)
        std = sum(b.count("std") for b in contended)
        assert vip >= 2 * std, (vip, std)

    def test_per_tenant_backpressure_forces_flush(self):
        """A tenant at its own queue bound blocks and forces flushes; the
        run completes (no deadlock) with bounded per-tenant residency."""
        eng = _FakeEngine()
        sched = SchedulerConfig(max_batch=4, max_queue=1024,
                                max_queue_per_tenant=4,
                                max_wait_ms=5_000.0, coalesce=False)

        async def drive():
            async with AsyncCacheServer(eng, sched) as server:
                await asyncio.gather(*(server.submit_request(
                    Request(query=f"q{i}", tenant="x")) for i in range(16)))

        asyncio.run(drive())
        assert sum(len(b) for b in eng.batches) == 16
        # forced flushes kept batches at/below the per-tenant bound
        assert all(len(b) <= 4 for b in eng.batches)


class TestTenantCoalescing:
    def test_normalize_query(self):
        assert normalize_query("  How  do I\tSort a LIST \n") == \
            "how do i sort a list"
        r1 = Request(query="How  Do I sort", tenant="t")
        r2 = Request(query="how do i sort ", tenant="t")
        r3 = Request(query="how do i sort", tenant="u")
        assert coalesce_key(r1) == coalesce_key(r2)
        assert coalesce_key(r1) != coalesce_key(r3)

    def test_trivially_different_duplicates_coalesce(self, pairs):
        """Satellite regression: whitespace/case variants share one leader
        (one backend call), the first step toward embedding-similarity
        coalescing."""
        eng = mk_engine(pairs, None, batch_size=8)
        variants = ["what is the WARRANTY on the doodad",
                    "  what is the warranty on the doodad ",
                    "What is the Warranty  on the doodad"]

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(v) for v in variants * 4))

        responses = asyncio.run(herd())
        assert eng.backend.calls == 1
        assert sum(r.coalesced for r in responses) == 11
        assert len({r.answer for r in responses}) == 1

    def test_identical_queries_do_not_coalesce_across_tenants(self, pairs):
        reg = TenantRegistry.uniform(["a", "b"])
        eng = mk_engine(pairs, reg, batch_size=8)
        q = "do identical cross tenant questions stay separate"

        async def herd():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0,
                                    tenant_weights=reg.weights())
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q, tenant=t)
                      for t in ("a", "b", "a", "b")))

        responses = asyncio.run(herd())
        # one leader per tenant -> 2 backend calls, 2 coalesced waiters
        assert eng.backend.calls == 2
        assert sum(r.coalesced for r in responses) == 2
        dev = eng.tenant_stats()
        assert dev["a"]["lookups"] == 1 and dev["b"]["lookups"] == 1


# --------------------------------------------------------------------- #
# loadgen: per-(seed, tenant) streams
# --------------------------------------------------------------------- #
class TestMultiTenantLoadgen:
    def test_tenant_rng_is_stable_and_per_tenant(self):
        a1 = [tenant_rng(7, "acme").random() for _ in range(1)][0]
        a2 = tenant_rng(7, "acme").random()
        b = tenant_rng(7, "globex").random()
        assert a1 == a2 and a1 != b
        assert tenant_rng(8, "acme").random() != a1

    def test_zipf_weights(self):
        w = zipf_weights(4, skew=1.0)
        assert w[0] > w[1] > w[2] > w[3]
        assert abs(sum(w) - 1.0) < 1e-9
        assert zipf_weights(3, skew=0.0) == pytest.approx([1 / 3] * 3)

    def test_adding_a_tenant_never_perturbs_another_stream(self, pairs):
        """Satellite: tenant A's request sequence is a pure function of
        (seed, tenant, n_requests) — growing the tenant set changes only
        the interleaving, never what an existing tenant asks."""
        wl_ab = build_multi_tenant_workload(
            pairs, 240, tenants=["a", "b"], skew=1.0, seed=5)
        wl_abc = build_multi_tenant_workload(
            pairs, 240, tenants=["a", "b", "c"], skew=1.0, seed=5)
        for t in ("a", "b"):
            seq2 = [r.query for r in wl_ab if r.tenant == t]
            seq3 = [r.query for r in wl_abc if r.tenant == t]
            k = min(len(seq2), len(seq3))
            assert k > 10
            assert seq2[:k] == seq3[:k]

    def test_skew_concentrates_traffic(self, pairs):
        wl = build_multi_tenant_workload(
            pairs, 400, tenants=["big", "mid", "tail"], skew=1.5, seed=2)
        counts = {t: sum(r.tenant == t for r in wl)
                  for t in ("big", "mid", "tail")}
        assert counts["big"] > counts["mid"] > counts["tail"]
        assert len(wl) == 400

    def test_bursts_stay_within_tenant(self, pairs):
        wl = build_multi_tenant_workload(
            pairs, 200, tenants=["a", "b"], skew=0.0, burst_prob=1.0,
            burst_size=4, seed=9)
        # consecutive identical queries always share a tenant
        for r1, r2 in zip(wl, wl[1:]):
            if r1.query == r2.query:
                assert r1.tenant == r2.tenant


# --------------------------------------------------------------------- #
# runtime pytree integration
# --------------------------------------------------------------------- #
class TestTenancyRuntime:
    def test_tenancy_state_is_pytree_leaf_group(self):
        reg = TenantRegistry.uniform(["a", "b"])
        c, cfg = mk_cache(registry=reg)
        rt = c.init()
        assert isinstance(rt.tenancy, TenancyState)
        leaves, treedef = jax.tree_util.tree_flatten(rt)
        rt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        jitted = jax.jit(lambda r, q, v, l, t, tid: c.step(
            r, q, v, l, t, tenant_id=tid))
        _, rt2 = jitted(rt2, emb, vals, lens, jnp.float32(0.0),
                        jnp.zeros((4,), jnp.int32))
        assert int(rt2.tenancy.inserts[0]) == 4
        assert int(rt2.tenancy.inserts[1]) == 0

    def test_counted_lookup_matches_peek_commit_accounting(self):
        """peek -> commit must account per-tenant identically to a counted
        lookup (the engine's fused path vs the reference path)."""
        reg = TenantRegistry.uniform(["a", "b"])
        c, cfg = mk_cache(registry=reg)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 6, cfg.dim)
        tid = jnp.asarray([0, 1, 0, 1, 0, 1], jnp.int32)

        def prime():
            rt = c.init()
            return c.insert(rt, emb, vals, lens, 0.0, tenant_id=tid)

        _, rt_a = c.lookup(prime(), emb, 1.0, tenant_id=tid)
        rt = prime()
        peek, _ = c.lookup(rt, emb, 1.0, update_counters=False,
                           tenant_id=tid)
        _, rt_b = c.commit(rt, peek, 1.0, tenant_id=tid)
        for f in ("lookups", "hits"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rt_a.tenancy, f)),
                np.asarray(getattr(rt_b.tenancy, f)))
        assert int(rt_a.tenancy.lookups.sum()) == 6
