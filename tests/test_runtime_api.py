"""CacheRuntime API: protocol interchangeability, fused-step equivalence,
and full-runtime checkpointing (the PR-1 redesign's acceptance surface).

Covers:
  * Exact and IVF indexes driven through the *identical* Index-protocol
    call sequence — no isinstance branches anywhere in core/ or serving/
    (enforced by a source scan below);
  * ``SemanticCache.step`` (fused lookup+insert) vs separate lookup+insert:
    identical hits, scores, stats and subsequent behaviour;
  * ``CachedEngine(use_fused_step=...)``: both engine paths produce
    identical responses and counters;
  * checkpoint save/load round-trips the whole runtime — adaptive-threshold
    state and IVF index state survive a restart (no forced rebuild).
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveThreshold, CacheConfig, CacheRuntime,
                        ExactIndex, IVFIndex, Index, Policy, SemanticCache)

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def mk(dim=32, capacity=128, **kw):
    kw.setdefault("ttl", None)
    return CacheConfig(dim=dim, capacity=capacity, value_len=8, **kw)


def corpus(rng, n, dim):
    k1, k2 = jax.random.split(rng)
    emb = jax.random.normal(k1, (n, dim))
    vals = jax.random.randint(k2, (n, 8), 0, 100)
    return emb, vals, jnp.full((n,), 8)


INDEXES = [
    ExactIndex(topk=4, backend="jnp"),
    IVFIndex(ncentroids=8, nprobe=8, bucket_cap=64, topk=4),
]


class TestProtocolInterchangeability:
    @pytest.mark.parametrize("index", INDEXES, ids=["exact", "ivf"])
    def test_same_call_sequence_serves_hits(self, index):
        """One code path — init / step / refit / step — for every index."""
        cfg = mk()
        c = SemanticCache(cfg, index=index)
        assert isinstance(c.index, Index) and isinstance(c.policy, Policy)
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 16, cfg.dim)
        res, rt = c.step(rt, emb, vals, lens, 0.0)
        assert int(res.hit.sum()) == 0
        # absorbed into the index at insert: hits before any refit
        res, rt = c.step(rt, emb, vals, lens, 1.0)
        assert int(res.hit.sum()) == 16
        # refit is uniform (no-op for exact, k-means rebuild for IVF)
        rt = c.refit(rt, 1.0, jax.random.PRNGKey(1))
        res, rt = c.lookup(rt, emb, 2.0)
        assert int(res.hit.sum()) == 16
        np.testing.assert_allclose(np.asarray(res.score), 1.0, atol=1e-5)

    def test_ivf_recall_tracks_exact_after_refit(self):
        cfg = mk(dim=32, capacity=512)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 512, cfg.dim)
        queries = emb[:64] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (64, cfg.dim))
        hits = {}
        for name, index in [("exact", INDEXES[0]),
                            ("ivf", IVFIndex(ncentroids=16, nprobe=8,
                                             bucket_cap=128, topk=4))]:
            c = SemanticCache(cfg, index=index)
            rt = c.init()
            rt = c.insert(rt, emb, vals, lens, 0.0)
            rt = c.refit(rt, 0.0, jax.random.PRNGKey(2))
            res, rt = c.lookup(rt, queries, 1.0)
            hits[name] = int(res.hit.sum())
        assert hits["ivf"] >= 0.85 * hits["exact"], hits

    def test_ivf_absorb_scales_past_one_bucket_without_refit(self):
        """Regression: plain init/insert/lookup (no refit ever) must keep
        entries findable well past a single bucket's capacity — unfitted
        centroids are random, not zero, so absorb spreads across buckets."""
        cfg = mk(dim=32, capacity=256)
        c = SemanticCache(cfg, index=IVFIndex(ncentroids=16, nprobe=16,
                                              bucket_cap=16, topk=4))
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 128, cfg.dim)
        for i in range(0, 128, 16):   # 128 inserts >> one bucket's 16 slots
            rt = c.insert(rt, emb[i:i + 16], vals[i:i + 16],
                          lens[i:i + 16], float(i))
        res, rt = c.lookup(rt, emb, 200.0)
        hit_rate = float(res.hit.mean())
        assert hit_rate >= 0.9, hit_rate

    def test_runtime_is_one_jitable_pytree(self):
        cfg = mk()
        c = SemanticCache(cfg, index=INDEXES[1], policy=AdaptiveThreshold())
        rt = c.init()
        assert isinstance(rt, CacheRuntime)
        leaves, treedef = jax.tree_util.tree_flatten(rt)
        assert all(hasattr(x, "shape") for x in leaves)
        rt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 4, cfg.dim)
        jitted = jax.jit(lambda r, q, v, l, t: c.step(r, q, v, l, t))
        _, rt2 = jitted(rt2, emb, vals, lens, jnp.float32(0.0))
        assert int(rt2.stats.inserts) == 4

    def test_no_index_isinstance_branches_in_core_or_serving(self):
        """Acceptance criterion: one signature for all index types."""
        pat = re.compile(r"isinstance\([^)]*(IVFIndex|ExactIndex)")
        for sub in ("core", "serving"):
            for root, _dirs, files in os.walk(os.path.join(SRC, sub)):
                for f in files:
                    if not f.endswith(".py"):
                        continue
                    src = open(os.path.join(root, f)).read()
                    assert not pat.search(src), \
                        f"index isinstance branch in {sub}/{f}"


class TestFusedStepEquivalence:
    @pytest.mark.parametrize("index", INDEXES, ids=["exact", "ivf"])
    def test_step_equals_lookup_then_insert(self, index):
        cfg = mk()
        c = SemanticCache(cfg, index=index)
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 16, cfg.dim)
        warm, wvals, wlens = corpus(jax.random.PRNGKey(3), 8, cfg.dim)

        def prime():
            rt = c.init()
            rt = c.insert(rt, warm, wvals, wlens, 0.0)
            return rt

        # half the queries paraphrase warm entries -> mixed hit/miss batch
        queries = jnp.concatenate([
            warm + 0.01 * jax.random.normal(jax.random.PRNGKey(4),
                                            warm.shape),
            emb[:8]])
        mv = jnp.concatenate([wvals, vals[:8]])
        ml = jnp.concatenate([wlens, lens[:8]])

        res_f, rt_f = c.step(prime(), queries, mv, ml, 1.0)
        res_s, rt_s = c.lookup(prime(), queries, 1.0)
        rt_s = c.insert(rt_s, queries, mv, ml, 1.0, mask=~res_s.hit)

        np.testing.assert_array_equal(np.asarray(res_f.hit),
                                      np.asarray(res_s.hit))
        np.testing.assert_allclose(np.asarray(res_f.score),
                                   np.asarray(res_s.score), atol=1e-6)
        for field in ("lookups", "hits", "misses", "inserts"):
            assert int(getattr(rt_f.stats, field)) == \
                int(getattr(rt_s.stats, field)), field
        # both runtimes serve the same traffic identically afterwards
        ra, _ = c.lookup(rt_f, queries, 2.0)
        rb, _ = c.lookup(rt_s, queries, 2.0)
        np.testing.assert_array_equal(np.asarray(ra.hit), np.asarray(rb.hit))
        np.testing.assert_allclose(np.asarray(ra.score),
                                   np.asarray(rb.score), atol=1e-6)

    @pytest.mark.parametrize("index", INDEXES, ids=["exact", "ivf"])
    def test_peeked_step_equals_plain_step(self, index):
        """peek -> step(peeked=...) (the engine's single-search path) must
        match the self-searching step bit for bit."""
        cfg = mk()
        c = SemanticCache(cfg, index=index)
        warm, wvals, wlens = corpus(jax.random.PRNGKey(3), 8, cfg.dim)
        queries = jnp.concatenate([
            warm[:4] + 0.01 * jax.random.normal(jax.random.PRNGKey(4),
                                                (4, cfg.dim)),
            corpus(jax.random.PRNGKey(5), 4, cfg.dim)[0]])
        mv = jnp.concatenate([wvals[:4], wvals[4:]])
        ml = wlens

        def prime():
            rt = c.init()
            return c.insert(rt, warm, wvals, wlens, 0.0)

        res_a, rt_a = c.step(prime(), queries, mv, ml, 1.0)
        rt = prime()
        peek, _ = c.lookup(rt, queries, 1.0, update_counters=False)
        res_b, rt_b = c.step(rt, queries, mv, ml, 1.0, peeked=peek)

        np.testing.assert_array_equal(np.asarray(res_a.hit),
                                      np.asarray(res_b.hit))
        np.testing.assert_allclose(np.asarray(res_a.score),
                                   np.asarray(res_b.score), atol=0)
        for a, b in zip(jax.tree_util.tree_leaves(rt_a),
                        jax.tree_util.tree_leaves(rt_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_engine_fused_and_separate_paths_identical(self):
        """Satellite: use_fused_step is real — both paths give one answer."""
        from repro.data.qa_dataset import build_corpus, build_test_queries
        from repro.serving import CachedEngine, Request, SimulatedLLMBackend
        pairs = build_corpus(100, seed=0)
        queries = build_test_queries(pairs, n_per_category=20, seed=1)
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries]

        results = {}
        for fused in (True, False):
            eng = CachedEngine(
                mk(dim=384, capacity=2048), SimulatedLLMBackend(pairs),
                batch_size=32, use_fused_step=fused)
            eng.warm(pairs[:50])
            resp = eng.process(reqs)
            results[fused] = (
                [(r.answer, r.cached, round(r.score, 5)) for r in resp],
                int(eng.stats.lookups), int(eng.stats.hits),
                int(eng.stats.inserts), eng.backend.calls)
        assert results[True] == results[False]


class TestRuntimeCheckpoint:
    def test_engine_restart_with_adaptive_ivf_resumes(self, tmp_path):
        """Acceptance criterion: a restarted engine with adaptive policy +
        IVF index resumes with identical policy_state and serves hits with
        no forced rebuild."""
        from repro.data.qa_dataset import build_corpus, build_test_queries
        from repro.serving import CachedEngine, Request, SimulatedLLMBackend
        pairs = build_corpus(100, seed=0)
        queries = build_test_queries(pairs, n_per_category=20, seed=1)
        by_id = {p.qa_id: p for p in pairs}

        def judge(req, sid):
            return sid >= 0 and sid in by_id and \
                by_id[sid].semantic_key == req.semantic_key

        def make(**kw):
            return CachedEngine(
                mk(dim=384, capacity=2048, threshold=0.7),
                SimulatedLLMBackend(pairs), judge=judge, batch_size=32,
                index=IVFIndex(ncentroids=16, nprobe=8, bucket_cap=256,
                               topk=4),
                policy=AdaptiveThreshold(init=0.7, lr=0.05, ema=0.5), **kw)

        eng = make()
        eng.warm(pairs)
        reqs = [Request(query=q.query, category=q.category,
                        source_id=q.source_id, semantic_key=q.semantic_key)
                for q in queries]
        eng.process(reqs)   # adapts the threshold, refits the IVF index
        path = os.path.join(str(tmp_path), "runtime.npz")
        eng.save_cache(path)

        eng2 = make()
        eng2.load_cache(path)
        # identical policy state (satellite: previously silently dropped)
        np.testing.assert_array_equal(np.asarray(eng.policy_state),
                                      np.asarray(eng2.policy_state))
        # identical index state: restored runtime needs no forced rebuild
        for a, b in zip(
                jax.tree_util.tree_leaves(eng.runtime.index_state),
                jax.tree_util.tree_leaves(eng2.runtime.index_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not eng2._needs_refit
        resp = eng2.process(reqs[:32])
        assert sum(r.cached for r in resp) >= 8
        # no refit ran during serving (rebuild counter untouched since load)
        assert eng2._inserts_since_rebuild == \
            sum(not r.cached for r in resp)

    def test_restart_restores_ttl_clock(self, tmp_path):
        """Regression: expiries are absolute deadlines — reloading at now=0
        would extend every entry's remaining TTL."""
        from repro.data.qa_dataset import build_corpus
        from repro.serving import CachedEngine, Request, SimulatedLLMBackend
        pairs = build_corpus(40, seed=0)
        mk_eng = lambda: CachedEngine(
            mk(dim=384, capacity=512, ttl=60.0),
            SimulatedLLMBackend(pairs), batch_size=8)
        eng = mk_eng()
        eng.tick(5000.0)
        q = Request(query="does the blender come with a warranty")
        eng.process([q])                      # inserted at t=5000, expires 5060
        path = os.path.join(str(tmp_path), "clock.npz")
        eng.save_cache(path)

        eng2 = mk_eng()
        eng2.load_cache(path)
        assert eng2._now == 5000.0            # clock restored from metadata
        assert eng2.process([q])[0].cached    # still inside TTL
        eng2.tick(61.0)
        assert not eng2.process([q])[0].cached  # expired on schedule

        # regression: a snapshot path WITHOUT the .npz suffix (np.savez adds
        # it to the data file only; the manifest keeps the raw name)
        bare = os.path.join(str(tmp_path), "clock_bare")
        eng.save_cache(bare)
        eng3 = mk_eng()
        eng3.load_cache(bare)
        assert eng3._now == 5000.0

    def test_raw_runtime_roundtrip_preserves_every_leaf(self, tmp_path):
        from repro.training.checkpoint import (load_checkpoint,
                                               save_checkpoint)
        cfg = mk()
        c = SemanticCache(cfg, index=INDEXES[1],
                          policy=AdaptiveThreshold())
        rt = c.init()
        emb, vals, lens = corpus(jax.random.PRNGKey(0), 16, cfg.dim)
        _, rt = c.step(rt, emb, vals, lens, 0.0)
        rt = c.refit(rt, 0.0, jax.random.PRNGKey(1))
        path = os.path.join(str(tmp_path), "rt.npz")
        save_checkpoint(path, rt)
        restored = load_checkpoint(path, c.init())
        for a, b in zip(jax.tree_util.tree_leaves(rt),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
