"""Resilient-serving tests (DESIGN.md §20): deterministic fault injection,
retry/deadline budgets, circuit breaker, degraded-mode cache serving, load
shedding, and the no-fault byte-parity guarantee."""
import asyncio
import os
import tempfile

import pytest

from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.generative.policy import BandPolicy
from repro.obs.export import REQUIRED_FAMILIES, MetricsExporter
from repro.serving import (AsyncCacheServer, BackendTimeout,
                           BackendUnavailable, CachedEngine, CircuitBreaker,
                           FaultSchedule, FaultWindow, FaultyBackend,
                           Overloaded, Request, ResilienceConfig, Response,
                           RetryPolicy, SchedulerConfig, SimulatedLLMBackend,
                           availability)
from repro.training.checkpoint import CheckpointCorruptError


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(120, seed=0)


def noop_sleep(s):
    pass


# every backend call faults — the outage never ends
ALL_ERRORS = FaultSchedule((FaultWindow("error", 0, 10_000),))

NOVEL = [
    "how do ion thrusters achieve specific impulse",
    "what is the halting problem in plain words",
    "why do violins have f-shaped sound holes",
    "explain how a heat pump beats resistive heating",
]


def make_engine(pairs, *, schedule=None, resilience=None, batch_size=8,
                latency_s=0.0, block=False, **kw):
    backend = SimulatedLLMBackend(pairs, latency_per_call_s=latency_s,
                                  block=block)
    if schedule is not None:
        backend = FaultyBackend(backend, schedule)
    cfg = kw.pop("config", CacheConfig(dim=384, capacity=4096, value_len=48,
                                       ttl=None, threshold=0.8))
    return CachedEngine(cfg, backend, batch_size=batch_size,
                        resilience=resilience, **kw)


class TestFaultSchedule:
    def test_window_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultWindow("meteor", 0, 5)
        with pytest.raises(ValueError, match="empty fault window"):
            FaultWindow("error", 5, 5)
        with pytest.raises(ValueError, match="error_rate"):
            FaultWindow("brownout", 0, 5, error_rate=1.5)
        with pytest.raises(ValueError, match="extra_latency_s"):
            FaultWindow("latency_spike", 0, 5, extra_latency_s=-1.0)

    def test_fault_at_is_deterministic(self):
        sched = FaultSchedule((FaultWindow("brownout", 0, 50, error_rate=0.5),),
                              seed=7)
        first = [sched.fault_at(i) is not None for i in range(50)]
        second = [sched.fault_at(i) is not None for i in range(50)]
        assert first == second
        # a 0.5 brownout over 50 indexes both fires and skips
        assert any(first) and not all(first)
        # a different seed flips at least one coin
        other = FaultSchedule(sched.windows, seed=8)
        assert first != [other.fault_at(i) is not None for i in range(50)]

    def test_outside_window_is_healthy(self):
        sched = FaultSchedule((FaultWindow("error", 3, 5),))
        assert sched.fault_at(2) is None
        assert sched.fault_at(3) is not None
        assert sched.fault_at(5) is None


class TestFaultyBackend:
    def test_error_and_timeout_kinds(self, pairs):
        fb = FaultyBackend(SimulatedLLMBackend(pairs), FaultSchedule((
            FaultWindow("error", 0, 1), FaultWindow("timeout", 1, 2))))
        with pytest.raises(BackendUnavailable, match="injected error: call 0"):
            fb.generate(["q"])
        with pytest.raises(BackendTimeout, match="injected timeout: call 1"):
            fb.generate(["q"])
        assert fb.calls_started == 2
        assert fb.faults_injected == 2
        assert fb.inner.calls == 0      # faults never reach the backend

    def test_latency_spike_taxes_but_serves(self, pairs):
        fb = FaultyBackend(
            SimulatedLLMBackend(pairs, latency_per_call_s=0.01),
            FaultSchedule((FaultWindow("latency_spike", 0, 1,
                                       extra_latency_s=0.5),)))
        spiked = fb.generate([pairs[0].question])
        healthy = fb.generate([pairs[0].question])
        assert spiked.answers == healthy.answers
        assert spiked.latency_s == pytest.approx(healthy.latency_s + 0.5)
        assert fb.faults_injected == 0   # a spike is a tax, not a fault

    def test_attribute_delegation(self, pairs):
        inner = SimulatedLLMBackend(pairs, latency_per_call_s=0.25)
        fb = FaultyBackend(inner, FaultSchedule())
        assert fb.latency_per_call_s == 0.25
        fb.generate(["q"])
        assert fb.calls == inner.calls == 1


class TestRetryPolicy:
    def test_backoff_exponential_and_capped(self):
        p = RetryPolicy(base_backoff_s=0.1, multiplier=2.0, max_backoff_s=0.3,
                        jitter=0.0)
        assert p.backoff_s(1) == pytest.approx(0.1)
        assert p.backoff_s(2) == pytest.approx(0.2)
        assert p.backoff_s(3) == pytest.approx(0.3)   # capped
        assert p.backoff_s(9) == pytest.approx(0.3)

    def test_jitter_bounded_and_deterministic(self):
        p = RetryPolicy(base_backoff_s=0.1, multiplier=1.0, jitter=0.5, seed=3)
        delays = [p.backoff_s(a, key="some query") for a in range(1, 6)]
        assert delays == [p.backoff_s(a, key="some query")
                          for a in range(1, 6)]
        for d in delays:
            assert 0.05 <= d <= 0.15
        assert len(set(delays)) > 1      # jitter actually varies by attempt

    def test_allows_attempt_cap_and_budget(self):
        p = RetryPolicy(max_attempts=3)
        assert p.allows(1, elapsed_s=0.0, next_backoff_s=0.0)
        assert not p.allows(3, elapsed_s=0.0, next_backoff_s=0.0)
        # the next backoff would overrun the remaining SLO: denied
        assert not p.allows(1, elapsed_s=0.02, next_backoff_s=0.04,
                            budget_s=0.05)
        assert p.allows(1, elapsed_s=0.02, next_backoff_s=0.01, budget_s=0.05)

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(multiplier=0.5)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=1.0)


class TestCircuitBreaker:
    def test_consecutive_failures_trip(self):
        b = CircuitBreaker(failure_threshold=3, window=100)
        b.record_failure()
        b.record_failure()
        assert b.state == "closed"
        b.record_failure()
        assert b.state == "open"
        assert b.trips == 1

    def test_error_rate_trips_only_with_full_window(self):
        b = CircuitBreaker(failure_threshold=10, window=4,
                           error_rate_threshold=0.5)
        # 2/3 failures but the window is not full yet: no trip
        b.record_failure()
        b.record_success()
        b.record_failure()
        assert b.state == "closed"
        b.record_failure()               # window full, 3/4 >= 0.5
        assert b.state == "open"

    def test_open_half_open_closed_lifecycle(self):
        t = [0.0]
        b = CircuitBreaker(failure_threshold=1, cooldown_s=5.0,
                           clock=lambda: t[0])
        b.record_failure()
        assert b.state == "open" and b.trips == 1
        assert not b.allow()             # cooldown not elapsed
        assert b.short_circuits == 1
        t[0] = 5.0
        assert b.allow()                 # half-open probe admitted
        assert b.state == "half_open"
        b.record_success()
        assert b.state == "closed" and b.recoveries == 1
        # a failed probe re-trips instead of recovering
        b.record_failure()
        t[0] = 10.0
        assert b.allow()
        b.record_failure()
        assert b.state == "open" and b.trips == 3

    def test_validation(self):
        with pytest.raises(ValueError):
            CircuitBreaker(failure_threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(error_rate_threshold=0.0)
        with pytest.raises(ValueError):
            CircuitBreaker(half_open_probes=0)


class TestBandPolicyDegradedLo:
    def test_degraded_lo_stored(self):
        p = BandPolicy(tau_lo=0.70, tau_hi=0.80, degraded_lo=0.60)
        assert p.degraded_lo == 0.60

    def test_degraded_lo_must_relax_not_tighten(self):
        with pytest.raises(ValueError, match="must not exceed tau_lo"):
            BandPolicy(tau_lo=0.70, tau_hi=0.80, degraded_lo=0.75)
        with pytest.raises(ValueError):
            BandPolicy(tau_lo=0.70, tau_hi=0.80, degraded_lo=1.5)


class TestFailureContainment:
    def test_hit_rows_survive_a_failed_backend_call(self, pairs):
        # satellite: NO resilience config — containment alone must keep a
        # batch's hit rows serving when the miss rows' backend call throws
        eng = make_engine(pairs, schedule=ALL_ERRORS)
        eng.warm(pairs)
        hit, miss = eng.process([Request(query=pairs[0].question),
                                 Request(query=NOVEL[0])])
        assert hit.cached and hit.error == "" and hit.answer
        assert miss.error != "" and miss.answer == "" and not miss.degraded
        assert eng.metrics.resilience.backend_failures == 1
        assert eng.metrics.resilience_seen


class TestEngineRetries:
    def test_retry_recovers_after_transient_fault(self, pairs):
        sched = FaultSchedule((FaultWindow("error", 0, 1),))
        res = ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0, jitter=0.0),
            breaker=None, sleep=noop_sleep)
        eng = make_engine(pairs, schedule=sched, resilience=res)
        r = eng.process([Request(query=NOVEL[0])])[0]
        assert r.error == "" and not r.degraded and r.answer
        rm = eng.metrics.resilience
        assert rm.backend_failures == 1
        assert rm.retries == 1
        assert rm.retry_successes == 1
        assert eng.backend.calls_started == 2

    def test_deadline_budget_blocks_the_retry(self, pairs):
        res = ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, base_backoff_s=10.0,
                              jitter=0.0),
            breaker=None, degraded_serving=False, sleep=noop_sleep)
        eng = make_engine(pairs, schedule=ALL_ERRORS, resilience=res)
        r = eng.process([Request(query=NOVEL[0], deadline_ms=50.0)])[0]
        assert r.error != ""
        rm = eng.metrics.resilience
        assert rm.retries == 0           # the 10s backoff never fit in 50ms
        assert rm.deadline_exhausted == 1
        assert eng.backend.calls_started == 1

    def test_spent_deadline_fails_fast_without_a_call(self, pairs):
        res = ResilienceConfig(retry=RetryPolicy(), breaker=None,
                               degraded_serving=False, sleep=noop_sleep)
        eng = make_engine(pairs, schedule=FaultSchedule(), resilience=res)
        r = eng.process([Request(query=NOVEL[0], deadline_ms=0.0)])[0]
        assert "DeadlineExhausted" in r.error
        assert eng.backend.calls_started == 0
        assert eng.metrics.resilience.deadline_exhausted == 1


class TestBreakerInEngine:
    def test_open_breaker_short_circuits_the_backend(self, pairs):
        res = ResilienceConfig(
            retry=RetryPolicy(max_attempts=3, base_backoff_s=0.0, jitter=0.0),
            breaker=CircuitBreaker(failure_threshold=1, cooldown_s=1000.0),
            degraded_serving=False, sleep=noop_sleep)
        eng = make_engine(pairs, schedule=ALL_ERRORS, resilience=res)
        r1 = eng.process([Request(query=NOVEL[0])])[0]
        assert r1.error != ""
        assert res.breaker.state == "open"
        assert eng.backend.calls_started == 1     # trip killed the retries
        rm = eng.metrics.resilience
        assert rm.breaker_short_circuits >= 1
        # next batch never touches the backend at all
        r2 = eng.process([Request(query=NOVEL[1])])[0]
        assert "BreakerOpen" in r2.error
        assert eng.backend.calls_started == 1


class TestDegradedServing:
    def test_serves_best_neighbour_and_never_admits(self, pairs):
        sched = FaultSchedule((FaultWindow("error", 0, 1),))
        res = ResilienceConfig(retry=RetryPolicy(max_attempts=1),
                               breaker=None, degraded_band_lo=0.0,
                               sleep=noop_sleep)
        eng = make_engine(pairs, schedule=sched, resilience=res)
        eng.warm(pairs)
        inserts_before = int(eng.stats.inserts)
        r1 = eng.process([Request(query=NOVEL[0])])[0]
        assert r1.degraded and r1.answer != "" and r1.error == ""
        assert not r1.cached
        assert eng.metrics.resilience.degraded_served == 1
        # the degraded answer was NOT admitted to the slab (§20.4) ...
        assert int(eng.stats.inserts) == inserts_before
        # ... so once the outage clears, the same query is a real miss that
        # pays the backend and gets its own, non-degraded answer
        r2 = eng.process([Request(query=NOVEL[0])])[0]
        assert not r2.degraded and not r2.cached and r2.answer
        assert eng.backend.calls_started == 2

    def test_cold_cache_has_nothing_to_degrade_to(self, pairs):
        res = ResilienceConfig(retry=RetryPolicy(max_attempts=1),
                               breaker=None, degraded_band_lo=0.0,
                               sleep=noop_sleep)
        eng = make_engine(pairs, schedule=ALL_ERRORS, resilience=res)
        r = eng.process([Request(query=NOVEL[0])])[0]
        assert r.error != "" and not r.degraded
        assert eng.metrics.resilience.degraded_failed == 1


class TestNoFaultParity:
    def test_resilient_engine_matches_plain_engine_bit_for_bit(self, pairs):
        reqs = [Request(query=p.question) for p in pairs[:16]] \
            + [Request(query=q) for q in NOVEL] \
            + [Request(query=p.question) for p in pairs[8:24]]

        def run(resilience, schedule):
            eng = make_engine(pairs, schedule=schedule, resilience=resilience)
            eng.warm(pairs[:40])
            return eng, eng.process(list(reqs))

        plain_eng, plain = run(None, None)
        res = ResilienceConfig(sleep=noop_sleep)
        res_eng, resilient = run(res, FaultSchedule())   # no fault windows
        assert res_eng.backend.faults_injected == 0
        for a, b in zip(plain, resilient):
            assert (a.answer, a.cached, a.score, a.near_hit, a.context,
                    a.degraded, a.error) == \
                   (b.answer, b.cached, b.score, b.near_hit, b.context,
                    b.degraded, b.error)


class TestOverloadShedding:
    def test_shed_policy_rejects_loudly_and_strands_nothing(self, pairs):
        eng = make_engine(pairs, latency_s=0.2, block=True, batch_size=1)
        eng.serve_batch([Request(query="compile warmup")])

        async def flood():
            sched = SchedulerConfig(max_batch=1, max_wait_ms=1.0, max_queue=1,
                                    coalesce=False, overload_policy="shed")
            async with AsyncCacheServer(eng, sched) as server:
                return await asyncio.gather(
                    *(server.submit(q) for q in NOVEL),
                    return_exceptions=True)

        results = asyncio.run(flood())
        assert len(results) == 4
        sheds = [r for r in results if isinstance(r, Overloaded)]
        served = [r for r in results if isinstance(r, Response)]
        assert len(sheds) >= 1
        assert len(sheds) + len(served) == 4      # nothing stranded or lost
        assert eng.metrics.resilience.shed == len(sheds)
        for r in sheds:
            assert "load shed" in str(r)
        for r in served:
            assert r.answer and r.error == ""

    def test_overload_policy_validation(self):
        with pytest.raises(ValueError):
            SchedulerConfig(overload_policy="panic")

    def test_availability_helper(self):
        ok = Response(answer="a", cached=True, score=0.9, latency_s=0.0)
        deg = Response(answer="b", cached=False, score=0.6, latency_s=0.0,
                       degraded=True)
        bad = Response(answer="", cached=False, score=0.1, latency_s=0.0,
                       error="BackendUnavailable: injected")
        assert availability([]) == 0.0
        assert availability([ok, deg, bad, Overloaded("queue full")]) \
            == pytest.approx(0.5)


class TestPrometheusFamilies:
    def test_resilient_engine_exports_the_fault_plane(self, pairs):
        res = ResilienceConfig(sleep=noop_sleep)
        eng = make_engine(pairs, schedule=FaultSchedule(), resilience=res)
        eng.process([Request(query=NOVEL[0])])
        text = MetricsExporter(eng).render()
        for fam in ("repro_backend_retries_total",
                    "repro_breaker_transitions_total",
                    "repro_degraded_served_total"):
            assert fam in text
        assert "repro_breaker_state 0" in text   # closed

    def test_plain_engine_still_serves_every_required_family(self, pairs):
        eng = make_engine(pairs)
        eng.process([Request(query=NOVEL[0])])
        text = MetricsExporter(eng).render()
        for fam in REQUIRED_FAMILIES:
            assert fam in text, fam
        # the breaker gauge is gated on an installed breaker
        assert "repro_breaker_state" not in text


class TestCrashSafeCheckpoints:
    def test_truncated_cache_file_is_rejected_loudly(self, pairs):
        eng = make_engine(pairs)
        eng.warm(pairs[:20])
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "cache.npz")
            eng.save_cache(path)
            # atomic write: no temp litter survives a successful save
            assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
            blob = open(path, "rb").read()
            with open(path, "wb") as f:
                f.write(blob[:len(blob) // 2])
            eng2 = make_engine(pairs)
            with pytest.raises(CheckpointCorruptError, match="cache.npz"):
                eng2.load_cache(path)
