"""Optimizer, schedule, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (AdamWConfig, CheckpointCorruptError, adamw_update,
                            global_norm, init_adamw, load_checkpoint, lr_at,
                            open_checkpoint, save_checkpoint)


def quad_loss(params, target):
    return jnp.sum(jnp.square(params["w"] - target)) + \
        jnp.sum(jnp.square(params["b"]))


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        target = jnp.ones((4, 4)) * 3.0
        opt = init_adamw(params)
        for _ in range(150):
            loss, grads = jax.value_and_grad(quad_loss)(params, target)
            params, opt, _ = adamw_update(cfg, params, grads, opt)
        assert float(quad_loss(params, target)) < 0.1

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros((2, 2))}
        grads = {"w": jnp.full((2, 2), 1e6)}
        opt = init_adamw(params)
        _, _, metrics = adamw_update(cfg, params, grads, opt)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_weight_decay_only_on_matrices(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        grads = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        opt = init_adamw(params)
        new, _, _ = adamw_update(cfg, params, grads, opt)
        assert float(jnp.max(jnp.abs(new["w"]))) < 1.0   # decayed
        np.testing.assert_allclose(np.asarray(new["b"]), 1.0)  # not decayed

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(1.0, abs=0.05)
        assert lrs[-1] == pytest.approx(0.1, abs=0.02)
        assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)


class TestCheckpoint:
    def test_roundtrip_nested(self):
        tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                          "b": jnp.ones((3,))},
                "step": jnp.asarray(7)}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_checkpoint(p, tree, metadata={"note": "test"})
            restored = load_checkpoint(p, jax.tree_util.tree_map(
                jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                      np.asarray(tree["layer"]["w"]))
        assert int(restored["step"]) == 7

    def test_shape_mismatch_raises(self):
        tree = {"w": jnp.ones((2, 2))}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_checkpoint(p, tree)
            bad = {"w": jnp.ones((3, 3))}
            with pytest.raises(ValueError):
                load_checkpoint(p, bad)

    def test_cache_runtime_checkpoint(self):
        """The Redis-persistence analogue: the *whole* CacheRuntime (slab +
        stats + policy + index state) round-trips as one pytree."""
        from repro.core import CacheConfig, SemanticCache
        import jax.random as jr
        c = SemanticCache(CacheConfig(dim=8, capacity=16, value_len=4))
        rt = c.init()
        emb = jr.normal(jr.PRNGKey(0), (4, 8))
        vals = jnp.arange(16).reshape(4, 4)
        rt = c.insert(rt, emb, vals, jnp.full((4,), 4), 0.0)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "cache.npz")
            save_checkpoint(p, rt)
            restored = load_checkpoint(p, jax.tree_util.tree_map(
                jnp.zeros_like, rt))
        res, _ = c.lookup(restored, emb, 1.0)
        assert bool(jnp.all(res.hit))
        assert int(restored.stats.inserts) == 4

    def test_save_is_atomic_no_tmp_litter(self):
        """Crash-safe writes (§20.6): the npz and manifest are staged to
        ``.tmp`` siblings and os.replace'd in — a successful save leaves no
        temp files, and the final paths exist."""
        tree = {"w": jnp.ones((4, 4))}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_checkpoint(p, tree, metadata={"note": "atomic"})
            names = sorted(os.listdir(d))
            assert not [n for n in names if n.endswith(".tmp")], names
            assert "ck.npz" in names and "ck.npz.manifest.json" in names

    def test_truncated_checkpoint_rejected_loudly(self):
        """A partially-written (chopped mid-file) checkpoint must raise
        CheckpointCorruptError naming the file — not a bare zipfile/EOF
        error, and never a silently-garbage tree."""
        tree = {"layer": {"w": jnp.arange(64.0).reshape(8, 8)},
                "step": jnp.asarray(3)}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_checkpoint(p, tree)
            blob = open(p, "rb").read()
            for frac in (0.5, 0.9):       # chop mid-archive and mid-member
                with open(p, "wb") as f:
                    f.write(blob[:int(len(blob) * frac)])
                with pytest.raises(CheckpointCorruptError, match="ck.npz"):
                    open_checkpoint(p)
                with pytest.raises(CheckpointCorruptError):
                    load_checkpoint(p, jax.tree_util.tree_map(
                        jnp.zeros_like, tree))

    def test_missing_key_is_a_corrupt_checkpoint(self):
        tree = {"w": jnp.ones((2, 2))}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "ck.npz")
            save_checkpoint(p, tree)
            with pytest.raises(CheckpointCorruptError):
                load_checkpoint(p, {"w": jnp.ones((2, 2)),
                                    "extra": jnp.ones((2,))})


class TestTrainSmallModel:
    @pytest.mark.slow
    def test_loss_decreases_100m_scale_family(self):
        """A few steps of real training on a reduced arch: loss must drop."""
        from repro.configs import get_arch
        from repro.models.model import Model
        cfg = get_arch("deepseek-7b").reduced()
        m = Model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        opt = init_adamw(params)
        ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=50)
        data_rng = jax.random.PRNGKey(42)

        @jax.jit
        def step(params, opt, tokens):
            loss, grads = jax.value_and_grad(
                lambda p: m.loss_fn(p, tokens, remat=False))(params)
            params, opt, _ = adamw_update(ocfg, params, grads, opt)
            return params, opt, loss

        # memorize a tiny corpus: loss must drop substantially
        tokens = jax.random.randint(data_rng, (4, 64), 0, cfg.vocab)
        losses = []
        for _ in range(30):
            params, opt, loss = step(params, opt, tokens)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 1.0, losses[::10]
