"""Unit + property tests for the slab store: insert / TTL / eviction.

The hypothesis suite (skipped gracefully when hypothesis is absent — see
``_hypothesis_compat``) drives random operation sequences against the store
and asserts the Redis-analogue invariants: capacity is never exceeded,
expired entries never serve lookups, FIFO/LRU/LFU eviction picks the right
victims, inserted entries are immediately retrievable. All cache state is
one ``CacheRuntime`` pytree threaded through the pure API.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import CacheConfig, SemanticCache
from repro.core import store


def mk(capacity=16, dim=8, ttl=100.0, eviction="ring", threshold=0.8):
    return CacheConfig(dim=dim, capacity=capacity, value_len=4, ttl=ttl,
                       threshold=threshold, eviction=eviction)


def rand_batch(rng, b, dim):
    k1, k2 = jax.random.split(rng)
    emb = jax.random.normal(k1, (b, dim))
    vals = jax.random.randint(k2, (b, 4), 0, 100)
    return emb, vals, jnp.full((b,), 4)


class TestInsert:
    def test_insert_then_lookup_hits(self):
        cfg = mk()
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        res, rt = c.lookup(rt, emb, 1.0)
        assert bool(jnp.all(res.hit))
        np.testing.assert_allclose(np.asarray(res.score), 1.0, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(res.values),
                                      np.asarray(vals))

    def test_empty_cache_never_hits(self):
        cfg = mk()
        c = SemanticCache(cfg)
        rt = c.init()
        emb, _, _ = rand_batch(jax.random.PRNGKey(1), 3, cfg.dim)
        res, _ = c.lookup(rt, emb, 0.0)
        assert not bool(jnp.any(res.hit))
        assert bool(jnp.all(res.score == -jnp.inf))

    def test_masked_insert_skips_rows(self):
        cfg = mk()
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(2), 4, cfg.dim)
        mask = jnp.asarray([True, False, True, False])
        rt = c.insert(rt, emb, vals, lens, 0.0, mask=mask)
        res, _ = c.lookup(rt, emb, 1.0)
        assert bool(res.hit[0]) and bool(res.hit[2])
        assert not bool(res.hit[1]) and not bool(res.hit[3])

    def test_value_roundtrip_dtype(self):
        cfg = mk()
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(3), 2, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        assert rt.state.values.dtype == jnp.int32


class TestTTL:
    def test_expiry_blocks_hits(self):
        cfg = mk(ttl=10.0)
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(0), 2, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        res, _ = c.lookup(rt, emb, 9.9)
        assert bool(jnp.all(res.hit))
        res, _ = c.lookup(rt, emb, 10.1)
        assert not bool(jnp.any(res.hit))

    def test_eager_expire_counts(self):
        cfg = mk(ttl=10.0)
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        rt = c.expire(rt, 11.0)
        assert int(rt.stats.expired_evictions) == 4
        assert not bool(jnp.any(rt.state.valid))

    def test_no_ttl_never_expires(self):
        cfg = mk(ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(0), 2, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        res, _ = c.lookup(rt, emb, 1e12)
        assert bool(jnp.all(res.hit))

    @settings(max_examples=25, deadline=None)
    @given(st.floats(0.1, 1e5), st.floats(0.0, 2.0))
    def test_alive_monotone_in_time(self, ttl, frac):
        """Property: aliveness is monotone non-increasing in time."""
        cfg = mk(ttl=ttl)
        c = SemanticCache(cfg)
        rt = c.init()
        emb, vals, lens = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, emb, vals, lens, 0.0)
        t = ttl * frac
        alive_t = int(jnp.sum(store.alive_mask(rt.state, t)))
        alive_later = int(jnp.sum(store.alive_mask(rt.state, t + 1.0)))
        assert alive_later <= alive_t


class TestEviction:
    @pytest.mark.parametrize("eviction", ["ring", "lru", "lfu"])
    def test_capacity_never_exceeded(self, eviction):
        cfg = mk(capacity=8, eviction=eviction, ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        for i in range(5):
            emb, vals, lens = rand_batch(jax.random.PRNGKey(i), 4, cfg.dim)
            rt = c.insert(rt, emb, vals, lens, float(i))
        assert int(jnp.sum(rt.state.valid)) <= cfg.capacity

    def test_ring_overwrites_oldest(self):
        cfg = mk(capacity=4, eviction="ring", ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        e1, v1, l1 = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, e1, v1, l1, 0.0)
        e2, v2, l2 = rand_batch(jax.random.PRNGKey(1), 2, cfg.dim)
        rt = c.insert(rt, e2, v2, l2, 1.0)
        # the first two of e1 were overwritten
        res, _ = c.lookup(rt, e1, 2.0)
        hits = np.asarray(res.hit)
        assert not hits[0] and not hits[1] and hits[2] and hits[3]

    def test_lru_evicts_least_recently_used(self):
        cfg = mk(capacity=4, eviction="lru", ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        e1, v1, l1 = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, e1, v1, l1, 0.0)
        # touch rows 0 and 1 (lookup hits bump last_used)
        res, rt = c.lookup(rt, e1[:2], 5.0)
        assert bool(jnp.all(res.hit))
        e2, v2, l2 = rand_batch(jax.random.PRNGKey(1), 2, cfg.dim)
        rt = c.insert(rt, e2, v2, l2, 6.0)
        res, _ = c.lookup(rt, e1, 7.0)
        hits = np.asarray(res.hit)
        assert hits[0] and hits[1]          # recently used survived
        assert not hits[2] and not hits[3]  # LRU victims

    def test_lfu_evicts_least_frequent(self):
        cfg = mk(capacity=4, eviction="lfu", ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        e1, v1, l1 = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, e1, v1, l1, 0.0)
        for _ in range(3):   # rows 2,3 get frequent hits
            _, rt = c.lookup(rt, e1[2:], 1.0)
        e2, v2, l2 = rand_batch(jax.random.PRNGKey(1), 2, cfg.dim)
        rt = c.insert(rt, e2, v2, l2, 2.0)
        res, _ = c.lookup(rt, e1, 3.0)
        hits = np.asarray(res.hit)
        assert hits[2] and hits[3]
        assert not hits[0] and not hits[1]

    def test_masked_ring_insert_packs_written_rows(self):
        """Regression: a masked ring insert (the fused step's mask=~hit)
        must pack written rows contiguously from ptr — scattered slots let
        the *next* batch clobber entries inserted one batch earlier."""
        cfg = mk(capacity=16, eviction="ring", ttl=None)
        c = SemanticCache(cfg)
        rt = c.init()
        e1, v1, l1 = rand_batch(jax.random.PRNGKey(0), 4, cfg.dim)
        rt = c.insert(rt, e1, v1, l1, 0.0,
                      mask=jnp.asarray([False, True, False, True]))
        e2, v2, l2 = rand_batch(jax.random.PRNGKey(1), 4, cfg.dim)
        rt = c.insert(rt, e2, v2, l2, 1.0)   # all-miss batch right after
        res, _ = c.lookup(rt, e1, 2.0)       # batch-1 inserts must survive
        hits = np.asarray(res.hit)
        assert hits[1] and hits[3], hits
        assert not hits[0] and not hits[2]
        res2, _ = c.lookup(rt, e2, 2.0)
        assert bool(jnp.all(res2.hit))
        # no holes: 2 + 4 entries occupy exactly 6 slots
        assert int(jnp.sum(rt.state.valid)) == 6

    def test_expired_slots_preferred_over_live(self):
        cfg = mk(capacity=4, eviction="lru", ttl=10.0)
        c = SemanticCache(cfg)
        rt = c.init()
        e1, v1, l1 = rand_batch(jax.random.PRNGKey(0), 2, cfg.dim)
        rt = c.insert(rt, e1, v1, l1, 0.0)   # expire at 10
        e2, v2, l2 = rand_batch(jax.random.PRNGKey(1), 2, cfg.dim)
        rt = c.insert(rt, e2, v2, l2, 50.0)  # fresh
        e3, v3, l3 = rand_batch(jax.random.PRNGKey(2), 2, cfg.dim)
        rt = c.insert(rt, e3, v3, l3, 51.0)
        res, _ = c.lookup(rt, e2, 52.0)
        assert bool(jnp.all(res.hit)), "live entries must not be evicted " \
                                       "while expired slots exist"


class TestPropertyOps:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["insert", "lookup", "expire"]),
                              st.integers(1, 4)), min_size=1, max_size=12))
    def test_random_op_sequences_keep_invariants(self, ops):
        cfg = mk(capacity=8, ttl=5.0)
        c = SemanticCache(cfg)
        rt = c.init()
        now = 0.0
        rng = jax.random.PRNGKey(0)
        for i, (op, b) in enumerate(ops):
            rng, k = jax.random.split(rng)
            now += 1.0
            if op == "insert":
                emb, vals, lens = rand_batch(k, b, cfg.dim)
                rt = c.insert(rt, emb, vals, lens, now)
            elif op == "lookup":
                emb, _, _ = rand_batch(k, b, cfg.dim)
                _, rt = c.lookup(rt, emb, now)
            else:
                rt = c.expire(rt, now)
            # invariants
            assert int(jnp.sum(rt.state.valid)) <= cfg.capacity
            assert 0 <= int(rt.state.ptr) < cfg.capacity
            assert int(rt.stats.hits) + int(rt.stats.misses) == \
                int(rt.stats.lookups)
            alive = store.alive_mask(rt.state, now)
            assert bool(jnp.all(rt.state.expiry[alive] > now))


class TestSoak:
    """Sustained-traffic churn: TTL expiry + eviction + lookups interleaved
    over many batches must hold every invariant (the long-running-service
    regime the paper's TTL design targets)."""

    def test_churn_with_ttl_and_eviction(self):
        cfg = mk(capacity=64, dim=32, ttl=8.0, eviction="lru")
        c = SemanticCache(cfg)
        rt = c.init()
        rng = jax.random.PRNGKey(0)
        hits_total = 0
        for step_i in range(60):
            now = float(step_i)
            rng, k1, k2 = jax.random.split(rng, 3)
            # mixed workload: re-query recent inserts + novel inserts
            recent, _, _ = rand_batch(jax.random.PRNGKey(step_i - 1), 4,
                                      cfg.dim)
            res, rt = c.lookup(rt, recent, now)
            hits_total += int(jnp.sum(res.hit))
            fresh, vals, lens = rand_batch(jax.random.PRNGKey(step_i), 4,
                                           cfg.dim)
            rt = c.insert(rt, fresh, vals, lens, now, mask=~res.hit[:4])
            if step_i % 7 == 0:
                rt = c.expire(rt, now)
            # invariants
            assert int(jnp.sum(rt.state.valid)) <= cfg.capacity
            alive = store.alive_mask(rt.state, now)
            assert bool(jnp.all(rt.state.expiry[alive] > now))
            assert int(rt.stats.hits) + int(rt.stats.misses) == \
                int(rt.stats.lookups)
        # queries one step after insert are inside TTL -> mostly hits
        assert hits_total >= 100, hits_total
