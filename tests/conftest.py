"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the single real CPU
device; multi-device tests spawn subprocesses with their own flags."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
