"""Long-horizon decode stability, audio delay pattern, M-RoPE properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.audio_delay import apply_delay, remove_delay
from repro.models.layers import apply_mrope, apply_rope
from repro.models.model import Model


class TestLongDecode:
    @pytest.mark.parametrize("arch", ["mamba2-130m", "hymba-1.5b"])
    def test_50_step_decode_stable(self, arch):
        """SSM/hybrid archs: long recurrent rollout stays finite and matches
        the full-sequence forward at the end (state correctness over time)."""
        cfg = get_arch(arch).reduced()
        m = Model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                    cfg.vocab)
        lg, caches, _ = m.forward(params, prompt, collect_cache=True,
                                  cache_size=128)
        step = jax.jit(m.decode_step)
        toks = [prompt]
        nt = jnp.argmax(lg[:, -1:], axis=-1)
        for _ in range(50):
            toks.append(nt)
            dl, caches = step(params, caches, nt)
            assert bool(jnp.all(jnp.isfinite(dl[..., :cfg.vocab])))
            nt = jnp.argmax(dl, axis=-1)
        # the 50th decode logits must match the forward over the whole text
        full = jnp.concatenate(toks, axis=1)
        lg2, _ = m.forward(params, full)
        err = float(jnp.max(jnp.abs(dl[:, 0] - lg2[:, -1])))
        assert err < 1e-2, f"{arch}: divergence after 50 steps: {err}"

    def test_ring_decode_past_window(self):
        """Decode far beyond the window size: ring overwrites must keep the
        attention masks consistent (no stale-position leakage)."""
        cfg = dataclasses.replace(get_arch("yi-6b").reduced(),
                                  sliding_window=8)
        m = Model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        caches = m.init_decode_caches(batch=1, cache_size=8)
        step = jax.jit(m.decode_step)
        nt = jnp.ones((1, 1), dtype=jnp.int32)
        for i in range(24):   # 3x the ring size
            dl, caches = step(params, caches, nt)
            assert bool(jnp.all(jnp.isfinite(dl[..., :cfg.vocab]))), i
            nt = jnp.argmax(dl, axis=-1)
        sp = np.asarray(caches.kv.slot_pos)
        assert sorted(sp.tolist()) == list(range(16, 24))


class TestAudioDelayPattern:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        toks = rng.integers(1, 2048, size=(2, 10, 4)).astype(np.int32)
        d = apply_delay(toks, pad_id=0)
        assert d.shape == (2, 13, 4)
        back = remove_delay(d, n_frames=10, pad_id=0)
        np.testing.assert_array_equal(back, toks)

    def test_delay_structure(self):
        toks = np.arange(12).reshape(1, 3, 4).astype(np.int32) + 1
        d = apply_delay(toks, pad_id=0)
        # codebook k starts at step k
        for k in range(4):
            assert (d[0, :k, k] == 0).all()
            assert d[0, k, k] == toks[0, 0, k]


class TestMRoPE:
    def test_degenerates_to_rope_for_text(self):
        """t == h == w positions must reproduce standard RoPE exactly
        (Qwen2-VL's construction)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
        r1 = apply_rope(x, pos, 10000.0)
        pos3 = jnp.stack([pos] * 3, axis=-1)
        r2 = apply_mrope(x, pos3, 10000.0, (8, 12, 12))
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   rtol=1e-5, atol=1e-5)

    def test_spatial_positions_differ(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 64))
        pos_t = jnp.stack([jnp.zeros((1, 4)), jnp.arange(4)[None] * 1.0,
                           jnp.zeros((1, 4))], axis=-1).astype(jnp.int32)
        pos_w = jnp.stack([jnp.zeros((1, 4)), jnp.zeros((1, 4)),
                           jnp.arange(4)[None] * 1.0], axis=-1).astype(jnp.int32)
        r_h = apply_mrope(x, pos_t, 10000.0, (8, 12, 12))
        r_w = apply_mrope(x, pos_w, 10000.0, (8, 12, 12))
        assert float(jnp.max(jnp.abs(r_h - r_w))) > 1e-3
