"""Per-architecture smoke tests (deliverable f): every assigned arch in a
REDUCED variant (2 layers, d_model<=512, <=4 experts) runs one forward +
one train step + one decode step on CPU; output shapes checked, no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

ALL_ARCHS = sorted(ARCHITECTURES)


def _inputs(cfg, b=2, l=32, seed=1):
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(jax.random.PRNGKey(seed),
                                    (b, l, cfg.n_codebooks), 0, cfg.vocab)
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, l), 0,
                                    cfg.vocab)
    prefix = None
    if cfg.n_prefix > 0:
        prefix = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                   (b, cfg.n_prefix, cfg.d_model)) * 0.1
    return tokens, prefix


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_is_reduced(arch):
    r = ARCHITECTURES[arch].reduced()
    assert r.n_layers <= 4 and r.d_model <= 512
    if r.is_moe:
        assert r.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = ARCHITECTURES[arch].reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens, prefix = _inputs(cfg)
    logits, aux = jax.jit(
        lambda p, t, pe: m.forward(p, t, prefix_emb=pe))(params, tokens, prefix)
    b, l = tokens.shape[:2]
    if cfg.n_codebooks > 1:
        assert logits.shape == (b, l, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (b, l, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))
    # padded vocab positions are masked
    if cfg.padded_vocab > cfg.vocab:
        assert float(jnp.max(logits[..., cfg.vocab:])) <= -1e8


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = ARCHITECTURES[arch].reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    tokens, prefix = _inputs(cfg)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    def loss_fn(p):
        return m.loss_fn(p, tokens, prefix_emb=prefix, remat=True)

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0)), arch
    new_params, opt, metrics = adamw_update(ocfg, params, grads, opt)
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    loss1 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss1))
    # one step on a fresh model should not explode
    assert float(loss1) < float(loss0) + 1.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill -> decode equals running the extended sequence (exactness of
    the serving path, per family)."""
    cfg = ARCHITECTURES[arch].reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    tokens, prefix = _inputs(cfg, l=24)
    lg, caches, _ = m.forward(params, tokens, prefix_emb=prefix,
                              collect_cache=True, cache_size=64)
    nt = jnp.argmax(lg[:, -1:], axis=-1)
    dl, caches2 = m.decode_step(params, caches, nt)
    ext = jnp.concatenate([tokens, nt], axis=1)
    lg2, _ = m.forward(params, ext, prefix_emb=prefix)
    err = float(jnp.max(jnp.abs(dl[:, 0] - lg2[:, -1])))
    assert err < 5e-3, f"{arch}: decode/forward divergence {err}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_from_empty_cache(arch):
    """Pure decode path (dry-run shape decode_32k analogue, tiny)."""
    cfg = ARCHITECTURES[arch].reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    b = 2
    caches = m.init_decode_caches(batch=b, cache_size=16)
    if cfg.n_codebooks > 1:
        tok = jnp.ones((b, 1, cfg.n_codebooks), dtype=jnp.int32)
    else:
        tok = jnp.ones((b, 1), dtype=jnp.int32)
    logits, caches = jax.jit(m.decode_step)(params, caches, tok)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))
    logits2, _ = jax.jit(m.decode_step)(params, caches, tok)
    assert bool(jnp.all(jnp.isfinite(logits2[..., :cfg.vocab])))


def test_param_counts_in_expected_range():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "minitron-8b": (6e9, 10e9),
        "grok-1-314b": (280e9, 350e9),
        "llama4-maverick-400b-a17b": (330e9, 470e9),
        "deepseek-7b": (6e9, 8.5e9),
        "yi-6b": (5e9, 7e9),
        "llama3-405b": (380e9, 430e9),
        "hymba-1.5b": (1.1e9, 2.2e9),
        "musicgen-large": (1.5e9, 3.5e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHITECTURES[name].param_count()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    g = ARCHITECTURES["grok-1-314b"]
    assert g.active_param_count() < g.param_count()
    l4 = ARCHITECTURES["llama4-maverick-400b-a17b"]
    # a17b: active far below total
    assert l4.active_param_count() < 0.15 * l4.param_count()
