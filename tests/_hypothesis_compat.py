"""Optional-hypothesis shim: property tests skip cleanly when the library
is absent instead of killing the whole suite at collection.

Test modules do ``from _hypothesis_compat import given, settings, st``.
With hypothesis installed this re-exports the real decorators; without it,
``@given(...)`` replaces the test with a zero-strategy stub that calls
``pytest.skip`` at run time, and ``st.<anything>(...)`` returns an inert
placeholder so decorator arguments still evaluate.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without dep
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_strategies, **_kw):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    class _InertStrategies:
        """st.integers(...), st.text(alphabet=...), ... -> None."""

        def __getattr__(self, _name):
            return lambda *a, **kw: None

    st = _InertStrategies()
