"""Unit + property tests for the similarity primitives (paper §2.6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.similarity import (best_match, cosine_scores,
                                   cosine_similarity, l2_normalize,
                                   masked_topk)


def test_cosine_identical():
    v = jnp.asarray([[1.0, 2.0, 3.0]])
    assert float(cosine_similarity(v, v)[0]) == pytest.approx(1.0, abs=1e-6)


def test_cosine_orthogonal():
    u = jnp.asarray([1.0, 0.0])
    v = jnp.asarray([0.0, 1.0])
    assert float(cosine_similarity(u, v)) == pytest.approx(0.0, abs=1e-6)


def test_cosine_opposite():
    u = jnp.asarray([1.0, 2.0])
    assert float(cosine_similarity(u, -u)) == pytest.approx(-1.0, abs=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 32), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_cosine_bounded(dim, n, seed):
    """Property: cosine similarity always lies in [-1, 1]."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(k1, (n, dim))
    v = jax.random.normal(k2, (n, dim))
    sims = cosine_similarity(u, v)
    assert bool(jnp.all(sims <= 1.0 + 1e-5)) and bool(jnp.all(sims >= -1.0 - 1e-5))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
def test_normalize_unit_norm(b, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, d))
    n = jnp.linalg.norm(l2_normalize(x), axis=-1)
    np.testing.assert_allclose(np.asarray(n), 1.0, rtol=1e-5)


def test_scores_mask():
    q = l2_normalize(jnp.ones((1, 4)))
    keys = l2_normalize(jnp.ones((3, 4)))
    valid = jnp.asarray([True, False, True])
    s = cosine_scores(q, keys, valid)
    assert s[0, 1] == -jnp.inf
    assert float(s[0, 0]) == pytest.approx(1.0, abs=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(4, 64), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_topk_matches_sort(b, n, k, seed):
    """Property: masked_topk == full sort's top-k."""
    s = jax.random.normal(jax.random.PRNGKey(seed), (b, n))
    vals, idx = masked_topk(s, k)
    ref = jnp.sort(s, axis=-1)[:, ::-1][:, :k]
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref), rtol=1e-6)


def test_best_match():
    s = jnp.asarray([[0.1, 0.9, 0.5]])
    idx, val = best_match(s)
    assert int(idx[0]) == 1 and float(val[0]) == pytest.approx(0.9)
