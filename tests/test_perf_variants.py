"""§Perf variant correctness: sharding constraints and remat policies must
not change the math (subprocess mesh tests), and the ring-buffer prefill
(the long_500k sliding-window path) must agree with windowed attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model

from tests.test_distributed import run_with_devices


class TestVariantNumericalEquivalence:
    def test_attn_sharding_constraints_preserve_outputs(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np, dataclasses
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_arch
            from repro.models.model import Model
            from repro.launch.sharding import param_pspecs
            cfg = dataclasses.replace(get_arch("yi-6b").reduced(),
                                      vocab_pad_multiple=64)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                        cfg.vocab)
            outs = {}
            for opt in (False, True):
                model = Model(cfg, mesh=mesh, opt_attn_sharding=opt,
                              opt_seq_parallel=opt)
                params = model.init_params(jax.random.PRNGKey(0))
                pspec = param_pspecs(cfg, ("data",))
                named = jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), pspec,
                    is_leaf=lambda x: isinstance(x, P))
                params = jax.device_put(params, named)
                logits, _ = jax.jit(lambda p, t: model.forward(p, t))(
                    params, tokens)
                outs[opt] = np.asarray(logits)
            np.testing.assert_allclose(outs[False], outs[True],
                                       rtol=2e-4, atol=2e-4)
            print("VARIANT-EQ-OK")
        """, n_devices=4)
        assert "VARIANT-EQ-OK" in out


class TestRingPrefill:
    """cache_size < seq_len: the sliding-window ring prefill (long_500k
    substrate) must hand decode a cache equivalent to windowed attention."""

    @pytest.mark.parametrize("arch", ["yi-6b", "musicgen-large"])
    def test_ring_prefill_decode_matches_windowed_forward(self, arch):
        import dataclasses
        window = 16
        cfg = dataclasses.replace(get_arch(arch).reduced(),
                                  sliding_window=window, n_prefix=0)
        m = Model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        L = 40
        if cfg.n_codebooks > 1:
            tokens = jax.random.randint(jax.random.PRNGKey(1),
                                        (2, L, cfg.n_codebooks), 0, cfg.vocab)
        else:
            tokens = jax.random.randint(jax.random.PRNGKey(1), (2, L), 0,
                                        cfg.vocab)
        # ring cache smaller than the sequence: only the last `window`
        # positions survive — exactly the long_500k memory model
        lg, caches, _ = m.forward(params, tokens, collect_cache=True,
                                  cache_size=window)
        assert caches.kv.size == window
        nt = jnp.argmax(lg[:, -1:], axis=-1)
        dl, _ = m.decode_step(params, caches, nt)
        ext = jnp.concatenate([tokens, nt], axis=1)
        lg2, _ = m.forward(params, ext)
        err = float(jnp.max(jnp.abs(dl[:, 0] - lg2[:, -1])))
        assert err < 5e-3, f"{arch}: ring-prefill decode divergence {err}"

    def test_ring_slot_positions(self):
        cfg = get_arch("yi-6b").reduced()
        import dataclasses
        cfg = dataclasses.replace(cfg, sliding_window=8)
        m = Model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0,
                                    cfg.vocab)
        _, caches, _ = m.forward(params, tokens, collect_cache=True,
                                 cache_size=8)
        sp = np.asarray(caches.kv.slot_pos)
        # slots hold positions 12..19 at ring indices pos % 8
        assert sorted(sp.tolist()) == list(range(12, 20))
        for i, p in enumerate(sp):
            assert p % 8 == i
