"""Distributed cache + sharded-model tests.

These need >1 device, so they run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count set (the main pytest
process keeps the default single CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


class TestDistributedCache:
    def test_lookup_insert_across_shards(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import SemanticCache, CacheConfig, DistributedCache
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            cfg = CacheConfig(dim=32, capacity=256, value_len=8, ttl=1e9)
            dc = DistributedCache(SemanticCache(cfg), mesh)
            rt = dc.init()
            step = dc.make_lookup_insert()
            q = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
            vals = jnp.arange(16*8).reshape(16, 8)
            vlens = jnp.full((16,), 8); sid = jnp.arange(16)
            rt, (slot, score, hit, v, vl, src) = step(
                rt, q, vals, vlens, sid, jnp.float32(0.0))
            assert int(np.asarray(hit).sum()) == 0
            rt, (slot, score, hit, v, vl, src) = step(
                rt, q + 0.01, vals, vlens, sid, jnp.float32(1.0))
            assert int(np.asarray(hit).sum()) == 16, np.asarray(hit)
            assert np.array_equal(np.asarray(v), np.asarray(vals))
            assert np.array_equal(np.asarray(src), np.arange(16))
            # entries spread across shards (round-robin routing)
            valid = np.asarray(rt.state.valid).reshape(4, -1)
            assert (valid.sum(axis=1) == 4).all(), valid.sum(axis=1)
            # replicated stats counters track the global workload
            assert int(rt.stats.lookups) == 32 and int(rt.stats.hits) == 16
            assert int(rt.stats.inserts) == 16
            # non-uniform insert counts (6 rows on 4 shards, repeatedly):
            # per-shard ring pointers derive from the global clock, so
            # earlier entries must survive later uneven batches
            for rep in range(3):
                qq = jax.random.normal(jax.random.PRNGKey(10 + rep), (6, 32))
                rt, _out = step(rt, qq, vals[:6], vlens[:6], sid[:6],
                                jnp.float32(2.0 + rep))
            q0 = jax.random.normal(jax.random.PRNGKey(10), (6, 32))
            rt, (s2, sc2, hit2, *_r) = step(
                rt, q0 + 0.01, vals[:6], vlens[:6], sid[:6],
                jnp.float32(9.0))
            assert int(np.asarray(hit2).sum()) == 6, np.asarray(hit2)
            print("DISTRIBUTED-OK")
        """)
        assert "DISTRIBUTED-OK" in out

    def test_ttl_respected_across_shards(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import SemanticCache, CacheConfig, DistributedCache
            mesh = jax.make_mesh((4,), ("data",))
            cfg = CacheConfig(dim=16, capacity=64, value_len=4, ttl=10.0)
            dc = DistributedCache(SemanticCache(cfg), mesh)
            rt = dc.init()
            step = dc.make_lookup_insert()
            q = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
            vals = jnp.zeros((8, 4), jnp.int32); vl = jnp.full((8,), 4)
            sid = jnp.arange(8)
            rt, _out = step(rt, q, vals, vl, sid, jnp.float32(0.0))
            rt, (s, sc, hit, *_rest) = step(rt, q, vals, vl, sid,
                                            jnp.float32(5.0))
            assert int(np.asarray(hit).sum()) == 8
            rt, (s, sc, hit, *_rest) = step(rt, q, vals, vl, sid,
                                            jnp.float32(20.0))
            assert int(np.asarray(hit).sum()) == 0   # expired everywhere
            print("TTL-OK")
        """)
        assert "TTL-OK" in out


class TestShardedModel:
    def test_train_step_on_4dev_mesh(self):
        """Reduced arch, real data, pjit train step on a (2,2) mesh."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_arch
            from repro.models.model import Model
            from repro.launch.sharding import param_pspecs
            from repro.training.optimizer import (AdamWConfig, adamw_update,
                                                  init_adamw)
            import dataclasses
            cfg = dataclasses.replace(get_arch("yi-6b").reduced(),
                                      vocab_pad_multiple=64)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            model = Model(cfg, mesh=mesh)
            params = model.init_params(jax.random.PRNGKey(0))
            pspec = param_pspecs(cfg, ("data",))
            named = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                pspec, is_leaf=lambda x: isinstance(x, P))
            params = jax.device_put(params, named)
            opt = init_adamw(params)
            ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
            tokens = jax.device_put(
                jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab),
                NamedSharding(mesh, P("data", None)))

            @jax.jit
            def train_step(params, opt, tokens):
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss_fn(p, tokens, remat=True))(params)
                params, opt, m = adamw_update(ocfg, params, grads, opt)
                return params, opt, loss

            l0 = None
            for i in range(3):
                params, opt, loss = train_step(params, opt, tokens)
                l0 = l0 or float(loss)
            assert float(loss) <= l0 + 0.5
            print("SHARDED-TRAIN-OK", float(loss))
        """, n_devices=4)
        assert "SHARDED-TRAIN-OK" in out

    def test_moe_shard_map_on_mesh(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.models.moe import moe_ffn, moe_ffn_sharded
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            d, ff, e, t = 32, 64, 4, 16
            ks = jax.random.split(jax.random.PRNGKey(0), 5)
            x = jax.random.normal(ks[0], (t, d))
            wr = jax.random.normal(ks[1], (d, e)) * 0.1
            wg = jax.random.normal(ks[2], (e, d, ff)) * 0.1
            wu = jax.random.normal(ks[3], (e, d, ff)) * 0.1
            wd = jax.random.normal(ks[4], (e, ff, d)) * 0.1
            y_ref, aux_ref = moe_ffn(x, wr, wg, wu, wd, topk=2,
                                     capacity_factor=8.0)
            fn = moe_ffn_sharded(mesh, ("data",), ("model",))
            y, aux = jax.jit(lambda *a: fn(*a, topk=2, capacity_factor=8.0))(
                x, wr, wg, wu, wd)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-3, atol=2e-3)
            print("MOE-SHARDED-OK")
        """, n_devices=4)
        assert "MOE-SHARDED-OK" in out


class TestDryRunMini:
    @pytest.mark.slow
    def test_dryrun_single_pair_runs(self, tmp_path):
        """The real dryrun script on the production 512-device mesh."""
        env = dict(os.environ)
        env["PYTHONPATH"] = REPO_SRC
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "mamba2-130m", "--shape", "decode_32k", "--out", str(tmp_path)],
            capture_output=True, text=True, timeout=600, env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "1/1 dry-runs succeeded" in r.stdout


class TestDistributedEquivalence:
    def test_distributed_matches_local_lookup(self):
        """Property: the sharded cache returns the same (hit, score, value)
        as a single-device SemanticCache over identical contents."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import (SemanticCache, CacheConfig,
                                    DistributedCache)
            cfg = CacheConfig(dim=48, capacity=128, value_len=6, ttl=None,
                              threshold=0.8)
            # local reference
            local = SemanticCache(cfg)
            lrt = local.init()
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            emb = jax.random.normal(ks[0], (32, 48))
            vals = jax.random.randint(ks[1], (32, 6), 0, 99)
            lens = jnp.full((32,), 6)
            lrt = local.insert(lrt, emb, vals, lens, 0.0)
            queries = emb[:16] + 0.02 * jax.random.normal(ks[2], (16, 48))
            lres, _ = local.lookup(lrt, queries, 1.0)

            # distributed: same inserts via the sharded step
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            dc = DistributedCache(SemanticCache(cfg), mesh)
            drt = dc.init()
            step = dc.make_lookup_insert()
            drt, _out = step(drt, emb, vals, lens,
                             jnp.arange(32), jnp.float32(0.0))
            drt, (slot, score, hit, v, vl, src) = step(
                drt, queries, jnp.zeros((16, 6), jnp.int32),
                jnp.zeros((16,), jnp.int32), jnp.full((16,), -1),
                jnp.float32(1.0))
            np.testing.assert_array_equal(np.asarray(hit), np.asarray(lres.hit))
            np.testing.assert_allclose(np.asarray(score),
                                       np.asarray(lres.score), atol=1e-5)
            hm = np.asarray(hit)
            np.testing.assert_array_equal(np.asarray(v)[hm],
                                          np.asarray(lres.values)[hm])
            print("EQUIV-OK")
        """)
        assert "EQUIV-OK" in out
