"""Session subsystem tests (DESIGN.md §16): context-fusion ops, the
SessionStore lifecycle (TTL/LRU/tenant namespacing), one-compiled-step
acceptance across session mixes, fused-key parity between step and the
standalone op, record/replay hit conversion, session-scoped coalescing,
checkpoint compatibility, and the flush-path expiry + bounded-memory
guarantees."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.context import (AttentionFusion, DecayMeanFusion, FusionState,
                           SessionStore, fuse_op)
from repro.core.types import CacheConfig
from repro.data.qa_dataset import build_corpus
from repro.serving import (AsyncCacheServer, CachedEngine, Request,
                           SchedulerConfig, SimulatedLLMBackend,
                           build_multi_turn_workload, coalesce_key,
                           turn_levels)

STRATEGIES = [DecayMeanFusion(window=4), AttentionFusion(window=4)]


@pytest.fixture(scope="module")
def pairs():
    return build_corpus(40, seed=0)


def mk_engine(pairs, *, fusion=None, batch_size=8, capacity=2048, **kw):
    key_by_sid = {p.qa_id: p.semantic_key for p in pairs}

    def judge(req, sid):
        return key_by_sid.get(sid, "") == req.semantic_key

    cfg = CacheConfig(dim=384, capacity=capacity, value_len=48,
                      ttl=None, threshold=0.8)
    return CachedEngine(cfg, SimulatedLLMBackend(pairs), judge=judge,
                        batch_size=batch_size, fusion=fusion, **kw), \
        key_by_sid


def serve_conversations(eng, conversations):
    """Record-first ordering contract: all recordings, then all replays,
    each half level-by-level (a turn must land before the next looks up)."""
    n = len(conversations) // 2
    for half in (conversations[:n], conversations[n:]):
        for level in turn_levels(half):
            eng.process(level)


def register_followup_keys(key_by_sid, conversations):
    for conv in conversations:
        for r in conv:
            key_by_sid.setdefault(r.source_id, r.semantic_key)


# --------------------------------------------------------------------- #
# fusion ops
# --------------------------------------------------------------------- #
class TestFusionOps:
    def _batch(self, seed=0, b=6, w=4, d=384):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        q = jax.random.normal(k1, (b, d))
        q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
        win = jax.random.normal(k2, (b, w, d))
        return q, win

    @pytest.mark.parametrize("fusion", STRATEGIES,
                             ids=["decay", "attention"])
    def test_empty_window_rows_pass_through_bit_identically(self, fusion):
        """The contract that lets session and stateless rows share one
        compiled step: window_len == 0 -> the query embedding, untouched."""
        q, win = self._batch()
        wl = jnp.zeros((q.shape[0],), dtype=jnp.int32)
        out = fuse_op(fusion, fusion.init_state(), q,
                      jnp.zeros_like(win), wl)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(q))
        # and per-row: a mixed batch only passes its empty rows through
        wl = jnp.asarray([0, 2, 0, 4, 1, 0], dtype=jnp.int32)
        out = np.asarray(fuse_op(fusion, fusion.init_state(), q, win, wl))
        qn = np.asarray(q)
        for i, n_turns in enumerate([0, 2, 0, 4, 1, 0]):
            if n_turns == 0:
                np.testing.assert_array_equal(out[i], qn[i])
            else:
                assert not np.array_equal(out[i], qn[i])

    @pytest.mark.parametrize("fusion", STRATEGIES,
                             ids=["decay", "attention"])
    def test_fused_keys_are_unit_and_context_bounded(self, fusion):
        """Rotated-subspace geometry (§16.2): fused keys are unit rows and
        their similarity to the RAW query is about sqrt(1-cw) — a fused
        key can never clear the 0.8 threshold against any raw slab key."""
        q, win = self._batch(seed=3)
        wl = jnp.full((q.shape[0],), 3, dtype=jnp.int32)
        out = np.asarray(fuse_op(fusion, fusion.init_state(), q, win, wl))
        np.testing.assert_allclose(np.linalg.norm(out, axis=-1), 1.0,
                                   atol=1e-5)
        sims = np.sum(out * np.asarray(q), axis=-1)
        bound = np.sqrt(1.0 - fusion.context_weight) + 0.12  # small overlap
        assert (np.abs(sims) <= bound).all(), sims

    def test_same_context_dominates_same_text(self):
        """The separability the record/replay bar stands on: two phrasings
        under ONE context score above threshold; the SAME text under two
        different contexts scores far below it."""
        fusion = DecayMeanFusion(window=4)
        fs = fusion.init_state()
        d = 384
        k = jax.random.PRNGKey(7)
        qa, qb, ca, cb, cc, cd = jax.random.normal(k, (6, d))
        qb = 0.9 * qa + jnp.sqrt(1 - 0.81) * qb    # paraphrase: cos ~ 0.9
        win_a = jnp.stack([ca, cb, ca, cb])[None]  # one shared context
        win_c = jnp.stack([cc, cd, cc, cd])[None]  # an unrelated context
        wl = jnp.asarray([4], dtype=jnp.int32)
        fa = np.asarray(fuse_op(fusion, fs, qa[None], win_a, wl))[0]
        fb = np.asarray(fuse_op(fusion, fs, qb[None], win_a, wl))[0]
        fc = np.asarray(fuse_op(fusion, fs, qa[None], win_c, wl))[0]
        same_state = float(fa @ fb)      # rephrased, same dialogue state
        other_state = float(fa @ fc)     # identical text, other state
        assert same_state > 0.9
        assert other_state < 0.5

    def test_decay_mean_weighs_recent_turns_more(self):
        fusion = DecayMeanFusion(window=4, decay=0.5)
        fs = fusion.init_state()
        d = 384
        old, new = jax.random.normal(jax.random.PRNGKey(1), (2, d))
        q = jax.random.normal(jax.random.PRNGKey(2), (1, d))
        win = jnp.stack([old, new])[None]            # oldest-to-newest
        pad = jnp.zeros((1, 2, d))
        win = jnp.concatenate([win, pad], axis=1)    # (1, 4, d)
        wl = jnp.asarray([2], dtype=jnp.int32)
        fused = np.asarray(fuse_op(fusion, fs, q, win, wl))[0]
        rot = lambda v: np.roll(np.asarray(v) / np.linalg.norm(v), d // 2)
        assert float(fused @ rot(new)) > float(fused @ rot(old))

    def test_attention_pools_the_referred_turn(self):
        """A query aligned with one turn pulls that turn into the key."""
        fusion = AttentionFusion(window=4, temp=0.25)
        fs = fusion.init_state()
        d = 384
        t0, t1, noise = jax.random.normal(jax.random.PRNGKey(4), (3, d))
        q = (t1 + 0.1 * noise)[None]                 # refers back to t1
        win = jnp.stack([t0, t1, jnp.zeros(d), jnp.zeros(d)])[None]
        wl = jnp.asarray([2], dtype=jnp.int32)
        fused = np.asarray(fuse_op(fusion, fs, q, win, wl))[0]
        rot = lambda v: np.roll(np.asarray(v) / np.linalg.norm(v), d // 2)
        assert float(fused @ rot(t1)) > float(fused @ rot(t0)) + 0.2

    def test_fusion_state_checkpoints_both_strategies(self):
        """One FusionState template for both strategies (§16.5): a state
        made by one strategy has the other's leaf riding along."""
        for fusion in STRATEGIES:
            fs = fusion.init_state()
            assert isinstance(fs, FusionState)
            leaves = jax.tree_util.tree_leaves(fs)
            assert len(leaves) == 3
            assert all(l.dtype == jnp.float32 for l in leaves)

    def test_strategy_validation(self):
        with pytest.raises(ValueError, match="window"):
            DecayMeanFusion(window=0)
        with pytest.raises(ValueError, match="context_weight"):
            DecayMeanFusion(context_weight=1.0)
        with pytest.raises(ValueError, match="decay"):
            DecayMeanFusion(decay=0.0)
        with pytest.raises(ValueError, match="temp"):
            AttentionFusion(temp=0.0)


# --------------------------------------------------------------------- #
# SessionStore: rings, TTL, LRU, tenancy
# --------------------------------------------------------------------- #
class TestSessionStore:
    def test_ring_window_left_aligned_oldest_to_newest(self):
        st = SessionStore(window=3, dim=4, ttl=None, max_sessions=8)
        embs = [np.full((4,), float(i), dtype=np.float32) for i in range(5)]
        win, n = st.window_for("t", "s", 0.0)
        assert n == 0 and not win.any()
        for i, e in enumerate(embs):
            st.append("t", "s", e, float(i))
        win, n = st.window_for("t", "s", 5.0)
        assert n == 3                       # capped at the window size
        # last W turns, oldest first: 2, 3, 4
        np.testing.assert_array_equal(win[:, 0], [2.0, 3.0, 4.0])

    def test_partial_window_zero_padded(self):
        st = SessionStore(window=4, dim=4, ttl=None)
        st.append("t", "s", np.ones(4, np.float32), 0.0)
        win, n = st.window_for("t", "s", 0.0)
        assert n == 1
        assert win[0].all() and not win[1:].any()

    def test_tenant_namespacing(self):
        """Same wire-level session id under two tenants = two sessions —
        a session can never read another tenant's turns (§16.1)."""
        st = SessionStore(window=2, dim=4, ttl=None)
        st.append("acme", "chat-1", np.ones(4, np.float32), 0.0)
        assert st.turns("acme", "chat-1") == 1
        assert st.turns("globex", "chat-1") == 0
        win, n = st.window_for("globex", "chat-1", 0.0)
        assert n == 0 and not win.any()
        assert len(st) == 2                 # two distinct sessions exist

    def test_ttl_stale_on_touch_restarts_session(self):
        st = SessionStore(window=2, dim=4, ttl=10.0)
        st.append("t", "s", np.ones(4, np.float32), 0.0)
        _, n = st.window_for("t", "s", 5.0)     # within TTL: turns kept
        assert n == 1
        _, n = st.window_for("t", "s", 100.0)   # reused id, long idle
        assert n == 0
        assert st.expired_ttl == 1

    def test_expire_sweeps_only_dead_sessions(self):
        st = SessionStore(window=2, dim=4, ttl=10.0)
        st.append("t", "old", np.ones(4, np.float32), 0.0)
        st.append("t", "new", np.ones(4, np.float32), 95.0)
        assert st.expire(100.0) == 1
        assert st.turns("t", "old") == 0
        assert st.turns("t", "new") == 1
        assert st.expire(100.0) == 0            # idempotent
        assert st.stats()["expired_ttl"] == 1

    def test_lru_cap_bounds_sessions(self):
        st = SessionStore(window=2, dim=4, ttl=None, max_sessions=3)
        for i in range(5):
            st.append("t", f"s{i}", np.ones(4, np.float32), float(i))
        assert len(st) == 3
        assert st.evicted_lru == 2
        assert st.turns("t", "s0") == 0 and st.turns("t", "s1") == 0
        assert st.turns("t", "s4") == 1
        # touching refreshes recency: s2 survives the next eviction
        st.window_for("t", "s2", 10.0)
        st.append("t", "s5", np.ones(4, np.float32), 11.0)
        assert st.turns("t", "s2") == 1 and st.turns("t", "s3") == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            SessionStore(window=0, dim=4)
        with pytest.raises(ValueError):
            SessionStore(window=2, dim=4, max_sessions=0)
        with pytest.raises(ValueError):
            SessionStore(window=2, dim=4, ttl=0.0)


# --------------------------------------------------------------------- #
# engine integration: one compiled step, key parity, hit conversion
# --------------------------------------------------------------------- #
class TestEngineSessions:
    def test_no_recompile_across_session_mixes(self, pairs):
        """Acceptance criterion (§16.3): the turn window is a traced
        operand, so all-sessionless, mixed and all-session batches — full
        or padded — share ONE compiled fused step."""
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4))
        eng.process([Request(query=f"stateless {i}") for i in range(8)])
        traces = eng._step_jit._cache_size()
        assert traces == 1
        eng.process([Request(query=f"mixed {i}",
                             session="conv-a" if i % 2 else "")
                     for i in range(8)])
        eng.process([Request(query=f"deep {i}", session="conv-b")
                     for i in range(3)])     # padded partial batch
        assert eng._step_jit._cache_size() == traces
        assert eng._peek_jit._cache_size() == 1

    def test_sessionless_traffic_identical_with_and_without_fusion(self,
                                                                   pairs):
        """A fusion-enabled engine serving only sessionless requests is
        byte-for-byte today's stateless engine (§16.3)."""
        reqs = [Request(query=p.question, source_id=p.qa_id,
                        semantic_key=p.semantic_key) for p in pairs[:16]]
        results = {}
        for fusion in (DecayMeanFusion(window=4), None):
            eng, _ = mk_engine(pairs, fusion=fusion)
            eng.warm(pairs)
            resp = eng.process(reqs)
            results[fusion is None] = [
                (r.answer, r.cached, round(r.score, 5), r.context)
                for r in resp]
        assert results[True] == results[False]
        assert all(not ctx for *_, ctx in results[False])

    @pytest.mark.parametrize("fusion", STRATEGIES,
                             ids=["decay", "attention"])
    def test_step_inserts_exactly_the_standalone_fused_key(self, pairs,
                                                           fusion):
        """Parity pin: the in-step fusion must be the plain ``fuse_op``,
        not a divergent reimplementation — the key the fused step inserts
        for a session miss equals the standalone op's output."""
        eng, _ = mk_engine(pairs, fusion=fusion)
        sess = "parity-conv"
        eng.process([Request(query="seed turn for context", session=sess)])
        win, n = eng.sessions.window_for("default", sess, eng._now)
        assert n == 1
        q = "a brand new follow-up that must miss"
        eng.process([Request(query=q, session=sess)])
        emb = jnp.asarray(eng.embedder.embed_batch([q]))
        expect = np.asarray(fuse_op(
            fusion, eng.runtime.fusion, emb, jnp.asarray(win[None]),
            jnp.asarray([n], dtype=jnp.int32)))[0]
        keys = np.asarray(eng.state.keys, dtype=np.float32)
        sims = keys @ expect
        np.testing.assert_allclose(float(sims.max()), 1.0, atol=1e-5)

    def test_record_replay_follow_ups_convert_to_hits(self, pairs):
        """The tentpole behaviour (§16.6): replayed follow-ups — globally
        unique raw texts — hit the recording's fused entries with fusion
        and CANNOT hit without it, at paper-grade precision."""
        convs = build_multi_turn_workload(pairs, 4, turns=3, seed=11)
        summaries = {}
        for tag, fusion in (("on", DecayMeanFusion(window=4)),
                            ("off", None)):
            eng, key_by_sid = mk_engine(pairs, fusion=fusion)
            register_followup_keys(key_by_sid, convs)
            eng.warm(pairs)
            serve_conversations(eng, convs)
            summaries[tag] = eng.metrics.summary()
        on = summaries["on"]["categories"]
        off = summaries["off"]["categories"]
        # replayed opener: identical text — hits either way
        assert on["ctx/open_repeat"]["hit_rate"] == 1.0
        assert off["ctx/open_repeat"]["hit_rate"] == 1.0
        # replayed follow-ups: the conversion the subsystem exists for
        assert on["ctx/followup_repeat"]["hit_rate"] == 1.0
        assert on["ctx/followup_repeat"]["positive_rate"] == 1.0
        assert off["ctx/followup_repeat"]["hit_rate"] == 0.0
        # context-bucket metrics rode along and clear the >97% bar
        ctx = summaries["on"]["context"]["context"]
        assert ctx["lookups"] > 0
        assert ctx["positive_rate"] > 0.97
        assert summaries["off"]["context"] == {}

    def test_separate_path_matches_fused_path_with_sessions(self, pairs):
        """The reference (separate) path pre-fuses with the same op the
        fused step inlines — both serve identical hit patterns."""
        convs = build_multi_turn_workload(pairs, 3, turns=3, seed=5)
        patterns = {}
        for fused in (True, False):
            eng, key_by_sid = mk_engine(pairs,
                                        fusion=DecayMeanFusion(window=4),
                                        use_fused_step=fused)
            register_followup_keys(key_by_sid, convs)
            eng.warm(pairs)
            serve_conversations(eng, convs)
            s = eng.metrics.summary()["categories"]
            patterns[fused] = {c: (s[c]["cache_hits"], s[c]["lookups"])
                               for c in s}
        assert patterns[True] == patterns[False]

    def test_responses_flag_context_rows(self, pairs):
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4))
        r0 = eng.process([Request(query="first turn", session="c")])[0]
        assert not r0.context                # empty window on turn 0
        r1 = eng.process([Request(query="second turn", session="c"),
                          Request(query="stateless neighbour")])
        assert r1[0].context and not r1[1].context

    def test_session_requires_fusion_to_matter(self, pairs):
        """On a fusion-less engine the session field is inert: no store is
        attached and responses never carry the context flag."""
        eng, _ = mk_engine(pairs, fusion=None)
        assert eng.sessions is None
        resp = eng.process([Request(query="hello", session="c")] * 2)
        assert all(not r.context for r in resp)


# --------------------------------------------------------------------- #
# checkpoint compatibility (§16.5)
# --------------------------------------------------------------------- #
class TestSessionCheckpoint:
    def test_fusion_round_trip_preserves_replay_hits(self, pairs, tmp_path):
        convs = build_multi_turn_workload(pairs, 3, turns=3, seed=9)
        n = len(convs) // 2
        eng, key_by_sid = mk_engine(pairs, fusion=DecayMeanFusion(window=4))
        register_followup_keys(key_by_sid, convs)
        eng.warm(pairs)
        for level in turn_levels(convs[:n]):     # recordings only
            eng.process(level)
        path = str(tmp_path / "session_era")
        eng.save_cache(path)

        eng2, key2 = mk_engine(pairs, fusion=DecayMeanFusion(window=4))
        register_followup_keys(key2, convs)
        eng2.load_cache(path)
        # fusion leaves restored (not re-initialised junk)
        assert eng2.runtime.fusion is not None
        np.testing.assert_allclose(
            float(eng2.runtime.fusion.context_weight), 0.8, atol=1e-6)
        for level in turn_levels(convs[n:]):     # replays against restore
            eng2.process(level)
        s = eng2.metrics.summary()["categories"]
        assert s["ctx/followup_repeat"]["hit_rate"] == 1.0
        assert s["ctx/followup_repeat"]["positive_rate"] == 1.0

    def test_pre_session_snapshot_loads_into_session_engine(self, pairs,
                                                            tmp_path):
        """Forward compatibility: a single-turn era snapshot restores into
        a session-enabled engine — shared leaves load, the engine keeps
        its fresh fusion state, and warm raw keys still hit."""
        old, _ = mk_engine(pairs, fusion=None)
        old.warm(pairs)
        path = str(tmp_path / "pre_session")
        old.save_cache(path)

        eng, _ = mk_engine(pairs, fusion=AttentionFusion(window=4))
        eng.load_cache(path)
        assert eng.runtime.fusion is not None    # kept, not dropped
        resp = eng.process([Request(query=p.question, source_id=p.qa_id,
                                    semantic_key=p.semantic_key)
                            for p in pairs[:8]])
        assert all(r.cached for r in resp)

    def test_fusion_snapshot_into_fusionless_engine_fails_loudly(
            self, pairs, tmp_path):
        """Backward direction must NOT silently drop learned fusion
        weights — every fused slab key was stored under them."""
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4))
        eng.process([Request(query="turn one", session="c")])
        path = str(tmp_path / "fused_era")
        eng.save_cache(path)

        plain, _ = mk_engine(pairs, fusion=None)
        with pytest.raises(ValueError, match="fusion"):
            plain.load_cache(path)


# --------------------------------------------------------------------- #
# session-scoped coalescing (§16.3)
# --------------------------------------------------------------------- #
class TestSessionCoalescing:
    def test_coalesce_key_shape(self):
        a = coalesce_key(Request(query="What  About the second one?",
                                 session="s1"))
        b = coalesce_key(Request(query="what about the second one?",
                                 session="s1"))
        c = coalesce_key(Request(query="what about the second one?",
                                 session="s2"))
        d = coalesce_key(Request(query="what about the second one?"))
        assert a == b           # normalization still applies within a session
        assert len({b, c, d}) == 3
        # sessionless keys keep the (tenant, "", query) shape — pre-session
        # coalescing behaviour is unchanged
        assert d == "default\x1f\x1fwhat about the second one?"

    def test_identical_followup_text_does_not_coalesce_across_sessions(
            self, pairs):
        """Regression (§16.3): two sessions asking the same follow-up TEXT
        are different dialogue states — sharing one in-flight leader would
        hand one session an answer fused under the other's context."""
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4))

        async def drive():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                # distinct openers: the two sessions diverge
                await asyncio.gather(
                    server.submit(pairs[0].question, session="conv-a"),
                    server.submit(pairs[1].question, session="conv-b"))
                calls0 = eng.backend.calls
                # identical elliptical follow-up text, both sessions at once
                follow = await asyncio.gather(
                    server.submit("what about the second one?",
                                  session="conv-a"),
                    server.submit("what about the second one?",
                                  session="conv-b"))
                return calls0, follow

        calls0, follow = asyncio.run(drive())
        # neither coalesced with the other, and neither hit the other's
        # fused entry: each paid its own backend call
        assert not any(r.coalesced for r in follow)
        assert eng.backend.calls - calls0 == 2
        assert all(r.context for r in follow)

    def test_same_session_duplicates_still_coalesce(self, pairs):
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4))

        async def drive():
            sched = SchedulerConfig(max_batch=8, max_wait_ms=5.0)
            async with AsyncCacheServer(eng, sched) as server:
                await server.submit(pairs[0].question, session="conv")
                calls0 = eng.backend.calls
                dup = await asyncio.gather(*(
                    server.submit("and what about pricing?", session="conv")
                    for _ in range(4)))
                return calls0, dup

        calls0, dup = asyncio.run(drive())
        assert eng.backend.calls - calls0 == 1   # one leader, three waiters
        assert sum(r.coalesced for r in dup) == 3


# --------------------------------------------------------------------- #
# flush-path expiry + bounded memory (§16.4)
# --------------------------------------------------------------------- #
class TestSessionHygiene:
    def test_flush_path_expires_abandoned_sessions(self, pairs):
        """An abandoned session dies on the next admission flush — nobody
        has to touch it (the serve_batch expire sweep)."""
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4),
                           session_ttl_s=60.0)
        eng.process([Request(query="opening turn", session="abandoned")])
        assert eng.sessions.turns("default", "abandoned") == 1
        eng.tick(120.0)                          # idle past the TTL
        # serve OTHER traffic: the sweep runs on the flush, not on touch
        eng.process([Request(query="unrelated stateless request")])
        assert eng.sessions.turns("default", "abandoned") == 0
        assert eng.sessions.stats()["expired_ttl"] == 1

    def test_session_memory_bounded_under_many_conversations(self, pairs):
        """LRU cap: serving far more distinct sessions than max_sessions
        never grows the store past the bound."""
        eng, _ = mk_engine(pairs, fusion=DecayMeanFusion(window=4),
                           max_sessions=16)
        for i in range(0, 64, 8):
            eng.process([Request(query=f"opening turn {i + j}",
                                 session=f"conv-{i + j}")
                         for j in range(8)])
        st = eng.sessions.stats()
        assert st["sessions"] <= 16
        assert st["created"] == 64
        assert st["evicted_lru"] == 48
