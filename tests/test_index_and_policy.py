"""ANN indexes (exact / IVF / HNSW reference) and threshold policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hnsw import HNSWIndex
from repro.core.index import ExactIndex, ExactState, IVFIndex
from repro.core.policy import (AdaptiveThreshold, FixedThreshold,
                               PerCategoryThreshold, make_policy)
from repro.core.similarity import l2_normalize


def _unit(rng, shape):
    x = jax.random.normal(rng, shape)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


class TestExactIndex:
    def test_self_retrieval(self):
        keys = _unit(jax.random.PRNGKey(0), (128, 32))
        idx = ExactIndex(topk=1, backend="jnp")
        s, i = idx.search(ExactState(), keys[:8], keys, jnp.ones((128,), bool))
        np.testing.assert_array_equal(np.asarray(i[:, 0]), np.arange(8))
        np.testing.assert_allclose(np.asarray(s[:, 0]), 1.0, atol=1e-5)


class TestIVF:
    def test_recall_vs_exact(self):
        """IVF with enough probes must recover most exact-NN results."""
        rng = jax.random.PRNGKey(0)
        keys = _unit(rng, (512, 32))
        valid = jnp.ones((512,), bool)
        queries = keys[:64] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (64, 32))
        ivf = IVFIndex(ncentroids=16, nprobe=8, bucket_cap=128, topk=1)
        st = ivf.fit(keys, valid, jax.random.PRNGKey(2))
        s_ivf, i_ivf = ivf.search(st, queries, keys, valid)
        ex = ExactIndex(topk=1, backend="jnp")
        s_ex, i_ex = ex.search(ExactState(), queries, keys, valid)
        recall = float(jnp.mean((i_ivf[:, 0] == i_ex[:, 0]).astype(jnp.float32)))
        assert recall >= 0.9, f"IVF recall {recall}"

    def test_respects_validity(self):
        keys = _unit(jax.random.PRNGKey(0), (64, 16))
        valid = jnp.zeros((64,), bool).at[10].set(True)
        ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=64, topk=1)
        st = ivf.fit(keys, valid, jax.random.PRNGKey(1))
        s, i = ivf.search(st, keys[10:11], keys, valid)
        assert int(i[0, 0]) == 10

    def test_int8_slab_parity_vs_exact(self):
        """Satellite regression: IVF gathered-candidate scoring on an int8
        slab must dequant (x 1/127) like the exact path — without it IVF
        scores inflate x127 and disagree with exact on the same slab."""
        keys = _unit(jax.random.PRNGKey(0), (256, 32))
        keys8 = jnp.clip(jnp.round(keys * 127.0), -127, 127).astype(jnp.int8)
        valid = jnp.ones((256,), bool)
        queries = keys[:32] + 0.05 * jax.random.normal(
            jax.random.PRNGKey(1), (32, 32))
        # nprobe == ncentroids: IVF probes every bucket -> exact recall,
        # so any score disagreement is a scoring bug, not a recall miss
        ivf = IVFIndex(ncentroids=8, nprobe=8, bucket_cap=256, topk=1)
        st = ivf.fit(keys8, valid, jax.random.PRNGKey(2))
        s_ivf, i_ivf = ivf.search(st, queries, keys8, valid)
        ex = ExactIndex(topk=1, backend="jnp")
        s_ex, i_ex = ex.search(ExactState(), queries, keys8, valid)
        np.testing.assert_array_equal(np.asarray(i_ivf[:, 0]),
                                      np.asarray(i_ex[:, 0]))
        np.testing.assert_allclose(np.asarray(s_ivf[:, 0]),
                                   np.asarray(s_ex[:, 0]), rtol=1e-5,
                                   atol=1e-5)
        assert float(jnp.max(jnp.abs(s_ivf))) <= 1.01  # not x127

    def test_interval_matches_dense_mask(self):
        """IVF per-row intervals == IVF with the equivalent dense (B, N)
        mask: same candidates, same scores, same slots."""
        from repro.core.similarity import interval_visibility
        keys = _unit(jax.random.PRNGKey(3), (192, 16))
        valid = jnp.ones((192,), bool)
        queries = _unit(jax.random.PRNGKey(4), (6, 16))
        starts = jnp.asarray([0, 64, 128, 0, 64, 128], jnp.int32)
        sizes = jnp.asarray([64, 64, 64, 64, 64, 0], jnp.int32)
        ivf = IVFIndex(ncentroids=6, nprobe=6, bucket_cap=192, topk=2)
        st = ivf.fit(keys, valid, jax.random.PRNGKey(5))
        s_a, i_a = ivf.search(st, queries, keys, valid,
                              interval=(starts, sizes))
        dense = interval_visibility(valid, starts, sizes)
        s_b, i_b = ivf.search(st, queries, keys, dense)
        np.testing.assert_array_equal(np.asarray(i_a), np.asarray(i_b))
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                                   atol=1e-6)
        # empty-interval row: the (-inf, -1) contract
        assert (np.asarray(i_a)[5] == -1).all()
        assert np.isneginf(np.asarray(s_a)[5]).all()

    def test_absorb_vectorized_matches_serial(self):
        """Satellite parity (DESIGN.md §15.4): the vectorized sort-by-
        centroid absorb equals the original serial fori_loop scatter —
        the broad random sweep lives in test_ivf_kernel.py."""
        from repro.core.index import _absorb_serial
        from repro.core.similarity import l2_normalize
        keys = _unit(jax.random.PRNGKey(0), (256, 32))
        valid = jnp.ones((256,), bool)
        ivf = IVFIndex(ncentroids=8, nprobe=4, bucket_cap=16, topk=2)
        st = ivf.fit(keys, valid, jax.random.PRNGKey(1))
        new_keys = jax.random.normal(jax.random.PRNGKey(2), (24, 32))
        slots = jax.random.randint(jax.random.PRNGKey(3), (24,), 0, 256)
        mask = jax.random.bernoulli(jax.random.PRNGKey(4), 0.8, (24,))
        got = ivf.absorb(st, slots, new_keys, mask)
        assign = jnp.argmax(jnp.einsum(
            "bd,cd->bc", l2_normalize(new_keys), st.centroids), axis=-1)
        exp_b, exp_v = _absorb_serial(st.buckets, st.bucket_valid, assign,
                                      slots, mask, ivf.bucket_cap)
        np.testing.assert_array_equal(np.asarray(got.buckets),
                                      np.asarray(exp_b))
        np.testing.assert_array_equal(np.asarray(got.bucket_valid),
                                      np.asarray(exp_v))

    def test_absorbed_rows_searchable_both_backends(self):
        """Fresh absorb -> immediately findable through the fused kernel
        path and the jnp path alike (the serve-loop integration seam)."""
        keys = _unit(jax.random.PRNGKey(5), (128, 16))
        valid = jnp.zeros((128,), bool)
        base = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=64, topk=1)
        st = base.fit(keys, valid, jax.random.PRNGKey(6))
        fresh = _unit(jax.random.PRNGKey(7), (8, 16))
        slots = jnp.arange(8, dtype=jnp.int32) + 40
        keys = keys.at[40:48].set(fresh)
        valid = valid.at[40:48].set(True)
        st = base.absorb(st, slots, fresh, jnp.ones((8,), bool))
        for backend in ("jnp", "pallas"):
            ivf = IVFIndex(ncentroids=4, nprobe=4, bucket_cap=64, topk=1,
                           backend=backend)
            s, i = ivf.search(st, fresh, keys, valid)
            np.testing.assert_array_equal(np.asarray(i[:, 0]),
                                          np.asarray(slots),
                                          err_msg=backend)
            np.testing.assert_allclose(np.asarray(s[:, 0]), 1.0, rtol=1e-5,
                                       err_msg=backend)


class TestHNSW:
    def test_exact_on_small_sets(self):
        """Paper-faithful HNSW: high recall vs brute force."""
        rng = np.random.default_rng(0)
        vecs = rng.normal(size=(400, 32)).astype(np.float32)
        vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
        idx = HNSWIndex(dim=32, max_elements=512, m=8, ef_construction=64,
                        ef_search=48, seed=0)
        for v in vecs:
            idx.add(v)
        hits = 0
        queries = vecs[:50] + 0.02 * rng.normal(size=(50, 32)).astype(np.float32)
        gt = (queries / np.linalg.norm(queries, axis=1, keepdims=True)) @ vecs.T
        for qi, q in enumerate(queries):
            ids, sims = idx.search(q, k=1)
            if ids[0] == int(np.argmax(gt[qi])):
                hits += 1
        assert hits / 50 >= 0.9, f"HNSW recall {hits / 50}"

    def test_dynamic_resize(self):
        idx = HNSWIndex(dim=8, max_elements=4, m=4, seed=0)
        rng = np.random.default_rng(1)
        for _ in range(10):    # beyond initial max_elements
            idx.add(rng.normal(size=8).astype(np.float32))
        assert idx.count == 10
        assert idx.max_elements >= 10

    def test_empty_search(self):
        idx = HNSWIndex(dim=8)
        ids, sims = idx.search(np.ones(8, dtype=np.float32), k=2)
        assert (ids == -1).all()


class TestPolicies:
    def test_fixed(self):
        p = FixedThreshold(0.8)
        st = p.init_state()
        hit, _ = p.decide(jnp.asarray([0.79, 0.8, 0.95]), st)
        np.testing.assert_array_equal(np.asarray(hit), [False, True, True])

    def test_per_category(self):
        p = PerCategoryThreshold(thresholds=(0.7, 0.9))
        st = p.init_state()
        scores = jnp.asarray([0.8, 0.8])
        cats = jnp.asarray([0, 1])
        hit, _ = p.decide(scores, st, cats)
        np.testing.assert_array_equal(np.asarray(hit), [True, False])

    def test_per_category_requires_categories(self):
        """The uniform protocol call must fail loudly, not silently apply
        one threshold to every query."""
        p = PerCategoryThreshold(thresholds=(0.7, 0.9))
        with pytest.raises(ValueError, match="per-query categories"):
            p.decide(jnp.asarray([0.8]), p.init_state())

    def test_adaptive_raises_threshold_on_false_hits(self):
        p = AdaptiveThreshold(init=0.8, target_precision=0.97, lr=0.05)
        st = p.init_state()
        for _ in range(20):   # every hit judged wrong -> precision collapses
            was_hit = jnp.asarray([True, True, True, True])
            was_pos = jnp.asarray([False, False, False, False])
            st = p.update(st, was_positive=was_pos, was_hit=was_hit)
        assert float(st[0]) > 0.8

    def test_adaptive_lowers_threshold_when_precise(self):
        p = AdaptiveThreshold(init=0.9, target_precision=0.9, lr=0.05)
        st = p.init_state()
        for _ in range(30):   # perfect precision -> harvest more hits
            st = p.update(st, was_positive=jnp.ones(4, bool),
                          was_hit=jnp.ones(4, bool))
        assert float(st[0]) < 0.9

    def test_adaptive_bounded(self):
        p = AdaptiveThreshold(init=0.8, lr=0.5, lo=0.6, hi=0.95)
        st = p.init_state()
        for _ in range(50):
            st = p.update(st, was_positive=jnp.zeros(4, bool),
                          was_hit=jnp.ones(4, bool))
        assert 0.6 <= float(st[0]) <= 0.95

    def test_factory(self):
        assert isinstance(make_policy("fixed"), FixedThreshold)
        assert isinstance(make_policy("adaptive"), AdaptiveThreshold)
        with pytest.raises(ValueError):
            make_policy("nope")
