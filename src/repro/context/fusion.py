"""Context fusion — pooling a session's recent turns into the lookup key
(DESIGN.md §16.2).

The seed paper keys the cache on single isolated queries; multi-turn chat
traffic breaks that: "what about the second one?" embeds nowhere near the
dialogue state it actually asks about, so it can never hit — and worse, the
*same* follow-up text under two different conversations would collide.
ContextCache (arxiv 2506.22791) shows the fix: fuse the last ``W`` turn
embeddings into the query embedding so semantically equivalent *dialogue
states* — not texts — share a key.

This module is the device half of the session subsystem: one jitted pooling
op ``(B, W, d) -> (B, d)`` that runs *inside* the fused ``step()`` (the
window tensor is a traced operand, so every session mix — all-sessionless,
all-deep, interleaved — shares ONE compiled program). Two strategies behind
the ``ContextFusion`` protocol:

  * ``DecayMeanFusion`` — exponential-decay mean over the turn window
    (recent turns weigh more), mixed with the query;
  * ``AttentionFusion`` — the current query attends over the turn window
    (scaled dot-product softmax), so only the turns the query actually
    refers back to contribute.

Both carry their (few, scalar) weights in a ``FusionState`` pytree that
lives as the ``fusion`` leaf group of ``CacheRuntime`` — ``None`` keeps the
pre-session treedef, so single-turn checkpoints and compiled programs are
untouched (the ``tenancy`` pattern, §13.2).

Contract shared by every strategy (tested in ``tests/test_context.py``):
rows with an empty window (``window_len == 0``) return the query embedding
*bit-identically* — a sessionless request through a fusion-enabled cache
behaves exactly like today's stateless path, which is what lets mixed
session/sessionless batches share the compiled step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


def _unit(x: Array, axis: int = -1) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=axis, keepdims=True), _EPS)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FusionState:
    """Fusion weights — one more ``CacheRuntime`` leaf group.

    Leaves (all f32 scalars, so they checkpoint and could be tuned from
    judge feedback like the adaptive threshold, §10):
      context_weight — energy fraction of (rotated) pooled context in the
                       fused key — the query keeps ``1 - cw`` (see ``_mix``);
      decay          — per-turn exponential decay (DecayMeanFusion);
      temp           — attention temperature (AttentionFusion).

    One uniform state class for both strategies keeps the checkpoint
    template identical across strategies: a snapshot taken under decay-mean
    restores into an attention cache (the unused leaf simply rides along).
    """

    context_weight: Array
    decay: Array
    temp: Array

    @staticmethod
    def make(context_weight: float, decay: float = 0.6,
             temp: float = 0.25) -> "FusionState":
        f = jnp.float32
        return FusionState(context_weight=f(context_weight), decay=f(decay),
                           temp=f(temp))


@runtime_checkable
class ContextFusion(Protocol):
    """Pluggable pooling strategy (the ``Index``/``Policy`` pattern, §8/§10).

    A strategy is a static frozen dataclass (hashable — it is baked into
    the compiled step like the index); its numeric weights live in the
    ``FusionState`` it creates, threaded through the runtime.
    """

    window: int   # W — turns pooled per session

    def init_state(self) -> FusionState:
        ...

    def fuse(self, fstate: FusionState, queries: Array, window: Array,
             window_len: Array) -> Array:
        """(B, d) queries + (B, W, d) turn windows -> (B, d) fused keys.

        ``window`` is left-aligned oldest-to-newest: row ``b``'s turns
        occupy ``window[b, :window_len[b]]``; the tail is zeros. Rows with
        ``window_len == 0`` must return ``queries`` bit-identically.
        """
        ...


def _mix(fstate: FusionState, queries: Array, ctx: Array,
         window_len: Array) -> Array:
    """Shared final stage: embed the pooled context in a *rotated* subspace
    and mix with the query at energy split ``context_weight`` (§16.2):

        fused = unit( sqrt(1-cw)·q̂  +  sqrt(cw)·roll(ĉ, d/2) )

    The half-dimension roll decorrelates context from every raw key
    (``v · roll(v) ≈ 0`` for hash embeddings), which is what makes the
    similarity between two fused keys *separable*:

        cos(f1, f2) ≈ (1-cw)·cos(q1,q2) + cw·cos(c1,c2)

    with no cross terms — and the similarity of a fused key to any RAW
    slab key at most ``sqrt(1-cw)``. Consequences, at the paper's 0.8
    threshold with the default cw=0.8: a follow-up can never false-hit
    the entry of a *previous turn* (its key is ≥ 80% rotated context,
    ≈ orthogonal to that raw key), identical follow-up *texts* under two
    different dialogue states score ≈ (1-cw)·1 = 0.2 apart, while two
    phrasings of the same follow-up under the SAME context score
    ≈ cw + (1-cw)·cos(q1,q2) > 0.8. A plain convex mix has none of these
    guarantees — its cross terms drag every follow-up toward the opening
    turn's raw key.

    Empty-window rows pass through untouched (bit-identical)."""
    cw = fstate.context_weight
    a = jnp.sqrt(jnp.maximum(1.0 - cw, 0.0))
    b = jnp.sqrt(cw)
    rot = jnp.roll(_unit(ctx), ctx.shape[-1] // 2, axis=-1)
    fused = _unit(a * _unit(queries) + b * rot)
    return jnp.where((window_len > 0)[:, None], fused, queries)


@dataclasses.dataclass(frozen=True)
class DecayMeanFusion:
    """Exponential-decay mean pooling: turn at age ``a`` (0 = most recent)
    weighs ``decay**a``. Cheap, parameter-light, order-aware."""

    window: int = 4
    context_weight: float = 0.8
    decay: float = 0.6

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("fusion window must be >= 1")
        if not 0.0 <= self.context_weight < 1.0:
            raise ValueError("context_weight must be in [0, 1)")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError("decay must be in (0, 1]")

    def init_state(self) -> FusionState:
        return FusionState.make(self.context_weight, decay=self.decay)

    def fuse(self, fstate: FusionState, queries: Array, window: Array,
             window_len: Array) -> Array:
        b, w, _ = window.shape
        pos = jnp.arange(w, dtype=jnp.int32)[None, :]            # (1, W)
        valid = pos < window_len[:, None]                        # (B, W)
        # turn j (left-aligned) has age L-1-j; clamp keeps pow well-defined
        # on masked lanes
        age = jnp.maximum(window_len[:, None] - 1 - pos, 0).astype(jnp.float32)
        wgt = jnp.where(valid, jnp.power(fstate.decay, age), 0.0)  # (B, W)
        ctx = jnp.einsum("bw,bwd->bd", wgt, _unit(window))
        return _mix(fstate, queries, ctx, window_len)


@dataclasses.dataclass(frozen=True)
class AttentionFusion:
    """Attention-weighted pooling: the query attends over the turn window
    (scaled dot-product softmax at temperature ``temp``), so a follow-up
    that refers back two turns pulls exactly that turn into the key."""

    window: int = 4
    context_weight: float = 0.8
    temp: float = 0.25

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("fusion window must be >= 1")
        if not 0.0 <= self.context_weight < 1.0:
            raise ValueError("context_weight must be in [0, 1)")
        if self.temp <= 0.0:
            raise ValueError("temp must be positive")

    def init_state(self) -> FusionState:
        return FusionState.make(self.context_weight, temp=self.temp)

    def fuse(self, fstate: FusionState, queries: Array, window: Array,
             window_len: Array) -> Array:
        b, w, _ = window.shape
        turns = _unit(window)                                    # (B, W, d)
        valid = jnp.arange(w, dtype=jnp.int32)[None, :] \
            < window_len[:, None]                                # (B, W)
        logits = jnp.einsum("bd,bwd->bw", _unit(queries), turns) / fstate.temp
        logits = jnp.where(valid, logits, -1e9)
        # empty rows: uniform garbage softmax over -1e9 lanes — harmless,
        # _mix routes those rows straight through
        alpha = jax.nn.softmax(logits, axis=-1)                  # (B, W)
        ctx = jnp.einsum("bw,bwd->bd", alpha, turns)
        return _mix(fstate, queries, ctx, window_len)


def fuse_op(fusion: Any, fstate: FusionState, queries: Array, window: Array,
            window_len: Array) -> Array:
    """The standalone jitted ``(B, W, d) -> (B, d)`` pooling op.

    ``SemanticCache.step`` inlines ``fusion.fuse`` into its own jit; this
    wrapper is the same op compiled on its own — parity between the two is
    what ``tests/test_context.py`` pins (the in-step fusion must be the
    plain op, not a divergent reimplementation).
    """
    return jax.jit(
        lambda fs, q, w, wl: fusion.fuse(fs, q, w, wl))(
            fstate, queries, window, window_len)


__all__ = ["ContextFusion", "FusionState", "DecayMeanFusion",
           "AttentionFusion", "fuse_op"]
