"""SessionStore — host-side conversation state for multi-turn caching
(DESIGN.md §16.1).

The device side (``fusion.py``) pools a ``(B, W, d)`` window of turn
embeddings; this module owns those windows. One store per engine holds a
bounded map of sessions, each a fixed-size ring buffer of the session's
last ``W`` turn embeddings.

What gets appended is the turn's **canonical slab key** — the matched
entry's stored key on a hit, the turn's own fused key on a miss (the very
key the fused step inserted). This is dialogue-state canonicalization: two
conversations that walk the same dialogue path through the cache converge
to *identical* turn windows — the replay's turn hits the recording's
entry, appends that entry's key, and therefore fuses the exact context
the recording fused at the next turn. Appending raw query embeddings
instead would let paraphrase noise compound turn over turn (each turn's
window would differ a little more, and by turn 3 the fused keys drift
below threshold — measured in the sweep that sized the defaults).

Lifecycle:
  * ``window_for`` creates-or-touches a session and returns its current
    window (called before the lookup, so a turn sees only *prior* turns);
  * ``append`` pushes the served turn's raw embedding (called after the
    batch, so two turns of one session in the same batch never see each
    other — callers submit a session's turns sequentially);
  * ``expire`` sweeps TTL-dead sessions. The engine runs it on every
    admission flush (DESIGN.md §16.4), not only on touch, so an abandoned
    session cannot pin its turn window until someone happens to touch it;
  * an LRU cap bounds the total session count: creating session
    ``max_sessions + 1`` evicts the least-recently-touched one.

Privacy/tenancy (MeanCache, arxiv 2403.02694): sessions are namespaced by
``(tenant, session_id)`` — the same wire-level session id under two
tenants is two unrelated sessions, so a session can never read another
tenant's turns. This composes with the slab-level isolation of §13: the
fused key is *built* only from the tenant's own turns and *searched* only
in the tenant's own slab region.

Clock: callers pass ``now`` explicitly (the engine passes its TTL clock,
``tick``-driven in tests) — the store never reads wall time, which keeps
expiry deterministic and testable like the slab's own TTL (§2.7).

Thread-safety: all methods are called from the engine's serve path, which
is single-threaded by construction (sync ``process`` loop, or the async
scheduler's single worker executor).
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


class _Session:
    """One conversation: a (W, d) ring of raw turn embeddings."""

    __slots__ = ("ring", "count", "ptr", "last_touch")

    def __init__(self, window: int, dim: int, now: float):
        self.ring = np.zeros((window, dim), dtype=np.float32)
        self.count = 0          # turns retained (<= window)
        self.ptr = 0            # next write slot
        self.last_touch = now

    def append(self, emb: np.ndarray) -> None:
        self.ring[self.ptr] = emb
        self.ptr = (self.ptr + 1) % self.ring.shape[0]
        self.count = min(self.count + 1, self.ring.shape[0])

    def window(self) -> tuple[np.ndarray, int]:
        """Left-aligned oldest-to-newest copy (the fusion-op layout)."""
        w = self.ring.shape[0]
        out = np.zeros_like(self.ring)
        if self.count == w:
            out[:] = np.roll(self.ring, -self.ptr, axis=0)
        elif self.count:
            out[:self.count] = self.ring[:self.count]
        return out, self.count


class SessionStore:
    """Bounded TTL + LRU map of ``(tenant, session_id) -> turn window``."""

    def __init__(self, *, window: int, dim: int,
                 ttl: float | None = 1800.0, max_sessions: int = 4096):
        if window < 1 or dim < 1:
            raise ValueError("window and dim must be positive")
        if max_sessions < 1:
            raise ValueError("max_sessions must be positive")
        if ttl is not None and ttl <= 0:
            raise ValueError("ttl must be positive (or None to disable)")
        self.window_size = window
        self.dim = dim
        self.ttl = ttl
        self.max_sessions = max_sessions
        # insertion/touch order IS the LRU order (move_to_end on touch)
        self._sessions: "OrderedDict[tuple[str, str], _Session]" \
            = OrderedDict()
        self.created = 0
        self.expired_ttl = 0
        self.evicted_lru = 0

    def __len__(self) -> int:
        return len(self._sessions)

    def _get(self, tenant: str, session: str, now: float) -> _Session:
        key = (tenant, session)
        s = self._sessions.get(key)
        if s is not None and self.ttl is not None \
                and now - s.last_touch > self.ttl:
            # stale hit on touch: the id is reused but the conversation is
            # long over — restart it rather than fuse ancient context
            del self._sessions[key]
            self.expired_ttl += 1
            s = None
        if s is None:
            s = _Session(self.window_size, self.dim, now)
            self._sessions[key] = s
            self.created += 1
            while len(self._sessions) > self.max_sessions:
                self._sessions.popitem(last=False)
                self.evicted_lru += 1
        s.last_touch = now
        self._sessions.move_to_end(key)
        return s

    # -- serve-path API -------------------------------------------------- #
    def window_for(self, tenant: str, session: str, now: float
                   ) -> tuple[np.ndarray, int]:
        """(W, d) left-aligned turn window + turn count; creates/touches."""
        return self._get(tenant, session, now).window()

    def append(self, tenant: str, session: str, emb: np.ndarray,
               now: float) -> None:
        """Push one served turn's raw embedding onto the session's ring."""
        self._get(tenant, session, now).append(
            np.asarray(emb, dtype=np.float32))

    def expire(self, now: float) -> int:
        """TTL sweep (the flush-path hygiene pass, §16.4): drop every
        session idle longer than ``ttl``. Returns the number dropped."""
        if self.ttl is None:
            return 0
        dead = [k for k, s in self._sessions.items()
                if now - s.last_touch > self.ttl]
        for k in dead:
            del self._sessions[k]
        self.expired_ttl += len(dead)
        return len(dead)

    def turns(self, tenant: str, session: str) -> int:
        """Retained turn count (0 if the session does not exist) — a
        read-only probe that neither creates nor touches."""
        s = self._sessions.get((tenant, session))
        return s.count if s is not None else 0

    def stats(self) -> dict:
        return {"sessions": len(self._sessions), "created": self.created,
                "expired_ttl": self.expired_ttl,
                "evicted_lru": self.evicted_lru,
                "window": self.window_size,
                "max_sessions": self.max_sessions}


__all__ = ["SessionStore"]
