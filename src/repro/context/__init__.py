"""Session subsystem: context-aware multi-turn caching (DESIGN.md §16).

``SessionStore`` (host) keeps per-session ring buffers of raw turn
embeddings; ``ContextFusion`` strategies (device) pool a ``(B, W, d)``
window of them into the ``(B, d)`` lookup key inside the fused cache step,
so semantically equivalent *dialogue states* hit where isolated follow-up
texts never could.
"""
from repro.context.fusion import (AttentionFusion, ContextFusion,
                                  DecayMeanFusion, FusionState, fuse_op)
from repro.context.session import SessionStore

__all__ = ["AttentionFusion", "ContextFusion", "DecayMeanFusion",
           "FusionState", "SessionStore", "fuse_op"]
