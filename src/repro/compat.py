"""Small jax version-compatibility shims (single source of truth).

The repo targets the latest stable jax API but must run on the pinned CI
jax[cpu] as well; the two differences that matter here:

* ``shard_map`` moved from ``jax.experimental.shard_map`` to top-level
  ``jax.shard_map``;
* its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map_fn
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_fn

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map_fn).parameters
             else "check_rep")


def shard_map_nocheck(f, mesh, in_specs, out_specs):
    """shard_map with replication checking disabled, any jax version."""
    return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **{_CHECK_KW: False})
