"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060], TPU-adapted.

The SSD *dual form* is the TPU-native formulation of the selective-scan:
sequence chunks of length Q are processed with dense matmuls (MXU) —
an intra-chunk "attention-like" quadratic term plus an inter-chunk
recurrence on the (H, P, N) state carried through a ``lax.scan``. This is
exactly the hardware adaptation the paper's CUDA kernel performs for GPUs
(DESIGN.md: rethink blocking for the memory hierarchy), expressed here in
JAX so XLA pipelines chunk GEMMs.

Layer = in_proj -> causal depthwise conv (x,B,C) -> SiLU -> SSD ->
gated RMSNorm (y · silu(z)) -> out_proj, matching the published block.

Decode is the recurrent form: S ← exp(dt·A)·S + dt·B·x, y = C·S + D·x,
with a (d_conv-1)-deep conv ring state — O(1) per token, no KV cache, which
is why the SSM/hybrid archs run the 524k long-context shape natively.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm

Array = jax.Array


def ssm_dims(config: ModelConfig) -> dict:
    d_inner = config.d_inner
    h = config.ssm_nheads
    g, n = config.ssm_ngroups, config.ssm_state
    conv_dim = d_inner + 2 * g * n
    in_dim = 2 * d_inner + 2 * g * n + h   # z, xBC, dt
    return dict(d_inner=d_inner, nheads=h, ngroups=g, state=n,
                conv_dim=conv_dim, in_dim=in_dim, headdim=config.ssm_headdim)


def _split_proj(zxbcdt: Array, dims: dict) -> tuple[Array, Array, Array]:
    d_inner, conv_dim = dims["d_inner"], dims["conv_dim"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def causal_conv1d(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x (B, L, C), w (K, C), b (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _segsum_exp(a_cum: Array) -> Array:
    """L[i, j] = exp(a_cum[i] - a_cum[j]) for i >= j else 0. a_cum (..., Q)."""
    q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(x: Array, dt: Array, a: Array, b_mat: Array, c_mat: Array,
             d_skip: Array, chunk: int, init_state: Array | None = None
             ) -> tuple[Array, Array]:
    """Chunked SSD. Shapes:
      x (B, L, H, P); dt (B, L, H) (post-softplus); a (H,) (negative);
      b_mat/c_mat (B, L, G, N); d_skip (H,).
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    l_orig = l
    if l % q:   # pad to a chunk multiple; dt=0 rows are state-transparent
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // q

    xr = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtr = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    br = jnp.repeat(b_mat.reshape(bsz, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    cr = jnp.repeat(c_mat.reshape(bsz, nc, q, g, n), rep, axis=3).astype(jnp.float32)

    da = dtr * a[None, None, None, :]           # (B,nc,Q,H)
    a_cum = jnp.cumsum(da, axis=2)              # within-chunk cumulative decay

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), dtype=jnp.float32)

    def chunk_step(state, inputs):
        xc, dtc, bc, cc, a_cumc = inputs        # (B,Q,H,P),(B,Q,H),(B,Q,H,N)x2,(B,Q,H)
        lmat = _segsum_exp(a_cumc.transpose(0, 2, 1))          # (B,H,Q,Q)
        # intra-chunk: scores[i,j] = C_i·B_j * L[i,j] * dt_j
        scores = jnp.einsum("bihn,bjhn->bhij", cc, bc) * lmat
        scores = scores * dtc.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhp->bihp", scores, xc)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bihn,bhpn->bihp", cc, state) \
            * jnp.exp(a_cumc)[..., None]
        # state update: S' = exp(a_sum)·S + Σ_j exp(a_sum - a_cum[j])·dt_j·B_j x_j^T
        a_sum = a_cumc[:, -1]                   # (B,H)
        decay = jnp.exp(a_sum[:, None] - a_cumc) * dtc          # (B,Q,H)
        ds = jnp.einsum("bjh,bjhn,bjhp->bhpn", decay, bc, xc)
        state = jnp.exp(a_sum)[..., None, None] * state + ds
        return state, y_intra + y_inter

    # scan over chunks (moveaxis chunk dim to front for xs)
    xs = (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
          jnp.moveaxis(br, 1, 0), jnp.moveaxis(cr, 1, 0),
          jnp.moveaxis(a_cum, 1, 0))
    final_state, ys = jax.lax.scan(chunk_step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y[:, :l_orig], final_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMState:
    """Decode-time recurrent state, layers stacked on the leading axis."""

    conv: Array   # (L, B, K-1, conv_dim) conv ring
    ssd: Array    # (L, B, H, P, N) SSD state


def init_ssm_state(config: ModelConfig, batch: int) -> SSMState:
    dims = ssm_dims(config)
    l = config.n_layers
    return SSMState(
        conv=jnp.zeros((l, batch, config.ssm_conv - 1, dims["conv_dim"]),
                       dtype=jnp.float32),
        ssd=jnp.zeros((l, batch, dims["nheads"], dims["headdim"],
                       dims["state"]), dtype=jnp.float32),
    )


def ssm_forward(params: dict, x: Array, config: ModelConfig,
                return_state: bool = False):
    """Full-sequence forward of one SSM layer. x (B, L, d_model).

    With ``return_state`` also returns (conv_state (B, K-1, conv_dim),
    ssd_state (B, H, P, N)) — the decode-continuation states after the
    last position (prefill -> decode handoff).
    """
    dims = ssm_dims(config)
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc_raw, dt = _split_proj(zxbcdt, dims)
    xbc = jax.nn.silu(causal_conv1d(xbc_raw, params["conv_w"], params["conv_b"]))
    d_inner, g, n = dims["d_inner"], dims["ngroups"], dims["state"]
    h, p = dims["nheads"], dims["headdim"]
    x_ssm = xbc[..., :d_inner].reshape(*xbc.shape[:2], h, p)
    b_mat = xbc[..., d_inner:d_inner + g * n].reshape(*xbc.shape[:2], g, n)
    c_mat = xbc[..., d_inner + g * n:].reshape(*xbc.shape[:2], g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, final_state = ssd_scan(x_ssm, dt, a, b_mat, c_mat, params["d_skip"],
                              config.ssm_chunk)
    y = y.reshape(*x.shape[:2], d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), params["norm_w"])
    out = jnp.einsum("ble,ed->bld", y.astype(x.dtype), params["out_proj"])
    if not return_state:
        return out
    km1 = config.ssm_conv - 1
    conv_state = xbc_raw[:, -km1:, :].astype(jnp.float32)
    if xbc_raw.shape[1] < km1:   # shorter-than-window prefill: left-pad zeros
        pad = km1 - xbc_raw.shape[1]
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    return out, conv_state, final_state


def ssm_decode_step(params: dict, x: Array, conv_state: Array, ssd_state: Array,
                    config: ModelConfig) -> tuple[Array, Array, Array]:
    """One-token recurrent step. x (B, 1, d_model).

    Returns (y (B, 1, d_model), new_conv_state, new_ssd_state).
    """
    dims = ssm_dims(config)
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc_new, dt = _split_proj(zxbcdt, dims)
    # conv ring: window = [conv_state, xbc_new]
    window = jnp.concatenate([conv_state, xbc_new.astype(jnp.float32)], axis=1)
    w = params["conv_w"]                           # (K, C)
    xbc = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    xbc = jax.nn.silu(xbc)[:, None, :]             # (B, 1, C)
    new_conv = window[:, 1:, :]

    d_inner, g, n = dims["d_inner"], dims["ngroups"], dims["state"]
    h, p = dims["nheads"], dims["headdim"]
    rep = h // g
    x_ssm = xbc[..., :d_inner].reshape(-1, h, p).astype(jnp.float32)
    b_mat = xbc[..., d_inner:d_inner + g * n].reshape(-1, g, n)
    c_mat = xbc[..., d_inner + g * n:].reshape(-1, g, n)
    b_h = jnp.repeat(b_mat, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    c_h = jnp.repeat(c_mat, rep, axis=1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a[None, :])                           # (B,H)
    ds = jnp.einsum("bh,bhn,bhp->bhpn", dt1, b_h, x_ssm)
    new_ssd = decay[..., None, None] * ssd_state + ds
    y = jnp.einsum("bhn,bhpn->bhp", c_h, new_ssd)
    y = y + x_ssm * params["d_skip"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), params["norm_w"])
    out = jnp.einsum("ble,ed->bld", y.astype(x.dtype), params["out_proj"])
    return out, new_conv, new_ssd


def init_ssm_params(rng: Array, config: ModelConfig, dtype) -> dict:
    dims = ssm_dims(config)
    k1, k2, k3 = jax.random.split(rng, 3)
    d = config.d_model
    scale = d ** -0.5
    return {
        "in_proj": (jax.random.normal(k1, (d, dims["in_dim"])) * scale).astype(dtype),
        "conv_w": (jax.random.normal(k2, (config.ssm_conv, dims["conv_dim"]))
                   * (config.ssm_conv ** -0.5)).astype(jnp.float32),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype=jnp.float32),
        "dt_bias": jnp.zeros((dims["nheads"],), dtype=jnp.float32),
        "a_log": jnp.zeros((dims["nheads"],), dtype=jnp.float32),
        "d_skip": jnp.ones((dims["nheads"],), dtype=jnp.float32),
        "norm_w": jnp.ones((dims["d_inner"],), dtype=jnp.float32),
        "out_proj": (jax.random.normal(k3, (dims["d_inner"], d))
                     * (dims["d_inner"] ** -0.5)).astype(dtype),
    }
