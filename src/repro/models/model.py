"""Model assembly for all six assigned arch families.

One ``Model`` class builds dense GQA / MoE / SSM / hybrid / audio / VLM
backbones from a ``ModelConfig``:

  * layer weights are stacked on a leading *group* axis and the forward
    pass is a ``lax.scan`` over groups (HLO depth-independent — llama3's
    126 layers compile as one scanned body);
  * a group holds ``moe_interleave`` layers; for MoE archs the last slot in
    each group is the MoE layer (llama4: dense/MoE alternation; grok: every
    layer MoE with interleave=1);
  * hybrid (hymba) layers run attention and SSD heads *in parallel* on the
    same normed input, per-branch-normalized and mean-fused, with
    ``n_meta_tokens`` learned registers prepended as attention sinks;
  * audio (musicgen) sums ``n_codebooks`` embedding tables and emits one
    head per codebook; vlm (qwen2-vl) consumes stub patch embeddings with
    M-RoPE grid positions.

Three entry points per model (the shapes' three workloads):
  ``forward``      — full-sequence logits (train_4k, prefill_32k),
  ``prefill``      — forward + KV/SSM cache construction,
  ``decode_step``  — one token against the caches (decode_32k, long_500k).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (KVCache, blockwise_attention, cache_write,
                                    decode_attention, init_kv_cache)
from repro.models.layers import rms_norm, rope_for, positionize, unembed
from repro.models.ssm import SSMState, init_ssm_state

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeCaches:
    """Everything ``decode_step`` threads through. Fields may be None-like
    (zero-size arrays) depending on the arch family."""

    kv: Optional[KVCache]
    ssm: Optional[SSMState]


def _dtype(config: ModelConfig):
    return jnp.bfloat16 if config.dtype == "bfloat16" else jnp.float32


class Model:
    """Config-driven multi-family decoder. Stateless; params are pytrees."""

    def __init__(self, config: ModelConfig, mesh=None,
                 data_axes: tuple[str, ...] = ("data",),
                 model_axes: tuple[str, ...] = ("model",),
                 opt_attn_sharding: bool = False,
                 opt_seq_parallel: bool = False,
                 remat_policy: str = "full"):
        self.config = config
        self.mesh = mesh
        self.data_axes = data_axes
        self.model_axes = model_axes
        # §Perf knobs (EXPERIMENTS.md): explicit sharding constraints on the
        # attention block (kills GSPMD's speculative all-gathers in the
        # blockwise-attention scan) and sequence-parallel residuals
        # (Megatron-SP: halves TP activation traffic).
        self.opt_attn_sharding = opt_attn_sharding
        self.opt_seq_parallel = opt_seq_parallel
        # "full" = nothing saveable (recompute everything), "dots" = save
        # matmul outputs (no recompute of TP collectives in bwd), "none" =
        # no remat. §Perf knob: trades HBM for recomputed FLOPs+collectives.
        self.remat_policy = remat_policy
        c = config
        self.n_groups = c.n_layers // c.moe_interleave
        self.interleave = c.moe_interleave
        self.n_mlp_slots = (self.interleave - 1) if c.is_moe else (
            self.interleave if c.d_ff > 0 else 0)

    # ------------------------------------------------------------------ #
    # sharding constraints (perf knobs; no-ops without a mesh)
    # ------------------------------------------------------------------ #
    def _constrain(self, x: Array, *spec) -> Array:
        if self.mesh is None or not self.opt_attn_sharding:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def _sp(self, x: Array) -> Array:
        """Sequence-parallel residual constraint (Megatron-SP): the residual
        stream lives sequence-sharded over `model`, so each TP sublayer exits
        through a reduce-scatter (operand counted once) instead of an
        all-reduce (2x), and norms/adds compute on 1/TP of the tokens. GSPMD
        inserts the matching all-gather where the next projection needs the
        full sequence."""
        if self.mesh is None or not self.opt_seq_parallel or x.ndim != 3:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self._dp(), "model", None)))

    def _dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    # ------------------------------------------------------------------ #
    # parameter construction
    # ------------------------------------------------------------------ #
    def init_params(self, rng: Array) -> dict:
        c = self.config
        dt = _dtype(c)
        g_cnt, i_cnt = self.n_groups, self.interleave
        d, vp = c.d_model, c.padded_vocab
        keys = jax.random.split(rng, 16)
        kit = iter(keys)

        def nrm(key, shape, scale):
            return (jax.random.normal(key, shape, dtype=jnp.float32)
                    * scale).astype(dt)

        params: dict[str, Any] = {}
        if c.n_codebooks > 1:
            params["embed"] = nrm(next(kit), (c.n_codebooks, vp, d), 0.02)
            params["lm_head"] = nrm(next(kit), (c.n_codebooks, d, vp), d ** -0.5)
        else:
            params["embed"] = nrm(next(kit), (vp, d), 0.02)
            params["lm_head"] = nrm(next(kit), (d, vp), d ** -0.5)
        params["final_norm"] = jnp.ones((d,), dtype=jnp.float32)
        if c.n_prefix > 0:
            params["prefix_proj"] = nrm(next(kit), (d, d), d ** -0.5)
        if c.n_meta_tokens > 0:
            params["meta_tokens"] = nrm(next(kit), (c.n_meta_tokens, d), 0.02)

        blocks: dict[str, Any] = {}
        if c.has_attention:
            h, hkv, hd = c.n_heads, c.n_kv_heads, c.head_dim
            blocks["norm1"] = jnp.ones((g_cnt, i_cnt, d), dtype=jnp.float32)
            blocks["wq"] = nrm(next(kit), (g_cnt, i_cnt, d, h * hd), d ** -0.5)
            blocks["wk"] = nrm(next(kit), (g_cnt, i_cnt, d, hkv * hd), d ** -0.5)
            blocks["wv"] = nrm(next(kit), (g_cnt, i_cnt, d, hkv * hd), d ** -0.5)
            blocks["wo"] = nrm(next(kit), (g_cnt, i_cnt, h * hd, d),
                               (h * hd) ** -0.5)
        if c.has_ssm:
            if not c.has_attention:   # pure ssm arch: own input norm
                blocks["norm1"] = jnp.ones((g_cnt, i_cnt, d), dtype=jnp.float32)
            ssm_stack = []
            srng = jax.random.split(next(kit), g_cnt * i_cnt)
            for li in range(g_cnt * i_cnt):
                ssm_stack.append(ssm_lib.init_ssm_params(srng[li], c, dt))
            blocks["ssm"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs).reshape((g_cnt, i_cnt) + xs[0].shape),
                *ssm_stack)
        if self.n_mlp_slots > 0:
            ff = c.d_ff
            blocks["norm2"] = jnp.ones((g_cnt, self.n_mlp_slots, d),
                                       dtype=jnp.float32)
            blocks["mlp_gate"] = nrm(next(kit), (g_cnt, self.n_mlp_slots, d, ff),
                                     d ** -0.5)
            blocks["mlp_up"] = nrm(next(kit), (g_cnt, self.n_mlp_slots, d, ff),
                                   d ** -0.5)
            blocks["mlp_down"] = nrm(next(kit), (g_cnt, self.n_mlp_slots, ff, d),
                                     ff ** -0.5)
        if c.is_moe:
            e, ff = c.n_experts, c.d_ff
            blocks["moe_norm"] = jnp.ones((g_cnt, d), dtype=jnp.float32)
            blocks["router"] = nrm(next(kit), (g_cnt, d, e), d ** -0.5)
            blocks["moe_gate"] = nrm(next(kit), (g_cnt, e, d, ff), d ** -0.5)
            blocks["moe_up"] = nrm(next(kit), (g_cnt, e, d, ff), d ** -0.5)
            blocks["moe_down"] = nrm(next(kit), (g_cnt, e, ff, d), ff ** -0.5)
        params["blocks"] = blocks
        return params

    # ------------------------------------------------------------------ #
    # per-layer pieces
    # ------------------------------------------------------------------ #
    def _window_list(self) -> list[int]:
        """Per-layer attention window; -1 = global."""
        c = self.config
        wins = []
        for li in range(c.n_layers):
            if c.arch_type == "hybrid" and c.global_attn_every:
                w = -1 if li % c.global_attn_every == 0 else c.sliding_window
            elif c.sliding_window:
                w = c.sliding_window
            else:
                w = -1
            wins.append(w if w is not None else -1)
        return wins

    def _window_table(self) -> jnp.ndarray:
        """(G, I) int32 attention window per layer; -1 = global."""
        return jnp.asarray(self._window_list(), dtype=jnp.int32).reshape(
            self.n_groups, self.interleave)

    def _uniform_window(self) -> int | None | str:
        """The common static window if all layers agree, else 'mixed'.

        Returns None for uniformly-global, an int for a uniform window, or
        the string 'mixed' when per-layer windows differ (hymba) — mixed
        forces the traced-window path (no static block pruning).
        """
        wins = set(self._window_list())
        if len(wins) > 1:
            return "mixed"
        w = wins.pop()
        return None if w < 0 else w

    def _attn_seq(self, lp: dict, s: int, x: Array, positions: Array,
                  window, n_sink: int, block_q: int, block_k: int) -> Array:
        """Full-sequence attention sublayer for slot ``s``. x (B, L, d)."""
        c = self.config
        h, hkv, hd = c.n_heads, c.n_kv_heads, c.head_dim
        b, l, d = x.shape
        q = jnp.einsum("bld,de->ble", x, lp["wq"][s]).reshape(b, l, h, hd)
        k = jnp.einsum("bld,de->ble", x, lp["wk"][s]).reshape(b, l, hkv, hd)
        v = jnp.einsum("bld,de->ble", x, lp["wv"][s]).reshape(b, l, hkv, hd)
        q = rope_for(c, q, positions)
        k = rope_for(c, k, positions)
        # §Perf: pin the TP layout for the attention inner loop — query heads
        # shard over `model` (GSPMD pads non-divisible head counts), KV heads
        # replicate (small: one AG per layer instead of per KV block).
        dp = self._dp()
        cache_k, cache_v = k, v
        if self.opt_attn_sharding and hkv < h:
            # expand KV groups so the (b,l,h,hd) -> (b,h,...) reshape keeps
            # the head sharding (GQA group splits would break it); the
            # expansion is a broadcast of already-replicated KV.
            g = h // hkv
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = self._constrain(q, dp, None, "model", None)
        k = self._constrain(k, dp, None, "model" if k.shape[2] == h else None,
                            None)
        v = self._constrain(v, dp, None, "model" if v.shape[2] == h else None,
                            None)
        o = blockwise_attention(q, k, v, causal=True, window=window,
                                n_sink=n_sink, block_q=block_q, block_k=block_k)
        o = self._constrain(o, dp, None, "model", None)
        o = o.reshape(b, l, h * hd)
        out = jnp.einsum("ble,ed->bld", o, lp["wo"][s])
        out = self._sp(out) if self.opt_seq_parallel \
            else self._constrain(out, dp, None, None)
        return out, cache_k, cache_v

    def _ffn(self, lp: dict, x: Array, s: int, is_moe_slot: bool
             ) -> tuple[Array, Array]:
        """FFN sublayer: dense SwiGLU or MoE. Returns (y, aux)."""
        c = self.config
        zero = jnp.zeros((), dtype=jnp.float32)
        if not is_moe_slot:
            xn = rms_norm(x, lp["norm2"][s])
            g = jnp.einsum("bld,df->blf", xn, lp["mlp_gate"][s])
            u = jnp.einsum("bld,df->blf", xn, lp["mlp_up"][s])
            y = jnp.einsum("blf,fd->bld", jax.nn.silu(g) * u, lp["mlp_down"][s])
            return self._sp(y), zero
        xn = rms_norm(x, lp["moe_norm"])
        b, l, d = xn.shape
        flat = xn.reshape(b * l, d)
        use_shard_map = False
        if self.mesh is not None:
            n_data = 1
            for ax in self.data_axes:
                n_data *= self.mesh.shape[ax]
            # shard_map needs the token batch to split evenly over data
            use_shard_map = (b * l) % n_data == 0 and (b * l) >= n_data
        if use_shard_map:
            fn = moe_lib.moe_ffn_sharded(self.mesh, self.data_axes,
                                         self.model_axes)
            y, aux = fn(flat, lp["router"], lp["moe_gate"], lp["moe_up"],
                        lp["moe_down"], topk=c.moe_topk,
                        capacity_factor=c.capacity_factor)
        else:
            y, aux = moe_lib.moe_ffn(flat, lp["router"], lp["moe_gate"],
                                     lp["moe_up"], lp["moe_down"],
                                     topk=c.moe_topk,
                                     capacity_factor=c.capacity_factor)
        return y.reshape(b, l, d), aux

    # ------------------------------------------------------------------ #
    # embedding / head
    # ------------------------------------------------------------------ #
    def _embed(self, params: dict, tokens: Array) -> Array:
        c = self.config
        if c.n_codebooks > 1:       # audio: tokens (B, L, C); sum codebooks
            parts = [params["embed"][cb][tokens[..., cb]]
                     for cb in range(c.n_codebooks)]
            return functools.reduce(jnp.add, parts)
        return params["embed"][tokens]

    def _head(self, params: dict, x: Array) -> Array:
        c = self.config
        x = rms_norm(x, params["final_norm"])
        if c.n_codebooks > 1:
            logits = jnp.einsum("bld,cdv->blcv", x, params["lm_head"])
            return unembed_multi(logits, c.vocab)
        return unembed(x, params["lm_head"], c.vocab)

    def _prepend_context(self, params: dict, x: Array, positions: Array,
                         prefix_emb: Array | None):
        """Prepend (meta tokens +) projected frontend embeddings.

        Returns (x, positions, n_lead) where n_lead = prepended length.
        positions for prepended tokens occupy 0..n_lead-1 and the supplied
        positions are shifted up (callers pass 0-based text positions).
        """
        c = self.config
        b = x.shape[0]
        lead = []
        if c.n_meta_tokens > 0:
            meta = jnp.broadcast_to(params["meta_tokens"][None],
                                    (b,) + params["meta_tokens"].shape)
            lead.append(meta.astype(x.dtype))
        if prefix_emb is not None:
            proj = jnp.einsum("bpd,de->bpe", prefix_emb.astype(x.dtype),
                              params["prefix_proj"])
            lead.append(proj)
        if not lead:
            return x, positionize(c, positions), 0
        lead_x = jnp.concatenate(lead, axis=1)
        n_lead = lead_x.shape[1]
        x = jnp.concatenate([lead_x, x], axis=1)
        if c.mrope:
            positions3 = positionize(c, positions) + n_lead
            lead_pos = self._mrope_grid_positions(b, n_lead)
            positions = jnp.concatenate([lead_pos, positions3], axis=1)
        else:
            lead_pos = jnp.broadcast_to(jnp.arange(n_lead, dtype=positions.dtype),
                                        (b, n_lead))
            positions = jnp.concatenate([lead_pos, positions + n_lead], axis=1)
        return x, positions, n_lead

    def _mrope_grid_positions(self, b: int, n: int) -> Array:
        """Stub-ViT patch grid (t=0, h=row, w=col) M-RoPE positions."""
        side = max(int(n ** 0.5), 1)
        idx = jnp.arange(n)
        t = jnp.zeros((n,), dtype=jnp.int32)
        hh = (idx // side).astype(jnp.int32)
        ww = (idx % side).astype(jnp.int32)
        pos3 = jnp.stack([t, hh, ww], axis=-1)            # (n, 3)
        return jnp.broadcast_to(pos3[None], (b, n, 3))

    # ------------------------------------------------------------------ #
    # full-sequence forward (train / prefill)
    # ------------------------------------------------------------------ #
    def forward(self, params: dict, tokens: Array, *,
                prefix_emb: Array | None = None, collect_cache: bool = False,
                cache_size: int | None = None, remat: bool = False,
                logits_last_only: bool = False,
                block_q: int = 1024, block_k: int = 1024):
        """Returns logits (B, L_text, ...) [, caches], aux_loss.

        ``logits_last_only`` computes the LM head on the final position only
        (serving prefill: the 32k x vocab unembed would dominate otherwise).
        """
        c = self.config
        b = tokens.shape[0]
        l_text = tokens.shape[1]
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(l_text, dtype=jnp.int32),
                                     (b, l_text))
        x, positions, n_lead = self._prepend_context(
            params, x, positions, prefix_emb)
        l_total = x.shape[1]
        window_tbl = self._window_table()
        uniform_win = self._uniform_window()
        n_sink = c.n_meta_tokens

        def group_body(carry, xs):
            x, aux = carry
            lp, wins = xs
            new_k, new_v, new_conv, new_ssd = [], [], [], []
            for s in range(self.interleave):
                if uniform_win == "mixed":   # traced per-layer window
                    win = wins[s]
                    win_eff = jnp.where(win < 0, jnp.int32(l_total + 1), win)
                else:                        # static: enables block pruning
                    win_eff = uniform_win
                if c.has_attention:
                    xn = rms_norm(x, lp["norm1"][s])
                    attn_out, k, v = self._attn_seq(
                        lp, s, xn, positions, win_eff, n_sink, block_q, block_k)
                    if c.arch_type == "hybrid":
                        ssm_p = jax.tree_util.tree_map(lambda a: a[s], lp["ssm"])
                        if collect_cache:
                            ssm_out, cs, ss = ssm_lib.ssm_forward(
                                ssm_p, xn, c, return_state=True)
                            new_conv.append(cs)
                            new_ssd.append(ss)
                        else:
                            ssm_out = ssm_lib.ssm_forward(ssm_p, xn, c)
                        # per-branch norm then mean fusion (hymba §3)
                        fused = 0.5 * (_branch_norm(attn_out)
                                       + _branch_norm(ssm_out))
                        x = self._sp(x + fused.astype(x.dtype))
                    else:
                        x = self._sp(x + attn_out)
                    if collect_cache:
                        new_k.append(k)
                        new_v.append(v)
                else:    # pure ssm
                    xn = rms_norm(x, lp["norm1"][s])
                    ssm_p = jax.tree_util.tree_map(lambda a: a[s], lp["ssm"])
                    if collect_cache:
                        y, cs, ss = ssm_lib.ssm_forward(
                            ssm_p, xn, c, return_state=True)
                        new_conv.append(cs)
                        new_ssd.append(ss)
                    else:
                        y = ssm_lib.ssm_forward(ssm_p, xn, c)
                    x = x + y
                is_moe_slot = c.is_moe and s == self.interleave - 1
                if is_moe_slot or self.n_mlp_slots > 0 and s < self.n_mlp_slots:
                    y, a = self._ffn(lp, x, s, is_moe_slot)
                    x = self._sp(x + y)
                    aux = aux + a
            ys = {}
            if collect_cache and c.has_attention:
                ys["k"] = jnp.stack(new_k)     # (I, B, L, HKV, D)
                ys["v"] = jnp.stack(new_v)
            if collect_cache and c.has_ssm:
                ys["conv"] = jnp.stack(new_conv)
                ys["ssd"] = jnp.stack(new_ssd)
            return (x, aux), (ys or None)

        body = group_body
        if remat and self.remat_policy != "none":
            if self.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            else:
                policy = jax.checkpoint_policies.nothing_saveable
            body = jax.checkpoint(group_body, policy=policy)

        (x, aux), cache_ys = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], window_tbl))

        x_out = x[:, -1:] if logits_last_only else x[:, n_lead:]
        logits = self._head(params, x_out)
        if not collect_cache:
            return logits, aux

        caches = self._build_prefill_caches(cache_ys, l_total, cache_size, b)
        return logits, caches, aux

    def _build_prefill_caches(self, cache_ys, l_total: int,
                              cache_size: int | None, b: int) -> DecodeCaches:
        c = self.config
        kv = None
        if c.has_attention and cache_ys is not None:
            ks, vs = cache_ys["k"], cache_ys["v"]  # (G, I, B, L, HKV, D)
            ks = ks.reshape((c.n_layers,) + ks.shape[2:])
            vs = vs.reshape((c.n_layers,) + vs.shape[2:])
            size = cache_size or l_total
            if size >= l_total:                # plain copy into the front
                pad = size - l_total
                ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                slot_pos = jnp.concatenate([
                    jnp.arange(l_total, dtype=jnp.int32),
                    jnp.full((pad,), -1, jnp.int32)])
            else:                              # ring: keep the last `size`
                slots = jnp.arange(size)
                # position stored in ring slot i after prefilling l_total:
                last = l_total - 1
                pos_i = last - ((last - slots) % size)
                take = jnp.where(pos_i >= 0, pos_i, 0)
                ks = jnp.take(ks, take, axis=2)
                vs = jnp.take(vs, take, axis=2)
                slot_pos = jnp.where(pos_i >= 0, pos_i, -1).astype(jnp.int32)
            kv = KVCache(k=ks, v=vs, slot_pos=slot_pos,
                         pos=jnp.asarray(l_total, jnp.int32))
        ssm = None
        if c.has_ssm and cache_ys is not None and "conv" in cache_ys:
            conv = cache_ys["conv"]            # (G, I, B, K-1, conv_dim)
            ssd = cache_ys["ssd"]              # (G, I, B, H, P, N)
            ssm = SSMState(
                conv=conv.reshape((c.n_layers,) + conv.shape[2:]),
                ssd=ssd.reshape((c.n_layers,) + ssd.shape[2:]))
        return DecodeCaches(kv=kv, ssm=ssm)

    # ------------------------------------------------------------------ #
    # decode
    # ------------------------------------------------------------------ #
    def init_decode_caches(self, batch: int, cache_size: int,
                           kv_quantized: bool = False) -> DecodeCaches:
        c = self.config
        kv = None
        if c.has_attention:
            kv = init_kv_cache(c.n_layers, batch, cache_size, c.n_kv_heads,
                               c.head_dim, dtype=_dtype(c),
                               quantized=kv_quantized)
        ssm = init_ssm_state(c, batch) if c.has_ssm else None
        return DecodeCaches(kv=kv, ssm=ssm)

    def decode_step(self, params: dict, caches: DecodeCaches, tokens: Array
                    ) -> tuple[Array, DecodeCaches]:
        """One token for every sequence. tokens (B, 1) or (B, 1, C)."""
        c = self.config
        b = tokens.shape[0]
        x = self._embed(params, tokens)
        pos = caches.kv.pos if caches.kv is not None else _ssm_pos(caches)
        positions = jnp.full((b, 1), pos, dtype=jnp.int32)
        positions = positionize(c, positions)
        window_tbl = self._window_table()
        n_sink = c.n_meta_tokens
        cache_sz = caches.kv.size if caches.kv is not None else 0

        # reshape stacked caches to groups for the scan
        def regroup(a):
            return a.reshape((self.n_groups, self.interleave) + a.shape[1:])

        kv_quant = caches.kv is not None and caches.kv.quantized
        xs = {"lp": params["blocks"], "win": window_tbl}
        if caches.kv is not None:
            xs["k"] = regroup(caches.kv.k)
            xs["v"] = regroup(caches.kv.v)
            if kv_quant:
                xs["ks"] = regroup(caches.kv.k_scale)
                xs["vs"] = regroup(caches.kv.v_scale)
        if caches.ssm is not None:
            xs["conv"] = regroup(caches.ssm.conv)
            xs["ssd"] = regroup(caches.ssm.ssd)

        slot_pos = caches.kv.slot_pos if caches.kv is not None else None

        def group_body(x, xs):
            lp, wins = xs["lp"], xs["win"]
            outs = {}
            if "k" in xs:
                outs["k"], outs["v"] = [], []
                if kv_quant:
                    outs["ks"], outs["vs"] = [], []
            if "conv" in xs:
                outs["conv"], outs["ssd"] = [], []
            for s in range(self.interleave):
                win = wins[s]
                win_eff = jnp.where(win < 0, jnp.int32(2 ** 30), win)
                if c.has_attention:
                    xn = rms_norm(x, lp["norm1"][s])
                    h, hkv, hd = c.n_heads, c.n_kv_heads, c.head_dim
                    q = jnp.einsum("bld,de->ble", xn, lp["wq"][s]
                                   ).reshape(b, 1, h, hd)
                    k1 = jnp.einsum("bld,de->ble", xn, lp["wk"][s]
                                    ).reshape(b, 1, hkv, hd)
                    v1 = jnp.einsum("bld,de->ble", xn, lp["wv"][s]
                                    ).reshape(b, 1, hkv, hd)
                    q = rope_for(c, q, positions)
                    k1 = rope_for(c, k1, positions)
                    kc, vc, sp, ksc, vsc = cache_write(
                        xs["k"][s], xs["v"][s], slot_pos, k1, v1, pos,
                        xs["ks"][s] if kv_quant else None,
                        xs["vs"][s] if kv_quant else None)
                    o = decode_attention(q, kc, vc, sp, pos, window=win_eff,
                                         n_sink=n_sink, k_scale=ksc,
                                         v_scale=vsc)
                    o = o.reshape(b, 1, h * hd)
                    attn_out = jnp.einsum("ble,ed->bld", o, lp["wo"][s])
                    if c.arch_type == "hybrid":
                        ssm_p = jax.tree_util.tree_map(lambda a: a[s], lp["ssm"])
                        ssm_out, nconv, nssd = ssm_lib.ssm_decode_step(
                            ssm_p, xn, xs["conv"][s], xs["ssd"][s], c)
                        fused = 0.5 * (_branch_norm(attn_out)
                                       + _branch_norm(ssm_out))
                        x = x + fused.astype(x.dtype)
                        outs["conv"].append(nconv)
                        outs["ssd"].append(nssd)
                    else:
                        x = x + attn_out
                    outs["k"].append(kc)
                    outs["v"].append(vc)
                    if kv_quant:
                        outs["ks"].append(ksc)
                        outs["vs"].append(vsc)
                else:
                    xn = rms_norm(x, lp["norm1"][s])
                    ssm_p = jax.tree_util.tree_map(lambda a: a[s], lp["ssm"])
                    y, nconv, nssd = ssm_lib.ssm_decode_step(
                        ssm_p, xn, xs["conv"][s], xs["ssd"][s], c)
                    x = x + y
                    outs["conv"].append(nconv)
                    outs["ssd"].append(nssd)
                is_moe_slot = c.is_moe and s == self.interleave - 1
                if is_moe_slot or self.n_mlp_slots > 0 and s < self.n_mlp_slots:
                    y, _ = self._ffn(lp, x, s, is_moe_slot)
                    x = x + y
            ys = {kk: jnp.stack(vv) for kk, vv in outs.items()}
            return x, ys

        x, ys = jax.lax.scan(group_body, x, xs)
        logits = self._head(params, x)

        def flatten_groups(a):
            return a.reshape((c.n_layers,) + a.shape[2:])

        new_kv = None
        if caches.kv is not None:
            size = cache_sz
            new_slot = jax.lax.dynamic_update_slice_in_dim(
                slot_pos, pos[None].astype(jnp.int32), pos % size, axis=0)
            new_kv = KVCache(
                k=flatten_groups(ys["k"]), v=flatten_groups(ys["v"]),
                slot_pos=new_slot, pos=pos + 1,
                k_scale=flatten_groups(ys["ks"]) if kv_quant
                else caches.kv.k_scale,
                v_scale=flatten_groups(ys["vs"]) if kv_quant
                else caches.kv.v_scale)
        new_ssm = None
        if caches.ssm is not None:
            new_ssm = SSMState(conv=flatten_groups(ys["conv"]),
                               ssd=flatten_groups(ys["ssd"]))
            if caches.kv is None:
                new_ssm = dataclasses.replace(new_ssm)
        return logits, DecodeCaches(kv=new_kv, ssm=new_ssm)

    # ------------------------------------------------------------------ #
    # loss
    # ------------------------------------------------------------------ #
    def loss_fn(self, params: dict, tokens: Array, *,
                prefix_emb: Array | None = None, remat: bool = True,
                aux_weight: float = 0.01) -> Array:
        """Next-token cross-entropy (+ MoE load-balance aux)."""
        c = self.config
        logits, aux = self.forward(params, tokens, prefix_emb=prefix_emb,
                                   remat=remat)
        logits = logits.astype(jnp.float32)
        if c.n_codebooks > 1:
            inp, tgt = logits[:, :-1], tokens[:, 1:]       # (B,L-1,C,V),(B,L-1,C)
            logp = jax.nn.log_softmax(inp, axis=-1)
            nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
            loss = jnp.mean(nll)
        else:
            inp, tgt = logits[:, :-1], tokens[:, 1:]
            logp = jax.nn.log_softmax(inp, axis=-1)
            nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
            loss = jnp.mean(nll)
        return loss + aux_weight * aux


def _branch_norm(x: Array) -> Array:
    """Parameter-free per-branch RMS normalization (hymba output fusion)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return x32 * jax.lax.rsqrt(var + 1e-6)


def _ssm_pos(caches: DecodeCaches) -> Array:
    # pure-SSM archs carry no explicit position; decode uses a zero position
    # (RoPE-free path) — position only matters for attention masks.
    return jnp.zeros((), dtype=jnp.int32)


def unembed_multi(logits: Array, logical_vocab: int) -> Array:
    pad = logits.shape[-1] - logical_vocab
    if pad > 0:
        logits = logits.at[..., logical_vocab:].set(-1e9)
    return logits
