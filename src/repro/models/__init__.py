"""Model zoo: the ten assigned architectures across six families."""
from repro.models.model import Model, DecodeCaches
from repro.models.attention import KVCache, blockwise_attention, init_kv_cache
from repro.models.ssm import SSMState, init_ssm_state

__all__ = ["Model", "DecodeCaches", "KVCache", "SSMState",
           "blockwise_attention", "init_kv_cache", "init_ssm_state"]
