"""Attention substrate: blockwise online-softmax attention (train/prefill)
and single-token decode attention against full or sliding-window KV caches.

One implementation serves every arch family: causal masking, GQA head
grouping, sliding windows, attention sinks (hymba meta tokens), and both
position conventions (RoPE applied by the caller before entry).

The blockwise path is the pure-JAX mirror of ``kernels/flash_attention``:
an outer *python* loop over query blocks (static per-block KV ranges — so
causal/windowed dry-runs never pay for masked-out blocks) with an inner
``lax.scan`` over exactly the KV blocks that block can see. The (Lq, Lk)
score matrix never materializes, which is what lets prefill_32k lower with
bounded memory on every mesh.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def _gqa_expand(h: int, hkv: int) -> int:
    assert h % hkv == 0
    return h // hkv


def blockwise_attention(
    q: Array,            # (B, Lq, H, D) — RoPE already applied
    k: Array,            # (B, Lk, HKV, D)
    v: Array,            # (B, Lk, HKV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    n_sink: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
    scale: float | None = None,
) -> Array:
    """Memory-bounded attention; query offset = Lk - Lq (ends aligned)."""
    b, lq, h, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    g = _gqa_expand(h, hkv)
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    offset = lk - lq

    bq = min(block_q, lq)
    bk = min(block_k, lk)
    nq = -(-lq // bq)
    nk_total = -(-lk // bk)
    # pad seq dims to block multiples (padding keys are masked by position)
    lq_p, lk_p = nq * bq, nk_total * bk
    if lq_p != lq:
        q = jnp.pad(q, ((0, 0), (0, lq_p - lq), (0, 0), (0, 0)))
    if lk_p != lk:
        k = jnp.pad(k, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, lk_p - lk), (0, 0), (0, 0)))

    # (B, HKV, G, Lq, D) query view grouped by kv head
    qg = q.reshape(b, lq_p, hkv, g, d).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)      # (B, HKV, Lk, D)
    vg = v.transpose(0, 2, 1, 3)

    # A *static* (python int) window allows pruning whole KV block ranges;
    # a traced window (per-layer table under a layer scan — hybrid archs)
    # falls back to full block range + masking.
    static_window = window if (window is None or isinstance(window, int)) else None
    sink_blocks = -(-n_sink // bk) if n_sink > 0 else 0
    out_blocks = []
    for qi in range(nq):
        q_lo = offset + qi * bq                  # absolute pos of first row
        q_hi = q_lo + bq - 1
        # static KV block range this q block can see
        if causal:
            end_blk = min(nk_total, -(-(q_hi + 1) // bk))
        else:
            end_blk = nk_total
        if static_window is not None:
            start_blk = max(0, (q_lo - static_window + 1) // bk)
        else:
            start_blk = 0
        # attention sinks: always include blocks covering [0, n_sink)
        ranges = []
        if sink_blocks > 0 and start_blk > 0:
            ranges.append((0, min(sink_blocks, start_blk)))
        ranges.append((start_blk, max(end_blk, start_blk + 1)))

        qb = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=3)  # (B,HKV,G,BQ,D)
        qpos = q_lo + jnp.arange(bq)

        m = jnp.full((b, hkv, g, bq), NEG_INF, dtype=jnp.float32)
        l = jnp.zeros((b, hkv, g, bq), dtype=jnp.float32)
        acc = jnp.zeros((b, hkv, g, bq, d), dtype=jnp.float32)

        def kv_step(carry, ki, qb=qb, qpos=qpos):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kg, ki * bk, bk, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vg, ki * bk, bk, axis=2)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            kpos = ki * bk + jnp.arange(bk)
            mask = kpos[None, :] < lk                      # clip key padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                wmask = kpos[None, :] > qpos[:, None] - window
                if n_sink > 0:
                    wmask = wmask | (kpos[None, :] < n_sink)
                mask = mask & wmask
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        for (lo_b, hi_b) in ranges:
            if hi_b <= lo_b:
                continue
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m, l, acc), jnp.arange(lo_b, hi_b))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]
        out_blocks.append(ob)

    out = jnp.concatenate(out_blocks, axis=3)             # (B,HKV,G,Lq_p,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, lq_p, h, d)
    return out[:, :lq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# Decode: one new token against a KV cache.
# --------------------------------------------------------------------------- #

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Per-model KV cache, layers stacked on the leading axis.

    Full cache:   k/v (L, B, S, HKV, D); ``slot_pos`` (S,) = absolute position
    stored in each slot (-1 = empty). For the sliding-window variant S is the
    window and slots are a ring buffer — slot = pos % S — so memory is O(W)
    for the 524k-token long-context shape.

    Quantized variant (§Perf pair 4): k/v int8 with per-(token, head) f32
    scales (L, B, S, HKV) — halves decode's dominant HBM term vs bf16.
    ``k_scale``/``v_scale`` are zero-size placeholders when unquantized
    (keeps the pytree structure static).
    """

    k: Array
    v: Array
    slot_pos: Array   # (S,) int32, -1 when empty (shared across layers/batch)
    pos: Array        # () int32: next absolute position to write
    k_scale: Array = dataclasses.field(
        default_factory=lambda: jnp.zeros((0,), jnp.float32))
    v_scale: Array = dataclasses.field(
        default_factory=lambda: jnp.zeros((0,), jnp.float32))

    @property
    def size(self) -> int:
        return self.k.shape[2]

    @property
    def quantized(self) -> bool:
        return self.k.dtype == jnp.int8


def init_kv_cache(n_layers: int, batch: int, size: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, quantized: bool = False) -> KVCache:
    shape = (n_layers, batch, size, n_kv, head_dim)
    if quantized:
        return KVCache(
            k=jnp.zeros(shape, dtype=jnp.int8),
            v=jnp.zeros(shape, dtype=jnp.int8),
            slot_pos=jnp.full((size,), -1, dtype=jnp.int32),
            pos=jnp.zeros((), dtype=jnp.int32),
            k_scale=jnp.zeros(shape[:-1], dtype=jnp.float32),
            v_scale=jnp.zeros(shape[:-1], dtype=jnp.float32),
        )
    return KVCache(
        k=jnp.zeros(shape, dtype=dtype),
        v=jnp.zeros(shape, dtype=dtype),
        slot_pos=jnp.full((size,), -1, dtype=jnp.int32),
        pos=jnp.zeros((), dtype=jnp.int32),
    )


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(…, head) symmetric int8 over the head_dim axis.
    x (..., D) -> (int8 (..., D), scale (...))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention(
    q: Array,           # (B, 1, H, D) — RoPE applied at current position
    k_cache: Array,     # (B, S, HKV, D) one layer's cache (new k written)
    v_cache: Array,
    slot_pos: Array,    # (S,) absolute positions, -1 empty
    pos: Array,         # () current position
    *,
    window: int | None = None,
    n_sink: int = 0,
    scale: float | None = None,
    k_scale: Array | None = None,   # (B, S, HKV) when the cache is int8
    v_scale: Array | None = None,
) -> Array:
    """Single-token attention over every live cache slot (order-free:
    the ring buffer never needs unrotating because masks use slot_pos)."""
    if k_cache.dtype == jnp.int8:
        k_cache = dequantize_kv(k_cache, k_scale, q.dtype)
        v_cache = dequantize_kv(v_cache, v_scale, q.dtype)
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    g = _gqa_expand(h, hkv)
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    visible = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        wmask = slot_pos > pos - window
        if n_sink > 0:
            wmask = wmask | (slot_pos < n_sink)
        visible = visible & wmask
    s = jnp.where(visible[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def cache_write(k_cache: Array, v_cache: Array, slot_pos: Array,
                k_new: Array, v_new: Array, pos: Array,
                k_scale: Array | None = None, v_scale: Array | None = None):
    """Write one token's k/v at ring slot ``pos % S`` (== pos for full cache
    sized >= max_len). k_new/v_new: (B, 1, HKV, D).

    Returns (k_cache, v_cache, slot_pos[, k_scale, v_scale]) — scales only
    for int8 caches."""
    size = k_cache.shape[1]
    slot = pos % size
    if k_cache.dtype == jnp.int8:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, slot, axis=1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, slot, axis=1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, slot, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            slot_pos, pos[None].astype(jnp.int32), slot, axis=0)
        return k_cache, v_cache, slot_pos, k_scale, v_scale
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, pos[None].astype(jnp.int32), slot, axis=0)
    return k_cache, v_cache, slot_pos, None, None
