"""Mixture-of-Experts block: GShard-style capacity dispatch, TPU-native.

Design (DESIGN.md §6): token dispatch is a static-shape scatter into
``(E, C, d)`` expert buffers (capacity factor 1.25, overflow tokens
dropped with their residual passthrough kept — standard Switch behaviour);
expert FFNs run as one batched einsum over E. Expert weights are
*tensor-parallel* — d_ff shards over the ``model`` mesh axis — so the
baseline path needs no all-to-all: each device holds every expert's d_ff
slice, computes its partial down-projection, and a single ``psum`` over
``model`` closes the contraction. Under ``shard_map`` the dispatch runs on
each device's local tokens (batch sharded over ``data``/``pod``).

grok-1: E=8, top-2, every layer.  llama4-maverick: E=128, top-1, every
second layer (interleave handled in the model assembly).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def router(x: Array, w_router: Array, topk: int
           ) -> tuple[Array, Array, Array]:
    """Softmax gating. x (T, d) -> (gates (T,k), experts (T,k) int32, aux ()).

    Aux is the Switch/GShard load-balance loss: E * Σ_e f_e · p_e where
    f_e = fraction of tokens whose top-1 choice is e and p_e = mean router
    probability for e.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, topk)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    e = probs.shape[-1]
    f = jnp.mean(jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * p)
    return gates.astype(x.dtype), experts.astype(jnp.int32), aux


def dispatch_indices(experts: Array, n_experts: int, capacity: int
                     ) -> tuple[Array, Array]:
    """Assign each (token, choice) a slot in its expert's capacity buffer.

    Returns (slots (T,k) int32 with -1 = dropped, counts (E,)).
    Ranks are assigned choice-major (all tokens' 1st choice first), the
    GShard convention that biases drops toward lower-gate choices.
    """
    t, k = experts.shape
    counts = jnp.zeros((n_experts,), dtype=jnp.int32)
    slots = []
    for j in range(k):
        oh = jax.nn.one_hot(experts[:, j], n_experts, dtype=jnp.int32)  # (T,E)
        ranks = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]
        slot = jnp.sum(ranks * oh, axis=-1)
        ok = slot < capacity
        slots.append(jnp.where(ok, slot, -1))
        counts = counts + jnp.sum(oh, axis=0)
    return jnp.stack(slots, axis=1).astype(jnp.int32), counts


def moe_ffn(
    x: Array,                # (T, d) local tokens
    w_router: Array,         # (d, E)
    w_gate: Array,           # (E, d, F_local)
    w_up: Array,             # (E, d, F_local)
    w_down: Array,           # (E, F_local, d)
    *,
    topk: int,
    capacity_factor: float = 1.25,
    model_axes: Sequence[str] | None = None,   # inside shard_map: psum axes
) -> tuple[Array, Array]:
    """Returns (y (T, d), aux_loss ()). See module docstring."""
    t, d = x.shape
    e = w_gate.shape[0]
    capacity = int(math.ceil(t * topk / e * capacity_factor))
    capacity = max(capacity, 1)

    gates, experts, aux = router(x, w_router, topk)
    slots, _ = dispatch_indices(experts, e, capacity)

    # scatter tokens into (E, C, d) buffers
    buf = jnp.zeros((e, capacity, d), dtype=x.dtype)
    for j in range(topk):
        ok = slots[:, j] >= 0
        idx_e = jnp.where(ok, experts[:, j], 0)
        idx_c = jnp.where(ok, slots[:, j], 0)
        contrib = jnp.where(ok[:, None], x, 0)
        buf = buf.at[idx_e, idx_c].add(contrib)

    # batched expert FFN (SwiGLU) — MXU einsums over the expert axis
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
    if model_axes:
        for ax in model_axes:   # close the sharded d_ff contraction
            y_buf = jax.lax.psum(y_buf, ax)

    # gather + combine with gate weights
    y = jnp.zeros((t, d), dtype=jnp.float32)
    for j in range(topk):
        ok = slots[:, j] >= 0
        idx_e = jnp.where(ok, experts[:, j], 0)
        idx_c = jnp.where(ok, slots[:, j], 0)
        yj = y_buf[idx_e, idx_c].astype(jnp.float32)
        y = y + jnp.where(ok[:, None], gates[:, j:j + 1].astype(jnp.float32) * yj, 0)
    return y.astype(x.dtype), aux


def moe_ffn_sharded(mesh, data_axes: tuple[str, ...], model_axes: tuple[str, ...]):
    """Build the shard_map-wrapped MoE ffn for a mesh.

    Token batch shards over ``data_axes``; expert d_ff shards over
    ``model_axes``. Router weights replicate.
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map_nocheck

    def fn(x, w_router, w_gate, w_up, w_down, topk, capacity_factor):
        y, aux = moe_ffn(x, w_router, w_gate, w_up, w_down, topk=topk,
                         capacity_factor=capacity_factor,
                         model_axes=model_axes)
        # aux is per-shard; average over the data axes for a global scalar
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        for ax in model_axes:   # replicated across model: any works; mean is safe
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    def wrapped(x, w_router, w_gate, w_up, w_down, *, topk, capacity_factor):
        f = lambda a, b, c, dd, ee: fn(a, b, c, dd, ee, topk, capacity_factor)
        return shard_map_nocheck(
            f, mesh=mesh,
            in_specs=(P(data_axes, None), P(), P(None, None, model_axes),
                      P(None, None, model_axes), P(None, model_axes, None)),
            out_specs=(P(data_axes, None), P()),
        )(x, w_router, w_gate, w_up, w_down)

    return wrapped
