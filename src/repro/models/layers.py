"""Shared model layers: RMSNorm, RoPE / M-RoPE, SwiGLU MLP, embeddings.

Parameters are plain dict pytrees; layer weights for the whole depth are
*stacked* on a leading layer axis and the forward pass scans over them
(MaxText-style), keeping the HLO size O(1) in depth — essential for the
126-layer llama3-405b dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# RoPE (standard) and M-RoPE (qwen2-vl §2.1: multimodal rotary with
# (temporal, height, width) position triples split across head_dim sections).
# --------------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, D); positions (..., S) int32 -> rotated x."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """M-RoPE: positions3 (..., S, 3) = (t, h, w) per token.

    head_dim/2 frequency slots are partitioned into three contiguous
    sections; each section rotates by its own coordinate. Text tokens carry
    t == h == w, which makes M-RoPE degenerate to standard RoPE for them —
    matching Qwen2-VL's construction.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    # section id per frequency slot: 0,0,...,1,1,...,2,2
    sec_id = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    # pick the coordinate for each slot: (..., S, D/2)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions3.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positionize(config: ModelConfig, positions: Array) -> Array:
    """Normalize positions to the arch's expected rank.

    Standard RoPE archs take (..., S); qwen2-vl takes (..., S, 3). Text-only
    callers pass (..., S) and we broadcast t=h=w for M-RoPE.
    """
    if config.mrope and positions.shape[-1] != 3:
        positions = jnp.stack([positions] * 3, axis=-1)
    return positions


def rope_for(config: ModelConfig, x: Array, positions: Array) -> Array:
    if config.mrope:
        return apply_mrope(x, positions, config.rope_theta,
                           config.mrope_sections)
    return apply_rope(x, positions, config.rope_theta)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #

def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# --------------------------------------------------------------------------- #
# Embedding / unembedding (vocab padded for `model`-axis sharding)
# --------------------------------------------------------------------------- #

def embed_tokens(table: Array, tokens: Array) -> Array:
    return table[tokens]


def unembed(x: Array, head: Array, logical_vocab: int) -> Array:
    """Project to padded vocab, mask the padding rows to -inf."""
    logits = jnp.einsum("...d,dv->...v", x, head)
    pad = logits.shape[-1] - logical_vocab
    if pad > 0:
        neg = jnp.full((pad,), -1e9, dtype=logits.dtype)
        logits = logits.at[..., logical_vocab:].set(neg)
    return logits
