"""SemanticCache — the paper's query-handling workflow (§2.5, §2.8) as a
composable, jit-able JAX module.

Workflow per batch of queries:
  1. embed (done by the caller / serving engine),
  2. ``lookup`` — ANN search over the slab, threshold policy decides hit/miss,
  3. hit  -> cached response returned, LRU/LFU counters touched,
  4. miss -> caller generates with the LLM backend, then ``insert`` stores
     (embedding, response) and the index absorbs the new entries.

Everything is batched (beyond-paper: the paper scores one query at a time;
batching turns scoring into a GEMM — see DESIGN.md §11.5) and functional:
``(state, stats)`` thread through, so the whole serve step can live inside
one ``jax.jit`` with donated buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import store
from repro.core.index import ExactIndex, IVFIndex, IVFState
from repro.core.policy import FixedThreshold
from repro.core.types import (CacheConfig, CacheState, CacheStats,
                              LookupResult, init_cache_state)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SemanticCache:
    """Stateless orchestrator; all state lives in (CacheState, CacheStats)."""

    config: CacheConfig
    index: Any = None          # ExactIndex | IVFIndex (None -> Exact)
    policy: Any = None         # threshold policy (None -> Fixed(config.threshold))

    def __post_init__(self):
        if self.index is None:
            object.__setattr__(self, "index", ExactIndex(topk=self.config.topk))
        if self.policy is None:
            object.__setattr__(
                self, "policy", FixedThreshold(threshold=self.config.threshold))

    # -- state ------------------------------------------------------------
    def init(self) -> tuple[CacheState, CacheStats]:
        return init_cache_state(self.config), CacheStats.zeros()

    def init_policy(self) -> Array:
        return self.policy.init_state()

    # -- lookup (paper §2.5 step 1) ----------------------------------------
    def lookup(
        self,
        state: CacheState,
        stats: CacheStats,
        queries: Array,                 # (B, d) embeddings (normalized or not)
        now: Array | float,
        *,
        policy_state: Array | None = None,
        ivf_state: IVFState | None = None,
        update_counters: bool = True,
    ) -> tuple[LookupResult, CacheState, CacheStats]:
        b = queries.shape[0]
        now = jnp.asarray(now, dtype=jnp.float32)
        alive = store.alive_mask(state, now)

        if isinstance(self.index, IVFIndex):
            if ivf_state is None:
                raise ValueError("IVFIndex requires ivf_state (call index.fit)")
            top_s, top_i = self.index.search(ivf_state, queries, state.keys, alive)
        else:
            top_s, top_i = self.index.search(queries, state.keys, alive)

        best_score = top_s[:, 0]
        best_idx = jnp.maximum(top_i[:, 0], 0)  # -1 guard when cache empty
        any_alive = jnp.any(alive)
        best_score = jnp.where(any_alive & (top_i[:, 0] >= 0), best_score, -jnp.inf)

        pstate = policy_state if policy_state is not None else self.init_policy()
        hit, pstate = self.policy.decide(best_score, pstate)
        hit = hit & (best_score > -jnp.inf)

        result = LookupResult(
            index=best_idx.astype(jnp.int32),
            score=best_score,
            hit=hit,
            values=state.values[best_idx],
            value_lens=state.value_lens[best_idx],
            source_id=state.source_id[best_idx],
            topk_index=top_i,
            topk_score=top_s,
        )
        if update_counters:
            state = store.touch(state, best_idx, now, hit)
            nhit = jnp.sum(hit).astype(jnp.int32)
            stats = CacheStats(
                lookups=stats.lookups + b,
                hits=stats.hits + nhit,
                misses=stats.misses + (b - nhit),
                expired_evictions=stats.expired_evictions,
                inserts=stats.inserts,
            )
        return result, state, stats

    # -- insert (paper §2.5 step 3) -----------------------------------------
    def insert(
        self,
        state: CacheState,
        stats: CacheStats,
        queries: Array,
        values: Array,
        value_lens: Array,
        now: Array | float,
        *,
        source_id: Array | None = None,
        mask: Array | None = None,     # typically = ~hit from the lookup
    ) -> tuple[CacheState, CacheStats]:
        state = store.insert(
            self.config, state, queries, values, value_lens, now,
            source_id=source_id, mask=mask)
        n = jnp.sum(mask).astype(jnp.int32) if mask is not None else queries.shape[0]
        stats = dataclasses.replace(stats, inserts=stats.inserts + n)
        return state, stats

    # -- maintenance (paper §2.7 TTL; §2.4 rebalancing) ----------------------
    def expire(self, state: CacheState, stats: CacheStats, now: Array | float
               ) -> tuple[CacheState, CacheStats]:
        state, n = store.expire(state, now)
        stats = dataclasses.replace(
            stats, expired_evictions=stats.expired_evictions + n)
        return state, stats

    def rebuild_index(self, state: CacheState, now: Array | float, rng: Array
                      ) -> IVFState | None:
        """Periodic IVF rebuild — the analogue of HNSW rebalancing (§2.4)."""
        if isinstance(self.index, IVFIndex):
            return self.index.fit(state.keys, store.alive_mask(state, now), rng)
        return None

    # -- fused serve-side step (beyond-paper: single jit) --------------------
    def lookup_insert(
        self,
        state: CacheState,
        stats: CacheStats,
        queries: Array,
        miss_values: Array,
        miss_value_lens: Array,
        now: Array | float,
        *,
        source_id: Array | None = None,
        policy_state: Array | None = None,
    ) -> tuple[LookupResult, CacheState, CacheStats]:
        """Lookup, then insert exactly the missed queries' fresh responses.

        ``miss_values`` are the responses the LLM backend produced for every
        query (rows for hits are ignored via the insert mask) — this is the
        shape-static formulation that lets the whole hit/miss branch live in
        one compiled step (no host round-trip for the branch).
        """
        result, state, stats = self.lookup(
            state, stats, queries, now, policy_state=policy_state)
        state, stats = self.insert(
            state, stats, queries, miss_values, miss_value_lens, now,
            source_id=source_id, mask=~result.hit)
        return result, state, stats
