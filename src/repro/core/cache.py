"""SemanticCache — the paper's query-handling workflow (§2.5, §2.8) as a
composable, jit-able JAX module.

Workflow per batch of queries:
  1. embed (done by the caller / serving engine),
  2. ``lookup`` — ANN search over the slab, threshold policy decides hit/miss,
  3. hit  -> cached response returned, LRU/LFU counters touched,
  4. miss -> caller generates with the LLM backend, then ``insert`` stores
     (embedding, response) and the index absorbs the new entries.

Everything is batched (beyond-paper: the paper scores one query at a time;
batching turns scoring into a GEMM — see DESIGN.md §11.5) and functional:
*all* mutable state — slab, counters, policy state, index state — lives in
one ``CacheRuntime`` pytree (DESIGN.md §2), so every method is a pure
``runtime -> runtime`` function and the whole serve step can live inside
one ``jax.jit`` with donated buffers:

    cache = SemanticCache(config, index=IVFIndex(), policy=AdaptiveThreshold())
    runtime = cache.init()
    result, runtime = cache.lookup(runtime, queries, now)
    runtime = cache.insert(runtime, queries, values, lens, now, mask=~result.hit)
    # ... or both at once, shape-static (DESIGN.md §7):
    result, runtime = cache.step(runtime, queries, miss_values, miss_lens, now)

The index and policy are protocol plugins (``repro.core.runtime.Index`` /
``Policy``): Exact and IVF — and any future structure — are interchangeable
with no ``isinstance`` branches and no out-of-band ``fit`` calls.

Multi-tenancy (DESIGN.md §13): an optional static ``partition``
(``repro.tenancy.PartitionMap``) splits the slab into disjoint per-tenant
regions. A per-row ``tenant_id`` vector — the only traced tenancy input —
masks every lookup to its row's own region and routes every insert into
its row's own per-tenant ring, so one compiled ``step()`` serves every
tenant mix with zero retraces and structural cross-tenant isolation.

Multi-turn context (DESIGN.md §16): an optional ``fusion`` plugin
(``repro.context.ContextFusion``) pools each row's session turn window —
a traced ``(B, W, d)`` tensor + ``(B,)`` length vector — into the lookup
key *inside* the compiled step, before the search and before the insert,
so the slab keys ARE dialogue-state embeddings. Rows with an empty window
pass through bit-identically (the stateless path), which is what lets one
compiled ``step()`` serve mixed session/sessionless batches with zero
retraces. Fusion weights live in the runtime's ``fusion`` leaf group
(``None`` = single-turn, old treedef).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import store
from repro.core.index import ExactIndex
from repro.core.policy import FixedThreshold
from repro.core.runtime import CacheRuntime
from repro.core.types import (CacheConfig, CacheStats, LookupResult,
                              init_cache_state)

Array = jax.Array


class _LocalComm:
    """Identity communication seam — the single-shard specialization of the
    cross-shard combine points in ``lookup``/``commit``/``insert``.

    Every place the step's dataflow would need to cross shard boundaries on
    a mesh is routed through one of these methods (DESIGN.md §19.2): top-k
    candidate merge, best-row value fetch, LRU touch ownership, per-tenant
    lookup-counter attribution, and round-robin insert routing. On a single
    device they are all identities / direct gathers, so the local path
    compiles to *exactly* the pre-seam program; ``repro.core.distributed``
    substitutes a mesh-aware implementation (collectives inside
    ``shard_map``) and reuses these same method bodies unchanged — ONE step
    abstraction for any mesh.
    """

    #: shards participating in the step (trace-time Python constant)
    num_shards: int = 1

    def merge_topk(self, top_s: Array, top_i: Array) -> tuple[Array, Array]:
        """Merge per-shard top-k candidate lists into the global top-k.
        Local: the per-shard list *is* the global list."""
        return top_s, top_i

    def fetch_best(self, state, top0: Array) -> tuple[Array, Array, Array]:
        """(values, value_lens, source_id) rows for each row's best slot id
        (-1 = no visible slot; the row's payload is unused on that path).
        Local: plain gathers. Mesh: owner-masked gather + psum."""
        idx = jnp.maximum(top0, 0)
        return state.values[idx], state.value_lens[idx], state.source_id[idx]

    def touch(self, state, slot: Array, now: Array, hit: Array):
        """LRU/LFU touch of each row's best slot where hit. Mesh: only the
        owning shard touches (slot ids are global there)."""
        return store.touch(state, slot, now, hit)

    def primary(self, counts: Array) -> Array:
        """Zero replicated per-batch counts on all but one shard, so a
        cross-shard sum-reduce of sharded counters is exact. Local: id."""
        return counts

    def insert_take(self, mask: Array, n_inserts: Array) -> Array:
        """Which masked-in rows THIS shard inserts. Mesh: round-robin by the
        cumulative rank of masked-in rows (not the raw row index — a batch
        with few actual inserts must not skew early shards), offset by the
        global insert clock so balance holds across batches. Local: mask."""
        return mask

    def prepare_insert(self, state):
        """Pre-insert state fixup. Mesh: derive this shard's local ring
        pointer from the replicated global insert clock."""
        return state

    def finalize_insert(self, state, prev_n_inserts: Array, mask: Array):
        """Post-insert state fixup. Mesh: re-replicate the clock leaves —
        ``n_inserts`` advances by the *global* masked count (store.insert
        added only this shard's take) and ``ptr`` parks at 0 (it is
        re-derived from ``n_inserts`` on the next insert)."""
        return state


#: module-level default — threading it as a keyword keeps every public
#: signature backward compatible while letting the mesh layer inject itself
LOCAL_COMM = _LocalComm()


@dataclasses.dataclass(frozen=True)
class SemanticCache:
    """Stateless orchestrator; all state lives in one CacheRuntime pytree."""

    config: CacheConfig
    index: Any = None          # Index protocol plugin (None -> ExactIndex)
    policy: Any = None         # Policy protocol plugin (None -> FixedThreshold)
    partition: Any = None      # PartitionMap for multi-tenant regions (§13)
    fusion: Any = None         # ContextFusion plugin for session windows (§16)

    def __post_init__(self):
        if self.index is None:
            object.__setattr__(self, "index", ExactIndex(topk=self.config.topk))
        if self.policy is None:
            object.__setattr__(
                self, "policy", FixedThreshold(threshold=self.config.threshold))
        if self.partition is not None:
            if self.partition.capacity != self.config.capacity:
                raise ValueError(
                    f"partition covers {self.partition.capacity} slots, "
                    f"slab capacity is {self.config.capacity}")
            if self.config.eviction != "ring":
                # per-tenant LRU/LFU needs a per-row in-region arg-min scan;
                # until that lands, failing loudly beats silently evicting
                # across regions
                raise ValueError(
                    "tenant partitioning currently supports ring eviction "
                    f"only (got {self.config.eviction!r})")

    # -- state ------------------------------------------------------------
    def init(self) -> CacheRuntime:
        """Fresh runtime: empty slab, zero counters, init policy/index state
        (+ per-tenant ring pointers/counters when partitioned, + fusion
        weights when context-fused)."""
        tenancy = None
        if self.partition is not None:
            from repro.tenancy.partition import TenancyState
            tenancy = TenancyState.zeros(len(self.partition))
        return CacheRuntime(
            state=init_cache_state(self.config),
            stats=CacheStats.zeros(),
            policy_state=self.policy.init_state(),
            index_state=self.index.init(self.config),
            tenancy=tenancy,
            fusion=None if self.fusion is None else self.fusion.init_state(),
        )

    # -- context fusion (no-op when fusion is None) ------------------------
    def _maybe_fuse(self, runtime: CacheRuntime, queries: Array,
                    window: Array | None, window_len: Array | None) -> Array:
        """Pool each row's turn window into its lookup key (§16.2). The
        fusion op is inlined here — inside whatever jit the caller wrapped
        around lookup/step — so context pooling batches with the search
        instead of costing a second dispatch. ``window=None`` (or a
        fusion-less cache) is the stateless fast path: queries unchanged."""
        if self.fusion is None or window is None:
            return queries
        if window_len is None:
            raise ValueError("window without window_len")
        return self.fusion.fuse(runtime.fusion, queries, window,
                                jnp.asarray(window_len, dtype=jnp.int32))

    # -- tenancy helpers (no-ops when partition is None) -------------------
    def _require_tenants(self, tenant_id: Array | None) -> Array | None:
        """Partitioned caches must be told each row's tenant; an unpartitioned
        cache ignores the argument entirely (single-tenant fast path)."""
        if self.partition is None:
            return None
        if tenant_id is None:
            raise ValueError("cache is tenant-partitioned: every call needs "
                             "a per-row tenant_id vector")
        return jnp.asarray(tenant_id, dtype=jnp.int32)

    def _tenant_interval(self, tenant_id: Array) -> tuple[Array, Array]:
        """(B,) tenant ids -> per-row ``(starts, sizes)`` interval operands:
        a row sees only its own region's slots (structural isolation — a
        cosine-1.0 duplicate in another tenant's region is invisible, not
        just sub-threshold). Regions are contiguous by construction
        (PartitionMap), so per-row visibility is O(B) interval operands —
        the index keeps the fused Pallas path on TPU (§14) instead of
        materializing a (B, N) mask."""
        return (self.partition.starts_array()[tenant_id],
                self.partition.sizes_array()[tenant_id])

    def _apply_threshold_overrides(self, hit: Array, score: Array,
                                   tenant_id: Array) -> Array:
        """Per-tenant similarity-threshold overrides (registry option): rows
        of a tenant with an override re-decide against it; rows without keep
        the cache-wide policy's decision. Negative entry = no override."""
        thr = self.partition.thresholds_array()[tenant_id]      # (B,)
        return jnp.where(thr >= 0.0, score >= thr, hit)

    # -- near-hit band (no-op unless the policy defines one — §17) ----------
    def _near_mask(self, hit: Array, score: Array,
                   tenant_id: Array | None, pstate: Array) -> Array:
        """[τ_lo, τ_hi) band membership for each row, or all-False on a
        band-less policy. The ``hasattr`` probe is a trace-time Python
        constant, so a band-less cache compiles the exact same program as
        before this subsystem existed. ``& ~hit`` makes the upper band edge
        *definitionally* the effective hit edge — including per-tenant τ_hi
        overrides — and a per-tenant ``band_lo`` override (sentinel < 0 =
        none) replaces the lower edge the same way τ_hi overrides replace
        the hit threshold."""
        if not hasattr(self.policy, "near"):
            return jnp.zeros_like(hit)
        near = self.policy.near(score, pstate)
        if tenant_id is not None:
            lo = self.partition.band_lo_array()[tenant_id]      # (B,)
            near = jnp.where(lo >= 0.0, score >= lo, near)
        return near & ~hit & (score > -jnp.inf)

    # -- lookup (paper §2.5 step 1) ----------------------------------------
    def lookup(
        self,
        runtime: CacheRuntime,
        queries: Array,                 # (B, d) embeddings (normalized or not)
        now: Array | float,
        *,
        update_counters: bool = True,
        tenant_id: Array | None = None,  # (B,) required when partitioned
        window: Array | None = None,     # (B, W, d) session turn windows (§16)
        window_len: Array | None = None,  # (B,) turns per row; 0 = stateless
        comm: _LocalComm = LOCAL_COMM,   # cross-shard seam (§19.2)
    ) -> tuple[LookupResult, CacheRuntime]:
        """ANN search + threshold decision. ``update_counters=False`` gives a
        pure peek (no LRU touch, no stats, no policy-state commit) — the
        engine uses it to learn the miss set before the fused ``step``.

        ``comm`` is the cross-shard combine seam (§19.2): on a mesh, the
        per-shard index search results are merged into a replicated global
        top-k (ids become global slot ids) and the best row's payload is
        fetched from its owning shard; on a single device every seam op is
        an identity, compiling to the exact pre-seam program.

        On a context-fused cache, ``window``/``window_len`` carry each
        row's session turns and the search key becomes the fused
        dialogue-state embedding (§16.2); rows with ``window_len == 0``
        search on the raw query, bit-identical to a fusion-less cache.

        On a partitioned cache each row searches only its own tenant's
        region, passed to the index as per-row ``(start, size)`` interval
        operands (§13.2, §14) so the TPU path stays on the fused
        interval-masked kernel — no (B, N) mask is ever materialized.
        The same transparency holds for IVF: ``IVFIndex.search`` applies
        the interval to its gathered candidate ids and runs the candidate
        stage on the fused gather kernel (§15), so neither a (B, N) mask
        nor the (B, M, d) gathered-candidate tensor ever touches HBM —
        Exact and IVF caches serve the fused ``step()`` alike."""
        tenant_id = self._require_tenants(tenant_id)
        queries = self._maybe_fuse(runtime, queries, window, window_len)
        state, stats = runtime.state, runtime.stats
        b = queries.shape[0]
        now = jnp.asarray(now, dtype=jnp.float32)
        alive = store.alive_mask(state, now)
        interval = None
        if tenant_id is not None:
            interval = self._tenant_interval(tenant_id)         # O(B) operands

        top_s, top_i = self.index.search(
            runtime.index_state, queries, state.keys, alive, interval=interval)
        # cross-shard merge: per-shard candidates -> replicated global top-k
        # with global slot ids (single-shard: identity)
        top_s, top_i = comm.merge_topk(top_s, top_i)

        best_idx = jnp.maximum(top_i[:, 0], 0)  # -1 guard when cache empty
        # every search path returns index -1 with score -inf for rows with
        # no visible live slot (empty cache, empty tenant region, padding)
        best_score = jnp.where(top_i[:, 0] >= 0, top_s[:, 0], -jnp.inf)

        hit, pstate = self.policy.decide(best_score, runtime.policy_state)
        if tenant_id is not None:
            hit = self._apply_threshold_overrides(hit, best_score, tenant_id)
        hit = hit & (best_score > -jnp.inf)
        near = self._near_mask(hit, best_score, tenant_id,
                               runtime.policy_state)

        values, value_lens, src = comm.fetch_best(state, top_i[:, 0])
        result = LookupResult(
            index=best_idx.astype(jnp.int32),
            score=best_score,
            hit=hit,
            values=values,
            value_lens=value_lens,
            source_id=src,
            topk_index=top_i,
            topk_score=top_s,
            near=near,
        )
        if not update_counters:
            return result, runtime
        state = comm.touch(state, best_idx, now, hit)
        stats = stats.record_lookups(b, jnp.sum(hit).astype(jnp.int32))
        tenancy = self._account_lookups(runtime.tenancy, tenant_id,
                                        hit=hit, valid=None, comm=comm)
        return result, runtime.replace(state=state, stats=stats,
                                       policy_state=pstate, tenancy=tenancy)

    def gather_topk(self, runtime: CacheRuntime, result: LookupResult
                    ) -> dict[str, Array]:
        """Materialize the top-k neighbour payload for a lookup result —
        the device half of the near-hit path (§17.3): cached responses,
        lengths, provenance and scores for every visible neighbour, ready
        to hand to a host-side ``Synthesizer``. Invalid neighbour slots
        (index -1: empty cache / region smaller than k) come back with
        length 0, source -1 and score -inf, so the host can trust the
        payload without re-checking the slab. Pure gather — jit it with
        the peek; it never touches counters."""
        idx = jnp.maximum(result.topk_index, 0)
        ok = result.topk_index >= 0
        state = runtime.state
        return {
            "values": jnp.where(ok[..., None], state.values[idx], 0),
            "value_lens": jnp.where(ok, state.value_lens[idx], 0),
            "source_id": jnp.where(ok, state.source_id[idx], -1),
            "score": jnp.where(ok, result.topk_score, -jnp.inf),
        }

    def _account_lookups(self, tenancy, tenant_id: Array | None, *,
                         hit: Array, valid: Array | None,
                         comm: _LocalComm = LOCAL_COMM):
        """Scatter-add one batch of lookups/hits into the per-tenant
        counters. Padding rows (``valid=False``) contribute nothing.

        Lookup/hit decisions are *replicated* per-batch facts on a mesh, so
        ``comm.primary`` attributes them on one shard only — a cross-shard
        sum-reduce of the sharded counters then counts each batch once
        (insert/eviction counters are genuinely per-shard and skip this)."""
        if tenancy is None or tenant_id is None:
            return tenancy
        ones = jnp.ones_like(tenant_id)
        if valid is not None:
            ones = jnp.where(valid, ones, 0)
        ones = comm.primary(ones)
        hits = jnp.where(hit, ones, 0)
        return dataclasses.replace(
            tenancy,
            lookups=tenancy.lookups.at[tenant_id].add(ones),
            hits=tenancy.hits.at[tenant_id].add(hits))

    # -- insert (paper §2.5 step 3) -----------------------------------------
    def insert(
        self,
        runtime: CacheRuntime,
        queries: Array,
        values: Array,
        value_lens: Array,
        now: Array | float,
        *,
        source_id: Array | None = None,
        mask: Array | None = None,     # typically = ~hit from the lookup
        tenant_id: Array | None = None,  # (B,) required when partitioned
        comm: _LocalComm = LOCAL_COMM,   # cross-shard seam (§19.2)
    ) -> CacheRuntime:
        tenant_id = self._require_tenants(tenant_id)
        if mask is None:
            mask = jnp.ones((queries.shape[0],), dtype=bool)
        now_f = jnp.asarray(now, dtype=jnp.float32)
        # which masked-in rows THIS shard writes (round-robin on a mesh by
        # masked rank + global insert clock; identity on a single device)
        take = comm.insert_take(mask, runtime.state.n_inserts)
        state0 = comm.prepare_insert(runtime.state)
        tenancy = runtime.tenancy
        slots = None
        if tenant_id is not None:
            # per-tenant ring inside each tenant's own region: a tenant can
            # only ever overwrite itself (structural capacity isolation)
            slots, new_ptr = store.select_slots_tenant(
                self.partition, tenancy.ptr, tenant_id, take)
            alive_before = store.alive_mask(state0, now_f)
            evicted = jnp.where(take & alive_before[slots],
                                jnp.ones_like(tenant_id), 0)
            inserted = jnp.where(take, jnp.ones_like(tenant_id), 0)
            tenancy = dataclasses.replace(
                tenancy,
                ptr=new_ptr,
                inserts=tenancy.inserts.at[tenant_id].add(inserted),
                evictions=tenancy.evictions.at[tenant_id].add(evicted))
        state, slots = store.insert(
            self.config, state0, queries, values, value_lens, now,
            source_id=source_id, mask=take, slots=slots)
        # re-replicate the clock leaves on a mesh (ptr parks, n_inserts
        # advances by the GLOBAL masked count); identity on a single device
        state = comm.finalize_insert(state, runtime.state.n_inserts, mask)
        # the index absorbs the new rows so they are findable before the
        # next periodic refit (DESIGN.md §8.2)
        istate = self.index.absorb(runtime.index_state, slots, queries, take)
        # stats are replicated on a mesh: count the global mask, not take
        n = jnp.sum(mask).astype(jnp.int32)
        stats = dataclasses.replace(
            runtime.stats, inserts=runtime.stats.inserts + n)
        return runtime.replace(state=state, stats=stats, index_state=istate,
                               tenancy=tenancy)

    # -- maintenance (paper §2.7 TTL; §2.4 rebalancing) ----------------------
    def expire(self, runtime: CacheRuntime, now: Array | float) -> CacheRuntime:
        state, n = store.expire(runtime.state, now)
        stats = dataclasses.replace(
            runtime.stats,
            expired_evictions=runtime.stats.expired_evictions + n)
        return runtime.replace(state=state, stats=stats)

    def refit(self, runtime: CacheRuntime, now: Array | float, rng: Array
              ) -> CacheRuntime:
        """Periodic index rebuild — the analogue of HNSW rebalancing (§2.4).
        Uniform across index types: a no-op for stateless indexes."""
        alive = store.alive_mask(runtime.state, jnp.asarray(now, jnp.float32))
        istate = self.index.refit(
            runtime.index_state, runtime.state.keys, alive, rng)
        return runtime.replace(index_state=istate)

    def update_policy(self, runtime: CacheRuntime, *, was_positive: Array,
                      was_hit: Array) -> CacheRuntime:
        """Judged-outcome feedback into the policy (paper §2.10 loop)."""
        pstate = self.policy.update(
            runtime.policy_state, was_positive=was_positive, was_hit=was_hit)
        return runtime.replace(policy_state=pstate)

    def update_band(self, runtime: CacheRuntime, *, was_positive: Array,
                    was_near: Array) -> CacheRuntime:
        """Judged synthesized-answer outcomes into the band edge (§17.2) —
        the near-hit analogue of ``update_policy``. A no-op (structurally,
        at trace time) on a band-less policy."""
        if not hasattr(self.policy, "update_band"):
            return runtime
        pstate = self.policy.update_band(
            runtime.policy_state, was_positive=was_positive,
            was_near=was_near)
        return runtime.replace(policy_state=pstate)

    # -- fused serve-side step (beyond-paper: single jit — DESIGN.md §7) -----
    def commit(self, runtime: CacheRuntime, peeked: LookupResult,
               now: Array | float, *, valid: Array | None = None,
               tenant_id: Array | None = None,
               comm: _LocalComm = LOCAL_COMM
               ) -> tuple[LookupResult, CacheRuntime]:
        """Commit a previously peeked lookup (counters, LRU touch, policy
        state) *without* re-searching the slab. The hit mask is re-derived
        from the peeked scores against the current policy state, so
        ``peek -> commit`` is bit-identical to a counted ``lookup``.

        ``valid`` marks real rows in a padded batch (DESIGN.md §12.2):
        padding rows are excluded from the hit mask, the LRU touch and
        every counter — including the per-tenant accounting — so a padded
        commit is counter-identical to an unpadded commit over just the
        valid rows."""
        tenant_id = self._require_tenants(tenant_id)
        now = jnp.asarray(now, dtype=jnp.float32)
        hit, pstate = self.policy.decide(peeked.score, runtime.policy_state)
        if tenant_id is not None:
            hit = self._apply_threshold_overrides(hit, peeked.score,
                                                  tenant_id)
        hit = hit & (peeked.score > -jnp.inf)
        near = self._near_mask(hit, peeked.score, tenant_id,
                               runtime.policy_state)
        if valid is None:
            n_lookups = peeked.score.shape[0]
        else:
            hit = hit & valid
            near = near & valid
            n_lookups = jnp.sum(valid).astype(jnp.int32)
        result = dataclasses.replace(peeked, hit=hit, near=near)
        state = comm.touch(runtime.state, peeked.index, now, hit)
        stats = runtime.stats.record_lookups(
            n_lookups, jnp.sum(hit).astype(jnp.int32))
        tenancy = self._account_lookups(runtime.tenancy, tenant_id,
                                        hit=hit, valid=valid, comm=comm)
        return result, runtime.replace(state=state, stats=stats,
                                       policy_state=pstate, tenancy=tenancy)

    def step(
        self,
        runtime: CacheRuntime,
        queries: Array,
        miss_values: Array,
        miss_value_lens: Array,
        now: Array | float,
        *,
        source_id: Array | None = None,
        peeked: LookupResult | None = None,
        valid: Array | None = None,
        tenant_id: Array | None = None,
        window: Array | None = None,
        window_len: Array | None = None,
        comm: _LocalComm = LOCAL_COMM,
    ) -> tuple[LookupResult, CacheRuntime]:
        """Lookup, then insert exactly the missed queries' fresh responses.

        ``miss_values`` carries a response row for every query (rows for hits
        are ignored via the insert mask) — the shape-static formulation that
        lets the whole hit/miss branch live in one compiled step: no host
        round-trip for the branch, no per-miss-count retraces, donated slab.

        ``peeked`` (a result from ``lookup(update_counters=False)``) skips
        the internal re-search: the engine peeks once to learn the miss set,
        then commits + inserts here, so the slab is searched exactly once
        per batch (DESIGN.md §7).

        ``valid`` marks the real rows of a padded batch (DESIGN.md §12.2):
        padding rows neither count as lookups/misses nor get inserted, so
        every batch size shares one compiled shape without polluting state.

        ``tenant_id`` (required on a partitioned cache) is a traced (B,)
        vector, so *every* tenant mix — all-one-tenant, interleaved,
        padded — shares this one compiled program (§13.2).

        ``window``/``window_len`` (context-fused cache, §16) pool each
        row's session turns into its key ONCE here — the same fused
        embedding searches the slab and, on a miss, becomes the inserted
        key, so a later equivalent dialogue state finds it. Both are
        traced, so every session mix shares this one compiled program.
        """
        queries = self._maybe_fuse(runtime, queries, window, window_len)
        if peeked is None and valid is None:
            result, runtime = self.lookup(runtime, queries, now,
                                          tenant_id=tenant_id, comm=comm)
        else:
            if peeked is None:
                # no peek supplied but the batch is padded: search without
                # committing, then commit valid-masked — pad rows must not
                # count as lookups/misses or touch LRU state
                peeked, _ = self.lookup(runtime, queries, now,
                                        update_counters=False,
                                        tenant_id=tenant_id, comm=comm)
            result, runtime = self.commit(runtime, peeked, now, valid=valid,
                                          tenant_id=tenant_id, comm=comm)
        insert_mask = ~result.hit
        if valid is not None:
            insert_mask = insert_mask & valid
        runtime = self.insert(
            runtime, queries, miss_values, miss_value_lens, now,
            source_id=source_id, mask=insert_mask, tenant_id=tenant_id,
            comm=comm)
        return result, runtime
