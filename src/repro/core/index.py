"""ANN indexes over the cache slab (paper §2.4, TPU-adapted — DESIGN.md §8).

Two TPU-native index structures replace the paper's HNSW graph:

* ``ExactIndex`` — blocked brute-force cosine top-k on the MXU. Exact
  (recall = 1.0), one GEMM; dispatches to the Pallas fused kernel on TPU
  and to the jnp reference elsewhere. Stateless: its index state is an
  empty pytree.
* ``IVFIndex`` — inverted-file index: k-means centroids over the slab;
  search probes the top-``nprobe`` clusters only. This recovers HNSW's
  sub-linear scaling with *static shapes and dense matmuls*: both the
  centroid scoring and the in-cluster scoring are GEMMs. Cluster membership
  is a padded (ncentroids, bucket_cap) table rebuilt by ``refit`` —
  the analogue of the paper's periodic HNSW "rebalancing" (§2.4) — and kept
  fresh between rebuilds by ``absorb`` (incremental assignment of new rows,
  vectorized as a sort-by-centroid scatter). Search runs in two stages that
  both hit fused Pallas kernels on TPU (DESIGN.md §15): the centroid probe
  goes through ``ops.cosine_topk`` (§3's kernel, centroids as the slab) and
  the candidate stage through ``ops.ivf_topk``, which gathers the probed
  slab rows HBM -> VMEM *inside* the kernel — the (B, M, d) gathered-
  candidate tensor of the jnp formulation never materializes in HBM. All
  visibility (bucket validity, aliveness, tenancy intervals, per-row
  duplicate suppression) is folded into the candidate ids by
  ``IVFIndex.candidates`` so the jnp oracle and the kernel share one
  contract.

Both conform to the ``repro.core.runtime.Index`` protocol — uniform
``init(config) / search(istate, ...) / absorb(istate, ...) /
refit(istate, ...)`` signatures so callers never branch on the index type
(DESIGN.md §8.1). The paper-faithful HNSW itself lives in
``repro.core.hnsw`` (CPU reference).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.similarity import (NEG_INF, cosine_scores,
                                   interval_visibility, l2_normalize,
                                   masked_topk)
from repro.core.types import CacheConfig

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ExactState:
    """Empty index state: brute-force scoring reads the slab directly."""


@dataclasses.dataclass(frozen=True)
class ExactIndex:
    """Exact blocked scoring. ``backend='auto'|'jnp'|'pallas'``."""

    topk: int = 4
    backend: str = "auto"

    def init(self, config: CacheConfig) -> ExactState:
        del config
        return ExactState()

    def search(self, istate: ExactState, queries: Array, keys: Array,
               alive: Array, *, interval: tuple[Array, Array] | None = None
               ) -> tuple[Array, Array]:
        """(B,d) x (N,d) -> (scores (B,k), indices (B,k)).

        ``alive`` is (N,) — one visibility mask for the whole batch — or
        (B, N) for general per-row visibility. ``interval`` = per-row
        ``(starts, sizes)`` operands restricting each row to a contiguous
        slot range on top of a shared (N,) ``alive`` — the tenancy path
        (contiguous PartitionMap regions, DESIGN.md §14): on TPU it stays
        on the fused interval-masked Pallas kernel with O(B) operand
        traffic; a (B, N) ``alive`` routes to the dense blocked-mask
        kernel. Rows with no visible live slot return exactly (-inf, -1).
        """
        del istate
        backend = self.backend
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        queries = l2_normalize(queries)  # keys are normalized at insert time
        if interval is not None and alive.ndim == 2:
            # interval on top of an already-per-row mask: fold it in so the
            # restriction is never dropped (IVF composes the same way)
            alive = interval_visibility(alive, *interval)
            interval = None
        if backend == "pallas":
            from repro.kernels import ops  # deferred: kernels are optional deps

            if interval is not None:
                starts, sizes = interval
                return ops.cosine_topk_interval(queries, keys, alive,
                                                starts, sizes, k=self.topk)
            return ops.cosine_topk(queries, keys, alive, k=self.topk)
        if interval is not None:
            alive = interval_visibility(alive, *interval)
        scores = cosine_scores(queries, keys, alive)
        vals, idx = masked_topk(scores, self.topk)
        # all-masked rows: same (-inf, -1) contract as the Pallas kernels
        idx = jnp.where(vals > NEG_INF, idx, -1)
        return vals, idx.astype(jnp.int32)

    def absorb(self, istate: ExactState, slots: Array, keys: Array,
               mask: Array) -> ExactState:
        del slots, keys, mask
        return istate

    def refit(self, istate: ExactState, keys: Array, alive: Array,
              rng: Array) -> ExactState:
        del keys, alive, rng
        return istate


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFState:
    centroids: Array   # (C, d) normalized
    buckets: Array     # (C, cap) int32 slot ids, -1 padded
    bucket_valid: Array  # (C, cap) bool


def dedup_candidates(cand: Array, visible: Array) -> Array:
    """Suppress per-row duplicate candidate slot ids (DESIGN.md §15.3).

    A slot recycled across buckets (``absorb`` leaves stale pointers behind
    by design) can reach ``search`` twice in one row's candidate list with
    *identical* scores — and without suppression would occupy two of the k
    result rows. Returns ``visible`` with every duplicate of an
    already-visible slot id turned off, keeping the *first visible*
    occurrence per row (matching ``top_k``'s lowest-position tie-break).
    Invisible occurrences never suppress a visible one.

    cand: (B, M) int32 slot ids; visible: (B, M) bool. O(B·M log M) — a
    sort over int32 ids, noise next to the candidate gather it protects.
    """
    b, m = cand.shape
    pos = jnp.arange(m, dtype=jnp.int32)[None, :]
    # invisible entries get unique sentinels so they never collide with a
    # real id (slot ids < 2**30) or with each other
    key = jnp.where(visible, cand, jnp.int32(2 ** 30) + pos)
    order = jnp.argsort(key, axis=1)                 # stable: earliest first
    skey = jnp.take_along_axis(key, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), bool), skey[:, 1:] == skey[:, :-1]], axis=1)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    dup = jnp.zeros((b, m), bool).at[rows, order].set(dup_sorted)
    return visible & ~dup


def _absorb_serial(buckets: Array, bucket_valid: Array, assign: Array,
                   slots: Array, mask: Array, cap: int
                   ) -> tuple[Array, Array]:
    """Reference serial absorb: the original O(B) ``fori_loop`` scatter.

    Kept as the semantic oracle for the vectorized scatter in
    ``IVFIndex.absorb`` (parity-tested): rows append *in batch order* to
    their assigned bucket's fill point; once a bucket is full, later rows
    overwrite the tail slot (last writer wins).
    """
    def body(i, carry):
        buckets, bucket_valid = carry
        c = assign[i]
        fill = jnp.sum(bucket_valid[c]).astype(jnp.int32)
        pos = jnp.minimum(fill, cap - 1)
        do = mask[i]
        buckets = buckets.at[c, pos].set(
            jnp.where(do, slots[i].astype(jnp.int32), buckets[c, pos]))
        bucket_valid = bucket_valid.at[c, pos].set(
            jnp.where(do, True, bucket_valid[c, pos]))
        return buckets, bucket_valid

    return jax.lax.fori_loop(0, slots.shape[0], body, (buckets, bucket_valid))


@dataclasses.dataclass(frozen=True)
class IVFIndex:
    """Inverted-file ANN. ``refit`` = k-means rebuild; ``search`` = probe
    GEMM + fused candidate gather/score (``backend='auto'|'jnp'|'pallas'``
    pins the candidate stage for parity tests — 'auto' follows the ops
    dispatch: Pallas on TPU, jnp elsewhere)."""

    ncentroids: int = 64
    nprobe: int = 8
    bucket_cap: int = 512
    topk: int = 4
    kmeans_iters: int = 10
    backend: str = "auto"

    def init(self, config: CacheConfig) -> IVFState:
        """Empty index: deterministic random unit centroids, all-invalid
        buckets. Shape-identical to a fitted state, so the whole runtime has
        one static treedef from birth (DESIGN.md §2.1). The centroids are
        random rather than zero so that pre-refit ``absorb`` spreads new
        entries across all buckets (zero centroids would argmax every row
        into bucket 0, losing entries past one bucket's capacity); ``refit``
        replaces them with real k-means centroids."""
        c, cap = self.ncentroids, self.bucket_cap
        centroids = l2_normalize(jax.random.normal(
            jax.random.PRNGKey(0), (c, config.dim), dtype=jnp.float32))
        return IVFState(
            centroids=centroids,
            buckets=jnp.full((c, cap), -1, dtype=jnp.int32),
            bucket_valid=jnp.zeros((c, cap), dtype=bool),
        )

    def refit(self, istate: IVFState, keys: Array, alive: Array, rng: Array
              ) -> IVFState:
        """K-means over live keys; bucket table with static capacity.

        Overflowing buckets drop the farthest members (recall loss is
        measured in tests against the exact index) — the static-shape price
        of TPU-friendliness, and the analogue of HNSW's bounded degree M.
        """
        del istate  # full rebuild from the slab; prior state irrelevant
        if keys.dtype == jnp.int8:
            keys = keys.astype(jnp.float32) / 127.0  # uniform slab dequant
        valid = alive
        n, d = keys.shape
        c = self.ncentroids
        # init: random valid rows (fall back to arbitrary rows if few valid)
        p = valid.astype(jnp.float32) + 1e-6
        init_idx = jax.random.choice(rng, n, shape=(c,), replace=True, p=p / p.sum())
        centroids = l2_normalize(keys[init_idx])

        def step(centroids, _):
            sims = jnp.einsum("nd,cd->nc", keys, centroids)
            assign = jnp.argmax(sims, axis=-1)
            onehot = jax.nn.one_hot(assign, c, dtype=jnp.float32)
            onehot = onehot * valid[:, None]
            sums = jnp.einsum("nc,nd->cd", onehot, keys)
            counts = jnp.sum(onehot, axis=0)[:, None]
            new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
            return l2_normalize(new), None

        centroids, _ = jax.lax.scan(step, centroids, None, length=self.kmeans_iters)

        sims = jnp.einsum("nd,cd->nc", keys, centroids)
        sims = jnp.where(valid[:, None], sims, NEG_INF)
        assign = jnp.argmax(sims, axis=-1)           # (N,)
        member_sim = jnp.max(sims, axis=-1)          # (N,)

        # Build padded buckets: for each centroid take its top-cap members.
        # score matrix (C, N): member_sim where assigned, else -inf
        belong = jax.nn.one_hot(assign, c, dtype=bool).T  # (C, N)
        belong = belong & valid[None, :]
        member_scores = jnp.where(belong, member_sim[None, :], NEG_INF)
        top_scores, top_idx = jax.lax.top_k(member_scores, min(self.bucket_cap, n))
        cap = self.bucket_cap
        if top_idx.shape[1] < cap:  # pad if slab smaller than bucket cap
            pad = cap - top_idx.shape[1]
            top_idx = jnp.pad(top_idx, ((0, 0), (0, pad)), constant_values=0)
            top_scores = jnp.pad(top_scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        bucket_valid = top_scores > NEG_INF
        buckets = jnp.where(bucket_valid, top_idx, -1).astype(jnp.int32)
        return IVFState(centroids=centroids, buckets=buckets, bucket_valid=bucket_valid)

    def fit(self, keys: Array, valid: Array, rng: Array) -> IVFState:
        """From-scratch build (refit with a throwaway empty state)."""
        return self.refit(None, keys, valid, rng)

    def absorb(self, istate: IVFState, slots: Array, keys: Array, mask: Array
               ) -> IVFState:
        """Incrementally index freshly inserted slab rows (DESIGN.md §8.2).

        Each new key is appended to its nearest centroid's bucket (overwriting
        the bucket tail when full — those entries are the farthest members,
        restored at the next ``refit``). Stale references to a recycled slot
        elsewhere in the table cost nothing at search time: ``candidates``
        scores against the *live* slab key and suppresses per-row duplicates
        (``dedup_candidates``), so a stale pointer can neither return a wrong
        score nor occupy two of the k result rows.

        Vectorized (DESIGN.md §15.4): instead of the serial O(B) scatter
        (``_absorb_serial``, kept as the parity oracle) the batch is
        stable-sorted by assigned centroid, each row's in-bucket position is
        ``fill + rank`` (rank = position within its centroid's run, so
        batch order is preserved within a bucket), positions clamp to the
        bucket tail, and of the rows clamped onto one tail slot only the
        last in batch order writes — one gather, one sort, two scatters,
        no sequential dependency.
        """
        q = l2_normalize(keys)
        assign = jnp.argmax(
            jnp.einsum("bd,cd->bc", q, istate.centroids), axis=-1)
        cap, c = self.bucket_cap, self.ncentroids
        b = slots.shape[0]
        idx = jnp.arange(b, dtype=jnp.int32)
        # masked-out rows sort to a sentinel group past every real centroid
        group = jnp.where(mask, assign.astype(jnp.int32), jnp.int32(c))
        order = jnp.argsort(group)                     # stable: batch order
        sorted_g = group[order]
        is_start = jnp.concatenate(
            [jnp.array([True]), sorted_g[1:] != sorted_g[:-1]])
        first = jax.lax.associative_scan(                # cummax: start of
            jnp.maximum, jnp.where(is_start, idx, 0))    # each group's run
        rank = idx - first                               # 0,1,2,... per group
        fill0 = jnp.sum(istate.bucket_valid, axis=1).astype(jnp.int32)  # (C,)
        pos = jnp.minimum(fill0[jnp.minimum(sorted_g, c - 1)] + rank, cap - 1)
        # clamped rows pile onto the tail slot; the serial loop's last writer
        # wins, which in sorted space is the last row of the centroid's run
        is_end = jnp.concatenate(
            [sorted_g[1:] != sorted_g[:-1], jnp.array([True])])
        write = (sorted_g < c) & ((pos < cap - 1) | is_end)
        tgt = jnp.where(write, sorted_g, jnp.int32(c))   # OOB -> dropped
        vals = slots[order].astype(jnp.int32)
        buckets = istate.buckets.at[tgt, pos].set(vals, mode="drop")
        bucket_valid = istate.bucket_valid.at[tgt, pos].set(True, mode="drop")
        return IVFState(centroids=istate.centroids, buckets=buckets,
                        bucket_valid=bucket_valid)

    def candidates(self, istate: IVFState, q: Array, valid: Array, *,
                   interval: tuple[Array, Array] | None = None) -> Array:
        """Probe + visibility: (B, d) normalized queries -> (B, M) int32
        candidate slot ids, M = nprobe * bucket_cap, with -1 marking every
        invisible candidate. This is the single source of truth both search
        backends consume (``ref.ivf_topk_ref`` and the fused kernel), so
        their parity is structural, not coincidental.

        The centroid probe runs through ``ops.cosine_topk`` — §3's fused
        kernel on TPU, the jnp oracle elsewhere — with an all-true mask
        (centroids are always scoreable; dead buckets are filtered per
        candidate below). Folded into the ids, in order: bucket-slot
        validity, slab aliveness (``valid``, (N,) shared or (B, N)
        per-row), the per-row tenancy ``interval`` (O(B·M) compares on the
        gathered ids — never a (B, N) mask), and per-row duplicate
        suppression (``dedup_candidates``)."""
        from repro.kernels import ops  # deferred: kernels are optional deps

        ivf = istate
        b = q.shape[0]
        p = min(self.nprobe, self.ncentroids)
        always = jnp.ones((ivf.centroids.shape[0],), dtype=bool)
        _, probe = ops.cosine_topk(q, ivf.centroids, always, k=p)  # (B, P)
        cand = ivf.buckets[probe].reshape(b, -1)          # (B, M)
        visible = ivf.bucket_valid[probe].reshape(b, -1)  # (B, M)
        safe = jnp.maximum(cand, 0)
        if valid.ndim == 2:
            visible = visible & jnp.take_along_axis(valid, safe, axis=1)
        else:
            visible = visible & valid[safe]
        if interval is not None:
            starts, sizes = interval
            visible = visible & (safe >= starts[:, None]) \
                & (safe < (starts + sizes)[:, None])
        visible = dedup_candidates(cand, visible)
        return jnp.where(visible, cand, -1).astype(jnp.int32)

    def search(self, istate: IVFState, queries: Array, keys: Array,
               valid: Array, *, interval: tuple[Array, Array] | None = None
               ) -> tuple[Array, Array]:
        """(B,d) -> (scores (B,k), slot indices (B,k)). Probes nprobe buckets.

        ``valid`` is (N,) shared or (B, N) per-row; ``interval`` = per-row
        ``(starts, sizes)`` restricting each row to its own contiguous slab
        region on top of a shared (N,) ``valid`` (tenancy: each query sees
        only its own region's slots, whichever buckets they landed in) —
        applied to the gathered candidate slot ids, O(B·M), never a (B, N)
        mask. Rows with no visible live candidate return (-inf, -1).

        Both stages stay fused on TPU (DESIGN.md §15): the probe on §3's
        ``cosine_topk`` kernel and the candidate stage on ``ops.ivf_topk``,
        which gathers probed slab rows HBM -> VMEM in-kernel — the
        (B, M, d) gathered tensor of the jnp path never touches HBM."""
        from repro.kernels import ops  # deferred: kernels are optional deps

        q = l2_normalize(queries)
        cand = self.candidates(istate, q, valid, interval=interval)
        k = min(self.topk, cand.shape[1])
        return ops.ivf_topk(q, keys, cand, k=k, backend=self.backend)


@functools.partial(jax.jit, static_argnums=(0,))
def exact_search_jit(index: ExactIndex, queries, keys, valid):
    return index.search(ExactState(), queries, keys, valid)
