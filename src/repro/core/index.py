"""ANN indexes over the cache slab (paper §2.4, TPU-adapted — DESIGN.md §8).

Two TPU-native index structures replace the paper's HNSW graph:

* ``ExactIndex`` — blocked brute-force cosine top-k on the MXU. Exact
  (recall = 1.0), one GEMM; dispatches to the Pallas fused kernel on TPU
  and to the jnp reference elsewhere. Stateless: its index state is an
  empty pytree.
* ``IVFIndex`` — inverted-file index: k-means centroids over the slab;
  search probes the top-``nprobe`` clusters only. This recovers HNSW's
  sub-linear scaling with *static shapes and dense matmuls*: both the
  centroid scoring and the in-cluster scoring are GEMMs. Cluster membership
  is a padded (ncentroids, bucket_cap) table rebuilt by ``refit`` —
  the analogue of the paper's periodic HNSW "rebalancing" (§2.4) — and kept
  fresh between rebuilds by ``absorb`` (incremental assignment of new rows).

Both conform to the ``repro.core.runtime.Index`` protocol — uniform
``init(config) / search(istate, ...) / absorb(istate, ...) /
refit(istate, ...)`` signatures so callers never branch on the index type
(DESIGN.md §8.1). The paper-faithful HNSW itself lives in
``repro.core.hnsw`` (CPU reference).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.similarity import (NEG_INF, cosine_scores,
                                   interval_visibility, l2_normalize,
                                   masked_topk)
from repro.core.types import CacheConfig

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ExactState:
    """Empty index state: brute-force scoring reads the slab directly."""


@dataclasses.dataclass(frozen=True)
class ExactIndex:
    """Exact blocked scoring. ``backend='auto'|'jnp'|'pallas'``."""

    topk: int = 4
    backend: str = "auto"

    def init(self, config: CacheConfig) -> ExactState:
        del config
        return ExactState()

    def search(self, istate: ExactState, queries: Array, keys: Array,
               alive: Array, *, interval: tuple[Array, Array] | None = None
               ) -> tuple[Array, Array]:
        """(B,d) x (N,d) -> (scores (B,k), indices (B,k)).

        ``alive`` is (N,) — one visibility mask for the whole batch — or
        (B, N) for general per-row visibility. ``interval`` = per-row
        ``(starts, sizes)`` operands restricting each row to a contiguous
        slot range on top of a shared (N,) ``alive`` — the tenancy path
        (contiguous PartitionMap regions, DESIGN.md §14): on TPU it stays
        on the fused interval-masked Pallas kernel with O(B) operand
        traffic; a (B, N) ``alive`` routes to the dense blocked-mask
        kernel. Rows with no visible live slot return exactly (-inf, -1).
        """
        del istate
        backend = self.backend
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        queries = l2_normalize(queries)  # keys are normalized at insert time
        if interval is not None and alive.ndim == 2:
            # interval on top of an already-per-row mask: fold it in so the
            # restriction is never dropped (IVF composes the same way)
            alive = interval_visibility(alive, *interval)
            interval = None
        if backend == "pallas":
            from repro.kernels import ops  # deferred: kernels are optional deps

            if interval is not None:
                starts, sizes = interval
                return ops.cosine_topk_interval(queries, keys, alive,
                                                starts, sizes, k=self.topk)
            return ops.cosine_topk(queries, keys, alive, k=self.topk)
        if interval is not None:
            alive = interval_visibility(alive, *interval)
        scores = cosine_scores(queries, keys, alive)
        vals, idx = masked_topk(scores, self.topk)
        # all-masked rows: same (-inf, -1) contract as the Pallas kernels
        idx = jnp.where(vals > NEG_INF, idx, -1)
        return vals, idx.astype(jnp.int32)

    def absorb(self, istate: ExactState, slots: Array, keys: Array,
               mask: Array) -> ExactState:
        del slots, keys, mask
        return istate

    def refit(self, istate: ExactState, keys: Array, alive: Array,
              rng: Array) -> ExactState:
        del keys, alive, rng
        return istate


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFState:
    centroids: Array   # (C, d) normalized
    buckets: Array     # (C, cap) int32 slot ids, -1 padded
    bucket_valid: Array  # (C, cap) bool


@dataclasses.dataclass(frozen=True)
class IVFIndex:
    """Inverted-file ANN. ``refit`` = k-means rebuild; ``search`` = 2 GEMMs."""

    ncentroids: int = 64
    nprobe: int = 8
    bucket_cap: int = 512
    topk: int = 4
    kmeans_iters: int = 10

    def init(self, config: CacheConfig) -> IVFState:
        """Empty index: deterministic random unit centroids, all-invalid
        buckets. Shape-identical to a fitted state, so the whole runtime has
        one static treedef from birth (DESIGN.md §2.1). The centroids are
        random rather than zero so that pre-refit ``absorb`` spreads new
        entries across all buckets (zero centroids would argmax every row
        into bucket 0, losing entries past one bucket's capacity); ``refit``
        replaces them with real k-means centroids."""
        c, cap = self.ncentroids, self.bucket_cap
        centroids = l2_normalize(jax.random.normal(
            jax.random.PRNGKey(0), (c, config.dim), dtype=jnp.float32))
        return IVFState(
            centroids=centroids,
            buckets=jnp.full((c, cap), -1, dtype=jnp.int32),
            bucket_valid=jnp.zeros((c, cap), dtype=bool),
        )

    def refit(self, istate: IVFState, keys: Array, alive: Array, rng: Array
              ) -> IVFState:
        """K-means over live keys; bucket table with static capacity.

        Overflowing buckets drop the farthest members (recall loss is
        measured in tests against the exact index) — the static-shape price
        of TPU-friendliness, and the analogue of HNSW's bounded degree M.
        """
        del istate  # full rebuild from the slab; prior state irrelevant
        if keys.dtype == jnp.int8:
            keys = keys.astype(jnp.float32) / 127.0  # uniform slab dequant
        valid = alive
        n, d = keys.shape
        c = self.ncentroids
        # init: random valid rows (fall back to arbitrary rows if few valid)
        p = valid.astype(jnp.float32) + 1e-6
        init_idx = jax.random.choice(rng, n, shape=(c,), replace=True, p=p / p.sum())
        centroids = l2_normalize(keys[init_idx])

        def step(centroids, _):
            sims = jnp.einsum("nd,cd->nc", keys, centroids)
            assign = jnp.argmax(sims, axis=-1)
            onehot = jax.nn.one_hot(assign, c, dtype=jnp.float32)
            onehot = onehot * valid[:, None]
            sums = jnp.einsum("nc,nd->cd", onehot, keys)
            counts = jnp.sum(onehot, axis=0)[:, None]
            new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
            return l2_normalize(new), None

        centroids, _ = jax.lax.scan(step, centroids, None, length=self.kmeans_iters)

        sims = jnp.einsum("nd,cd->nc", keys, centroids)
        sims = jnp.where(valid[:, None], sims, NEG_INF)
        assign = jnp.argmax(sims, axis=-1)           # (N,)
        member_sim = jnp.max(sims, axis=-1)          # (N,)

        # Build padded buckets: for each centroid take its top-cap members.
        # score matrix (C, N): member_sim where assigned, else -inf
        belong = jax.nn.one_hot(assign, c, dtype=bool).T  # (C, N)
        belong = belong & valid[None, :]
        member_scores = jnp.where(belong, member_sim[None, :], NEG_INF)
        top_scores, top_idx = jax.lax.top_k(member_scores, min(self.bucket_cap, n))
        cap = self.bucket_cap
        if top_idx.shape[1] < cap:  # pad if slab smaller than bucket cap
            pad = cap - top_idx.shape[1]
            top_idx = jnp.pad(top_idx, ((0, 0), (0, pad)), constant_values=0)
            top_scores = jnp.pad(top_scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        bucket_valid = top_scores > NEG_INF
        buckets = jnp.where(bucket_valid, top_idx, -1).astype(jnp.int32)
        return IVFState(centroids=centroids, buckets=buckets, bucket_valid=bucket_valid)

    def fit(self, keys: Array, valid: Array, rng: Array) -> IVFState:
        """From-scratch build (refit with a throwaway empty state)."""
        return self.refit(None, keys, valid, rng)

    def absorb(self, istate: IVFState, slots: Array, keys: Array, mask: Array
               ) -> IVFState:
        """Incrementally index freshly inserted slab rows (DESIGN.md §8.2).

        Each new key is appended to its nearest centroid's bucket (overwriting
        the bucket tail when full — those entries are the farthest members,
        restored at the next ``refit``). Stale references to a recycled slot
        elsewhere in the table are harmless: search always scores against the
        *live* slab key, so a stale pointer can at worst duplicate a
        candidate, never return a wrong score.
        """
        q = l2_normalize(keys)
        assign = jnp.argmax(jnp.einsum("bd,cd->bc", q, istate.centroids), axis=-1)
        cap = self.bucket_cap

        def body(i, carry):
            buckets, bucket_valid = carry
            c = assign[i]
            fill = jnp.sum(bucket_valid[c]).astype(jnp.int32)
            pos = jnp.minimum(fill, cap - 1)
            do = mask[i]
            buckets = buckets.at[c, pos].set(
                jnp.where(do, slots[i].astype(jnp.int32), buckets[c, pos]))
            bucket_valid = bucket_valid.at[c, pos].set(
                jnp.where(do, True, bucket_valid[c, pos]))
            return buckets, bucket_valid

        buckets, bucket_valid = jax.lax.fori_loop(
            0, slots.shape[0], body, (istate.buckets, istate.bucket_valid))
        return IVFState(centroids=istate.centroids, buckets=buckets,
                        bucket_valid=bucket_valid)

    def search(self, istate: IVFState, queries: Array, keys: Array,
               valid: Array, *, interval: tuple[Array, Array] | None = None
               ) -> tuple[Array, Array]:
        """(B,d) -> (scores (B,k), slot indices (B,k)). Probes nprobe buckets.

        ``valid`` is (N,) shared or (B, N) per-row; ``interval`` = per-row
        ``(starts, sizes)`` restricting each row to its own contiguous slab
        region on top of a shared (N,) ``valid`` (tenancy: each query sees
        only its own region's slots, whichever buckets they landed in) —
        applied to the gathered candidate slot ids, O(B·M), never a (B, N)
        mask. Rows with no visible live candidate return (-inf, -1)."""
        ivf = istate
        q = l2_normalize(queries)
        csims = jnp.einsum("bd,cd->bc", q, ivf.centroids)      # (B, C)
        _, probe = jax.lax.top_k(csims, min(self.nprobe, self.ncentroids))  # (B, P)
        cand = ivf.buckets[probe]          # (B, P, cap)
        cand_ok = ivf.bucket_valid[probe]  # (B, P, cap)
        b = q.shape[0]
        cand_flat = cand.reshape(b, -1)
        ok_flat = cand_ok.reshape(b, -1)
        safe = jnp.maximum(cand_flat, 0)
        cand_keys = keys[safe]                                  # (B, M, d)
        if cand_keys.dtype == jnp.int8:
            # uniform slab dequant (store.insert: round(normalized * 127));
            # scoring raw int8 would inflate every score x127
            cand_keys = cand_keys.astype(jnp.float32) / 127.0
        sims = jnp.einsum("bd,bmd->bm", q, cand_keys,
                          preferred_element_type=jnp.float32)
        if valid.ndim == 2:
            alive = jnp.take_along_axis(valid, safe, axis=1) & ok_flat
        else:
            alive = valid[safe] & ok_flat
        if interval is not None:
            starts, sizes = interval
            alive = alive & (safe >= starts[:, None]) \
                & (safe < (starts + sizes)[:, None])
        sims = jnp.where(alive, sims, NEG_INF)
        k = min(self.topk, sims.shape[-1])
        top_s, top_m = jax.lax.top_k(sims, k)
        top_slot = jnp.take_along_axis(cand_flat, top_m, axis=-1)
        top_slot = jnp.where(top_s > NEG_INF, top_slot, -1)
        return top_s, top_slot.astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(0,))
def exact_search_jit(index: ExactIndex, queries, keys, valid):
    return index.search(ExactState(), queries, keys, valid)
