"""Core datatypes for the semantic cache.

The cache is a *functional*, device-resident analogue of the paper's
Redis + hnswlib stack: a fixed-capacity slab of embedding keys, response
values and per-entry metadata (TTL deadline, validity, LRU/LFU counters),
updated purely with ``.at[]`` so every operation is jit-able, donate-able
and pjit-shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Static configuration of a semantic cache instance.

    Attributes:
      dim: embedding dimensionality (384 for MiniLM-class, 1536 for ada-002).
      capacity: number of slab slots (paper: Redis keyspace size).
      value_len: stored response length in tokens (fixed-width slab).
      ttl: time-to-live in seconds (paper §2.7). ``None`` disables expiry.
      threshold: cosine-similarity hit threshold (paper: 0.8).
      topk: neighbours retrieved per query (paper: top-k ANN search).
      eviction: slot-selection policy on insert: "ring" | "lru" | "lfu".
      key_dtype: dtype of stored keys (f32 faithful; int8 = quantized variant).
    """

    dim: int = 384
    capacity: int = 8192
    value_len: int = 32
    ttl: float | None = 3600.0
    threshold: float = 0.8
    topk: int = 4
    eviction: str = "ring"
    key_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.eviction not in ("ring", "lru", "lfu"):
            raise ValueError(f"unknown eviction policy {self.eviction!r}")
        if self.capacity <= 0 or self.dim <= 0 or self.value_len <= 0:
            raise ValueError("capacity, dim and value_len must be positive")
        if not (0.0 <= self.threshold <= 1.0):
            raise ValueError("threshold must be within [0, 1]")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheState:
    """The slab. All leaves have leading dim = capacity (except scalars)."""

    keys: Array        # (N, dim) normalized embeddings
    values: Array      # (N, value_len) int32 response token ids
    value_lens: Array  # (N,) int32 true response lengths
    expiry: Array      # (N,) float32 absolute deadline (inf = never)
    valid: Array       # (N,) bool slot occupied & alive
    freq: Array        # (N,) int32 hit count since insert (LFU)
    last_used: Array   # (N,) float32 last access time (LRU)
    inserted_at: Array # (N,) float32 insert time
    source_id: Array   # (N,) int32 provenance id (dataset QA id; -1 unknown)
    ptr: Array         # () int32 ring insert pointer
    n_inserts: Array   # () int32 total inserts (monotone clock)

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def dim(self) -> int:
        return self.keys.shape[1]


def init_cache_state(config: CacheConfig) -> CacheState:
    """Fresh, empty slab."""
    n, d, v = config.capacity, config.dim, config.value_len
    return CacheState(
        keys=jnp.zeros((n, d), dtype=config.key_dtype),
        values=jnp.zeros((n, v), dtype=jnp.int32),
        value_lens=jnp.zeros((n,), dtype=jnp.int32),
        expiry=jnp.full((n,), jnp.inf, dtype=jnp.float32),
        valid=jnp.zeros((n,), dtype=bool),
        freq=jnp.zeros((n,), dtype=jnp.int32),
        last_used=jnp.zeros((n,), dtype=jnp.float32),
        inserted_at=jnp.zeros((n,), dtype=jnp.float32),
        source_id=jnp.full((n,), -1, dtype=jnp.int32),
        ptr=jnp.zeros((), dtype=jnp.int32),
        n_inserts=jnp.zeros((), dtype=jnp.int32),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LookupResult:
    """Result of a batched cache lookup."""

    index: Array    # (B,) int32 best slot (argmax cosine among valid+alive)
    score: Array    # (B,) float32 best cosine similarity (-inf if cache empty)
    hit: Array      # (B,) bool score >= threshold
    values: Array   # (B, value_len) int32 cached response (garbage when miss)
    value_lens: Array  # (B,) int32
    source_id: Array   # (B,) int32 provenance of the matched entry
    topk_index: Array  # (B, k) int32
    topk_score: Array  # (B, k) float32
    near: Array     # (B,) bool score in [τ_lo, τ_hi) band — always False
                    # unless the policy defines a band (DESIGN.md §17)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheStats:
    """Running counters (the paper's Table-1 numbers are derived from these)."""

    lookups: Array  # () int32 (int64 unavailable without x64)
    hits: Array     # () int32
    misses: Array   # () int32
    expired_evictions: Array  # () int32
    inserts: Array  # () int32

    def record_lookups(self, n: Array | int, n_hit: Array) -> "CacheStats":
        """Counters after a batch of ``n`` lookups with ``n_hit`` hits —
        the single definition shared by the local and distributed paths."""
        return CacheStats(
            lookups=self.lookups + n,
            hits=self.hits + n_hit,
            misses=self.misses + (n - n_hit),
            expired_evictions=self.expired_evictions,
            inserts=self.inserts,
        )

    @staticmethod
    def zeros() -> "CacheStats":
        # distinct buffers per field: the runtime pytree is donated as a
        # unit, and donating one aliased buffer N times is an XLA error
        def z():
            return jnp.zeros((), dtype=jnp.int32)
        return CacheStats(lookups=z(), hits=z(), misses=z(),
                          expired_evictions=z(), inserts=z())

    def hit_rate(self) -> Array:
        return jnp.where(self.lookups > 0, self.hits / jnp.maximum(self.lookups, 1), 0.0)
