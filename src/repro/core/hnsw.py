"""Paper-faithful HNSW index (Malkov & Yashunin 2018), numpy, CPU.

The paper's deployment searches the cache with hnswlib-node. HNSW is a
pointer-chasing multi-layer proximity graph — the *reference* algorithm for
our reproduction baseline. It does not map onto the TPU's MXU (DESIGN.md §3),
so the TPU path replaces it with exact blocked scoring / IVF; this module
exists so the reproduction measures the paper's own data structure and so
tests can assert the TPU path's recall against it.

Implements: level sampling (exponential), greedy descent through upper
layers, ef-bounded best-first search at layer 0, and bidirectional link
insertion with degree pruning — the core of the published algorithm.
Distances are cosine (via normalized dot product), matching the paper.
"""
from __future__ import annotations

import heapq
import math

import numpy as np


class HNSWIndex:
    """Hierarchical Navigable Small World graph over normalized vectors."""

    def __init__(self, dim: int, max_elements: int = 100_000, m: int = 16,
                 ef_construction: int = 200, ef_search: int = 64,
                 seed: int = 0):
        self.dim = dim
        self.max_elements = max_elements
        self.m = m                      # max links per node per layer (2m at layer 0)
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self._rng = np.random.default_rng(seed)
        self._level_mult = 1.0 / math.log(m)

        self.vectors = np.zeros((max_elements, dim), dtype=np.float32)
        self.levels: list[int] = []
        # links[level][node] -> list[int]
        self.links: list[dict[int, list[int]]] = []
        self.entry_point: int | None = None
        self.count = 0

    # -- distances ---------------------------------------------------------
    def _sim(self, q: np.ndarray, idx) -> np.ndarray:
        return self.vectors[idx] @ q

    # -- construction ------------------------------------------------------
    def _random_level(self) -> int:
        return int(-math.log(max(self._rng.random(), 1e-12)) * self._level_mult)

    def add(self, vec: np.ndarray) -> int:
        if self.count >= self.max_elements:
            # dynamic resize, as the paper's system does when the index fills
            self._resize(self.max_elements * 2)
        vec = np.asarray(vec, dtype=np.float32)
        vec = vec / max(np.linalg.norm(vec), 1e-12)
        node = self.count
        self.vectors[node] = vec
        level = self._random_level()
        self.levels.append(level)
        while len(self.links) <= level:
            self.links.append({})
        for lv in range(level + 1):
            self.links[lv][node] = []
        self.count += 1

        if self.entry_point is None:
            self.entry_point = node
            return node

        ep = self.entry_point
        top = self.levels[self.entry_point]
        # greedy descend through layers above the node's level
        for lv in range(top, level, -1):
            ep = self._greedy_step(vec, ep, lv)
        # insert links from level min(level, top) down to 0
        for lv in range(min(level, top), -1, -1):
            cands = self._search_layer(vec, [ep], lv, self.ef_construction)
            m_max = self.m * 2 if lv == 0 else self.m
            neigh = self._select_neighbors(cands, self.m)
            self.links[lv][node] = [n for _, n in neigh]
            for _, n in neigh:
                lst = self.links[lv][n]
                lst.append(node)
                if len(lst) > m_max:
                    # prune to the closest m_max
                    sims = self._sim(self.vectors[n], lst)
                    order = np.argsort(-sims)[:m_max]
                    self.links[lv][n] = [lst[i] for i in order]
            ep = cands[0][1] if cands else ep
        if level > self.levels[self.entry_point]:
            self.entry_point = node
        return node

    def _resize(self, new_max: int) -> None:
        grown = np.zeros((new_max, self.dim), dtype=np.float32)
        grown[: self.count] = self.vectors[: self.count]
        self.vectors = grown
        self.max_elements = new_max

    def _greedy_step(self, q: np.ndarray, ep: int, level: int) -> int:
        cur, cur_sim = ep, float(self.vectors[ep] @ q)
        improved = True
        while improved:
            improved = False
            for n in self.links[level].get(cur, ()):
                s = float(self.vectors[n] @ q)
                if s > cur_sim:
                    cur, cur_sim, improved = n, s, True
        return cur

    def _search_layer(self, q, eps, level, ef):
        """Best-first search; returns [(sim, node)] sorted desc, <= ef items."""
        visited = set(eps)
        cand = [(-float(self.vectors[e] @ q), e) for e in eps]  # max-heap via neg
        heapq.heapify(cand)
        best = [(float(self.vectors[e] @ q), e) for e in eps]   # min-heap of sims
        heapq.heapify(best)
        while cand:
            neg_s, node = heapq.heappop(cand)
            if best and -neg_s < best[0][0] and len(best) >= ef:
                break
            for n in self.links[level].get(node, ()):
                if n in visited:
                    continue
                visited.add(n)
                s = float(self.vectors[n] @ q)
                if len(best) < ef or s > best[0][0]:
                    heapq.heappush(cand, (-s, n))
                    heapq.heappush(best, (s, n))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted(best, reverse=True)

    @staticmethod
    def _select_neighbors(cands, m):
        return cands[:m]

    # -- search ------------------------------------------------------------
    def search(self, q: np.ndarray, k: int = 1) -> tuple[np.ndarray, np.ndarray]:
        """Top-k (indices, cosine sims) for one query. Empty -> (-1, -inf)."""
        if self.entry_point is None or self.count == 0:
            return (np.full(k, -1, dtype=np.int64), np.full(k, -np.inf, np.float32))
        q = np.asarray(q, dtype=np.float32)
        q = q / max(np.linalg.norm(q), 1e-12)
        ep = self.entry_point
        for lv in range(self.levels[self.entry_point], 0, -1):
            ep = self._greedy_step(q, ep, lv)
        res = self._search_layer(q, [ep], 0, max(self.ef_search, k))[:k]
        idx = np.full(k, -1, dtype=np.int64)
        sims = np.full(k, -np.inf, dtype=np.float32)
        for i, (s, n) in enumerate(res):
            idx[i], sims[i] = n, s
        return idx, sims

    def search_batch(self, qs: np.ndarray, k: int = 1):
        idx = np.stack([self.search(q, k)[0] for q in qs])
        sims = np.stack([self.search(q, k)[1] for q in qs])
        return idx, sims
