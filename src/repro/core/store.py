"""Functional slab store: insert / evict / expire (paper §2.3, §2.7).

This is the Redis analogue (DESIGN.md §3): a fixed-capacity, device-resident
slab updated functionally. TTL semantics mirror Redis ``SETEX``/``EXPIRE``:
every entry carries an absolute deadline; a slot is *alive* iff it is valid
and its deadline has not passed. Expired slots are reclaimed lazily by the
eviction scan — exactly how Redis lazy expiry interacts with eviction.

Eviction policies (slot selection when inserting into a full slab):
  ring — paper-faithful FIFO ring buffer (oldest-inserted overwritten first);
  lru  — least-recently-used (Redis ``allkeys-lru``);
  lfu  — least-frequently-used (Redis ``allkeys-lfu``).
Empty or expired slots are always preferred over evicting a live entry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CacheConfig, CacheState
from repro.core.similarity import l2_normalize

Array = jax.Array

_BIG = jnp.float32(3.0e38)


def alive_mask(state: CacheState, now: Array | float) -> Array:
    """(N,) bool: valid and not past TTL deadline."""
    now = jnp.asarray(now, dtype=jnp.float32)
    return state.valid & (state.expiry > now)


def expire(state: CacheState, now: Array | float) -> tuple[CacheState, Array]:
    """Eagerly mark expired slots invalid. Returns (state, n_expired).

    Lazy expiry (just using ``alive_mask`` in lookups) is equivalent for
    correctness; eager expiry keeps the stats honest and frees slots for the
    eviction scan. This mirrors Redis' active-expire cycle.
    """
    now = jnp.asarray(now, dtype=jnp.float32)
    expired = state.valid & (state.expiry <= now)
    n = jnp.sum(expired).astype(jnp.int32)
    state = jax.tree_util.tree_map(lambda x: x, state)  # shallow copy
    state.valid = state.valid & ~expired
    return state, n


def _eviction_scores(config: CacheConfig, state: CacheState, now: Array) -> Array:
    """Lower score == evict first. Dead slots get -BIG so they always win."""
    dead = ~alive_mask(state, now)
    if config.eviction == "ring":
        # FIFO by insert clock; ties broken by slot order via tiny epsilon.
        score = state.inserted_at
    elif config.eviction == "lru":
        score = state.last_used
    else:  # lfu
        score = state.freq.astype(jnp.float32)
    return jnp.where(dead, -_BIG, score)


def select_slots(config: CacheConfig, state: CacheState, now: Array, m: int,
                 mask: Array | None = None) -> Array:
    """Pick ``m`` distinct slots to (over)write, per the eviction policy.

    For the ring, masked batches pack the *written* rows contiguously from
    ``ptr`` (masked-out rows are parked on the distinct slots just past the
    written block, where their keep-old write is a no-op). Without packing,
    written rows would land at scattered offsets while ``ptr`` advances only
    by ``sum(mask)`` — the next batch would then overwrite entries inserted
    one batch earlier and leave permanent holes in the slab.
    """
    if config.eviction == "ring":
        # Pure ring: pointer arithmetic, O(1), exactly a circular Redis stream.
        if mask is None:
            off = jnp.arange(m, dtype=jnp.int32)
        else:
            mi = mask.astype(jnp.int32)
            written_rank = jnp.cumsum(mi) - mi          # rank among written
            skipped_rank = jnp.cumsum(1 - mi) - (1 - mi)
            off = jnp.where(mask, written_rank,
                            jnp.sum(mi) + skipped_rank)
        return (state.ptr + off) % config.capacity
    scores = _eviction_scores(config, state, now)
    # m smallest scores == top-k of negated scores.
    _, idx = jax.lax.top_k(-scores, m)
    return idx.astype(jnp.int32)


def select_slots_tenant(partition, tenant_ptr: Array, tenant_id: Array,
                        mask: Array) -> tuple[Array, Array]:
    """Per-tenant ring slot selection inside disjoint slab regions
    (DESIGN.md §13.2).

    Each tenant runs its own FIFO ring over its contiguous region
    ``[start_t, start_t + size_t)``; ``tenant_ptr`` is the (T,) vector of
    per-tenant ring offsets. Written rows of a tenant pack contiguously from
    that tenant's pointer (same packing argument as the global ring in
    ``select_slots``); masked-out rows park on that tenant's slots just past
    its written block, where their keep-old write is a no-op. Regions are
    disjoint, so slots are distinct across tenants by construction; within a
    tenant they are distinct as long as the per-batch row count does not
    exceed the region size (the engine enforces ``min region >= batch``).

    Returns ``(slots (B,), new_tenant_ptr (T,))``.
    """
    b = tenant_id.shape[0]
    starts = partition.starts_array()[tenant_id]
    sizes = partition.sizes_array()[tenant_id]
    mask = mask.astype(bool)
    same = tenant_id[:, None] == tenant_id[None, :]              # (B, B)
    before = jnp.tril(jnp.ones((b, b), dtype=bool), k=-1)
    written_rank = jnp.sum(same & before & mask[None, :], axis=1)
    skipped_rank = jnp.sum(same & before & ~mask[None, :], axis=1)
    written_total = jnp.sum(same & mask[None, :], axis=1)
    off = jnp.where(mask, written_rank, written_total + skipped_rank)
    slots = starts + (tenant_ptr[tenant_id] + off) % sizes
    counts = jnp.zeros_like(tenant_ptr).at[tenant_id].add(
        mask.astype(jnp.int32))
    new_ptr = (tenant_ptr + counts) % partition.sizes_array()
    return slots.astype(jnp.int32), new_ptr


def insert(
    config: CacheConfig,
    state: CacheState,
    embeddings: Array,   # (B, d) query embeddings (normalized here)
    values: Array,       # (B, value_len) int32 response tokens
    value_lens: Array,   # (B,) int32
    now: Array | float,
    *,
    source_id: Array | None = None,  # (B,) provenance
    mask: Array | None = None,       # (B,) bool: only insert where True
    slots: Array | None = None,      # (B,) externally chosen distinct slots
) -> tuple[CacheState, Array]:
    """Insert a batch of (embedding, response) pairs (paper §2.5 step 3).

    Masked-out rows are written to a scratch slot pattern and immediately
    neutralized, keeping the op fully static-shaped (jit/pjit friendly):
    rows with ``mask=False`` do not modify any live slot.

    ``slots`` overrides the eviction policy's slot choice with externally
    selected (distinct) slots — the tenancy layer picks per-region slots via
    ``select_slots_tenant`` and manages its own per-tenant ring pointers, so
    the global ``state.ptr`` is left untouched on that path.

    Returns ``(state, slots)`` where ``slots`` is the (B,) int32 slot id each
    row was (or, for masked rows, would have been) written to — the ANN
    index's ``absorb`` hook consumes these to stay fresh between refits
    (DESIGN.md §8.2).
    """
    b = embeddings.shape[0]
    now = jnp.asarray(now, dtype=jnp.float32)
    if source_id is None:
        source_id = jnp.full((b,), -1, dtype=jnp.int32)
    if mask is None:
        mask = jnp.ones((b,), dtype=bool)

    keys = l2_normalize(embeddings)
    if config.key_dtype == jnp.int8:
        # symmetric quantization of unit rows: scale 1/127 is uniform, so
        # cosine ranking is preserved within ~0.4% (int8 slab = 4x less HBM
        # traffic in the lookup — EXPERIMENTS.md §Perf)
        keys = jnp.clip(jnp.round(keys * 127.0), -127, 127)
    keys = keys.astype(config.key_dtype)
    external_slots = slots is not None
    if not external_slots:
        slots = select_slots(config, state, now, b, mask=mask)  # (B,) distinct

    # For masked-out rows keep the previous slot contents: gather-then-where.
    def upd(dst, src_new, slot_axis0=True):
        old = dst[slots]
        sel = mask.reshape((b,) + (1,) * (src_new.ndim - 1))
        return dst.at[slots].set(jnp.where(sel, src_new, old))

    expiry = jnp.full((b,), jnp.inf, dtype=jnp.float32)
    if config.ttl is not None:
        expiry = jnp.full((b,), now + jnp.float32(config.ttl))

    new = CacheState(
        keys=upd(state.keys, keys),
        values=upd(state.values, values.astype(jnp.int32)),
        value_lens=upd(state.value_lens, value_lens.astype(jnp.int32)),
        expiry=upd(state.expiry, expiry),
        valid=upd(state.valid, jnp.ones((b,), dtype=bool)),
        freq=upd(state.freq, jnp.zeros((b,), dtype=jnp.int32)),
        last_used=upd(state.last_used, jnp.full((b,), now)),
        inserted_at=upd(
            state.inserted_at,
            # strictly increasing within the batch so FIFO order is total
            now + jnp.arange(b, dtype=jnp.float32) * 1e-6,
        ),
        source_id=upd(state.source_id, source_id.astype(jnp.int32)),
        ptr=(state.ptr + jnp.sum(mask).astype(jnp.int32)) % config.capacity
        if config.eviction == "ring" and not external_slots
        else state.ptr,
        n_inserts=state.n_inserts + jnp.sum(mask).astype(jnp.int32),
    )
    return new, slots


def touch(state: CacheState, slot: Array, now: Array | float, hit: Array) -> CacheState:
    """Record an access for LRU/LFU bookkeeping (batched; only where hit)."""
    now = jnp.asarray(now, dtype=jnp.float32)
    slot = jnp.asarray(slot, dtype=jnp.int32)
    hit = jnp.asarray(hit)
    one = jnp.where(hit, 1, 0).astype(jnp.int32)
    state = jax.tree_util.tree_map(lambda x: x, state)
    state.freq = state.freq.at[slot].add(one)
    state.last_used = state.last_used.at[slot].max(jnp.where(hit, now, -jnp.inf))
    return state


def occupancy(state: CacheState, now: Array | float) -> Array:
    """Fraction of slots alive."""
    return jnp.mean(alive_mask(state, now).astype(jnp.float32))
