"""Distributed semantic cache — paper §2.10 "Distributed Caching" / §5.4.

Sharding scheme (DESIGN.md §5):
  * the slab shards its *capacity* dimension over the ``data`` mesh axis —
    each data-parallel group owns ``capacity/shards`` entries (a Redis
    Cluster hash-slot analogue, but with deterministic round-robin routing);
  * queries are replicated across cache shards for lookup (they are a few
    hundred floats; the slab is the big operand);
  * lookup = per-shard fused top-k, then a global argmax combine with
    ``jax.lax.pmax`` over packed (score, global_slot) pairs — one small
    all-reduce instead of gathering any slab data;
  * the winning entry's value tokens are fetched with a masked ``psum``
    (owner contributes, everyone else contributes zeros);
  * inserts route round-robin by global insert clock — shard
    ``(n_inserts + row) % num_shards`` takes the row, keeping shards
    balanced without coordination;
  * across pods the cache shards over ``data`` within each pod and the
    ``pod`` axis joins the same combine, so a response cached in pod 0
    serves a query landing on pod 1.

Everything is ``shard_map`` + ``jax.lax`` collectives — no host round trips.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import store
from repro.core.cache import SemanticCache
from repro.core.types import CacheConfig, CacheState, CacheStats, LookupResult

Array = jax.Array


def shard_axes(mesh: Mesh, cache_axes: Sequence[str]) -> int:
    return int(jnp.prod(jnp.asarray([mesh.shape[a] for a in cache_axes])))


def cache_sharding(mesh: Mesh, cache_axes: Sequence[str]) -> dict:
    """NamedShardings for a CacheState whose capacity dim shards over axes."""
    row = NamedSharding(mesh, P(tuple(cache_axes)))
    mat = NamedSharding(mesh, P(tuple(cache_axes), None))
    rep = NamedSharding(mesh, P())
    return dict(keys=mat, values=mat, value_lens=row, expiry=row, valid=row,
                freq=row, last_used=row, inserted_at=row, source_id=row,
                ptr=rep, n_inserts=rep)


def place_cache_state(state: CacheState, mesh: Mesh, cache_axes: Sequence[str]
                      ) -> CacheState:
    sh = cache_sharding(mesh, cache_axes)
    return CacheState(**{
        f.name: jax.device_put(getattr(state, f.name), sh[f.name])
        for f in dataclasses.fields(CacheState)})


@dataclasses.dataclass(frozen=True)
class DistributedCache:
    """Sharded wrapper around SemanticCache. ``cache_axes`` shard capacity."""

    cache: SemanticCache
    mesh: Mesh
    cache_axes: tuple[str, ...] = ("data",)

    @property
    def num_shards(self) -> int:
        n = 1
        for a in self.cache_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def local_config(self) -> CacheConfig:
        cfg = self.cache.config
        return dataclasses.replace(cfg, capacity=cfg.capacity // self.num_shards)

    def init(self) -> tuple[CacheState, CacheStats]:
        state, stats = self.cache.init()
        return place_cache_state(state, self.mesh, self.cache_axes), stats

    # ------------------------------------------------------------------ #
    def _local_lookup(self, state: CacheState, queries: Array, now: Array):
        """Runs per-shard inside shard_map. Returns packed global winners."""
        axes = self.cache_axes
        shard_id = jnp.zeros((), jnp.int32)
        mult = 1
        for a in reversed(axes):
            shard_id = shard_id + jax.lax.axis_index(a) * mult
            mult *= jax.lax.axis_size(a)
        local_cap = state.keys.shape[0]

        alive = store.alive_mask(state, now)
        local_cache = SemanticCache(self.local_config, index=self.cache.index,
                                    policy=self.cache.policy)
        top_s, top_i = local_cache.index.search(queries, state.keys, alive)
        best_s, best_i = top_s[:, 0], jnp.maximum(top_i[:, 0], 0)
        best_s = jnp.where(top_i[:, 0] >= 0, best_s, -jnp.inf)
        global_slot = shard_id * local_cap + best_i

        # pack (score, slot): lexicographic max == max score, tie -> max slot
        packed = jnp.stack([best_s, global_slot.astype(jnp.float32)], axis=-1)

        def combine(p):
            for a in axes:
                # pmax on score; to carry the winning slot, use the classic
                # two-field trick: compare scores, select slot of the winner.
                s = jax.lax.pmax(p[..., 0], a)
                winner = p[..., 0] >= s - 0.0  # == max on the winning shard
                slot = jnp.where(winner, p[..., 1], -1.0)
                slot = jax.lax.pmax(slot, a)
                p = jnp.stack([s, slot], axis=-1)
            return p

        packed = combine(packed)
        g_score, g_slot = packed[..., 0], packed[..., 1].astype(jnp.int32)

        # fetch winning values: owner shard contributes, psum broadcasts
        owner = g_slot // local_cap
        local_idx = jnp.where(owner == shard_id, g_slot % local_cap, 0)
        mine = (owner == shard_id) & (g_score > -jnp.inf)
        vals = jnp.where(mine[:, None], state.values[local_idx], 0)
        vlen = jnp.where(mine, state.value_lens[local_idx], 0)
        src = jnp.where(mine, state.source_id[local_idx], 0)
        # fused fetch: one psum of the concatenated (values | len | src)
        # payload instead of three collectives (§Perf iteration 3.2)
        packed = jnp.concatenate(
            [vals, vlen[:, None], src[:, None]], axis=1)
        for a in axes:
            packed = jax.lax.psum(packed, a)
        vals = packed[:, :-2]
        vlen = packed[:, -2]
        src = packed[:, -1]

        pstate = self.cache.init_policy()
        hit, _ = self.cache.policy.decide(g_score, pstate)
        hit = hit & (g_score > -jnp.inf)

        # touch local LRU/LFU where this shard owns the hit
        state = store.touch(state, local_idx, now, hit & mine)
        return state, (g_slot, g_score, hit, vals, vlen, src)

    def _local_insert(self, state: CacheState, queries, values, value_lens,
                      source_id, mask, now):
        axes = self.cache_axes
        shard_id = jnp.zeros((), jnp.int32)
        mult = 1
        for a in reversed(axes):
            shard_id = shard_id + jax.lax.axis_index(a) * mult
            mult *= jax.lax.axis_size(a)
        b = queries.shape[0]
        # round-robin routing by (global insert clock + row index)
        owner = (state.n_inserts + jnp.arange(b, dtype=jnp.int32)) % self.num_shards
        take = mask & (owner == shard_id)
        new_state = store.insert(self.local_config, state, queries, values,
                                 value_lens, now, source_id=source_id, mask=take)
        # keep the *global* insert clock in sync on every shard
        n_global = state.n_inserts + jnp.sum(mask).astype(jnp.int32)
        new_state.n_inserts = n_global
        new_state.ptr = jnp.where(
            jnp.asarray(self.cache.config.eviction == "ring"),
            new_state.ptr, new_state.ptr)
        return new_state

    # ------------------------------------------------------------------ #
    def make_lookup_insert(self):
        """Build the jit-able fused sharded step (state donated)."""
        axes = self.cache_axes
        mesh = self.mesh
        row = P(tuple(axes))
        mat = P(tuple(axes), None)
        state_spec = CacheState(
            keys=mat, values=mat, value_lens=row, expiry=row, valid=row,
            freq=row, last_used=row, inserted_at=row, source_id=row,
            ptr=P(), n_inserts=P())
        rep = P()

        def step(state, queries, miss_values, miss_value_lens, source_id, now):
            state, (slot, score, hit, vals, vlen, src) = self._local_lookup(
                state, queries, now)
            state = self._local_insert(
                state, queries, miss_values, miss_value_lens, source_id,
                ~hit, now)
            return state, (slot, score, hit, vals, vlen, src)

        sharded = shard_map(
            step, mesh=mesh,
            in_specs=(state_spec, rep, rep, rep, rep, rep),
            out_specs=(state_spec, (rep, rep, rep, rep, rep, rep)),
            check_vma=False)
        return jax.jit(sharded, donate_argnums=(0,))
