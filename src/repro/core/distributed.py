"""DistributedCache — the ONE fused step compiled for any mesh (§2.10,
DESIGN.md §19).

The paper scales its Redis store by clustering (§2.10 "Distributed
Caching"); the JAX analogue shards the slab's capacity axis across a device
mesh and runs the *same* ``SemanticCache`` step body per shard under
``shard_map``, with the cross-shard dataflow routed through the
``repro.core.cache`` communication seam (``_LocalComm``):

  merge_topk   — per-shard top-k candidates become (score, global_slot)
                 pairs, all-gathered along the cache axes and re-top-k'd
                 per row, so the merged list is replicated and its ids are
                 *global* slot ids (``gather_topk`` / near-hit payloads
                 work on the global view unchanged);
  fetch_best   — each shard contributes its owned rows' payload, combined
                 with one masked ``psum``;
  touch        — only the owning shard touches LRU/LFU counters;
  primary      — replicated per-batch lookup/hit counts are attributed on
                 shard 0 only, so a sum-reduce over the sharded
                 ``TenancyState`` counters is exact;
  insert_take  — round-robin routing by the cumulative rank of *masked-in*
                 rows (not the raw row index: a batch where only a few
                 rows miss must not systematically skew early shards),
                 offset by the replicated global insert clock;
  prepare/finalize_insert — each shard's local ring pointer is derived
                 from the replicated clock (shard ``s`` holds
                 ``ceil((n_inserts - s) / S)`` of the first ``n_inserts``
                 round-robin inserts), and after the write the clock
                 leaves are re-replicated: ``n_inserts`` advances by the
                 global masked count, ``ptr`` parks at 0.

Slot-id convention (shard-major): global slot ``g`` lives on shard
``g // local_capacity`` at local row ``g % local_capacity`` — which is
exactly the global row index of the sharded slab arrays, so every
global-view consumer (``gather_topk``, checkpointing, explain) indexes the
placed arrays directly.

Sharded state layout:
  * ``CacheState`` matrices/vectors split on the capacity axis; ``ptr`` /
    ``n_inserts`` replicated (the insert clock is global);
  * ``CacheStats`` / policy state / fusion weights replicated;
  * ``TenancyState`` leaves stacked per shard — global ``(S, T)``, local
    ``(T,)`` — each shard runs its own per-tenant rings over its local
    region slice; ``tenant_stats`` sum-reduces counters via
    ``TenancyState.reduced()``;
  * index state stacked on the leading axis — e.g. IVF centroids
    ``(S*C, d)`` and buckets ``(S*C, cap)`` of *local* slot ids — so each
    shard trains/probes its own IVF over its own rows. Any Index plugin
    whose state follows the leading-axis convention shards transparently;
    the old "leafless index only" restriction is gone.

All static shard math (shard counts, strides) is pure-Python int — no
device op is ever dispatched for a trace-time constant.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_nocheck
from repro.core import store
from repro.core.cache import SemanticCache, _LocalComm
from repro.core.runtime import CacheRuntime
from repro.core.types import LookupResult

Array = jax.Array


def shard_axes(mesh: Mesh, cache_axes: tuple[str, ...]) -> int:
    """Number of slab shards = product of the mesh axes the capacity axis is
    split over. Mesh axis sizes are static host ints, so this is a plain
    Python product — never a device op."""
    return math.prod(int(mesh.shape[a]) for a in cache_axes)


@dataclasses.dataclass(frozen=True)
class _MeshComm(_LocalComm):
    """Mesh specialization of the cache's cross-shard seam: the same
    ``SemanticCache`` method bodies run per shard inside ``shard_map``;
    these overrides splice collectives into the combine points."""

    axes: tuple[str, ...] = ()
    axis_sizes: tuple[int, ...] = ()
    local_capacity: int = 0

    @property
    def num_shards(self) -> int:  # type: ignore[override]
        return math.prod(self.axis_sizes)

    def shard_id(self) -> Array:
        """Row-major linear shard index over the cache axes (matches the
        order ``PartitionSpec((*axes,))`` assigns capacity blocks). Only the
        per-axis ``axis_index`` is traced; strides are Python ints."""
        sid: Any = 0
        for name, size in zip(self.axes, self.axis_sizes):
            sid = sid * size + jax.lax.axis_index(name)
        return sid

    # -- lookup seams ------------------------------------------------------
    def merge_topk(self, top_s: Array, top_i: Array) -> tuple[Array, Array]:
        k = top_i.shape[1]
        gid = jnp.where(top_i >= 0,
                        self.shard_id() * self.local_capacity + top_i, -1)
        s_all, i_all = top_s, gid
        for name in self.axes:
            s_all = jax.lax.all_gather(s_all, name, axis=1, tiled=True)
            i_all = jax.lax.all_gather(i_all, name, axis=1, tiled=True)
        merged_s, sel = jax.lax.top_k(s_all, k)          # (B, k) of (B, S*k)
        merged_i = jnp.take_along_axis(i_all, sel, axis=1)
        merged_i = jnp.where(merged_s > -jnp.inf, merged_i, -1)
        return merged_s, merged_i.astype(jnp.int32)

    def fetch_best(self, state, top0: Array) -> tuple[Array, Array, Array]:
        mine = (top0 >= 0) & (top0 // self.local_capacity == self.shard_id())
        lidx = jnp.where(mine, top0 % self.local_capacity, 0)
        payload = jnp.concatenate(
            [state.values[lidx].astype(jnp.int32),
             state.value_lens[lidx].astype(jnp.int32)[:, None],
             state.source_id[lidx].astype(jnp.int32)[:, None]], axis=1)
        payload = jnp.where(mine[:, None], payload, 0)
        payload = jax.lax.psum(payload, self.axes)       # one combine
        return payload[:, :-2], payload[:, -2], payload[:, -1]

    def touch(self, state, slot: Array, now: Array, hit: Array):
        mine = slot // self.local_capacity == self.shard_id()
        lidx = jnp.where(mine, slot % self.local_capacity, 0)
        return store.touch(state, lidx, now, hit & mine)

    def primary(self, counts: Array) -> Array:
        return jnp.where(self.shard_id() == 0, counts,
                         jnp.zeros_like(counts))

    # -- insert seams ------------------------------------------------------
    def insert_take(self, mask: Array, n_inserts: Array) -> Array:
        mi = mask.astype(jnp.int32)
        rank = jnp.cumsum(mi) - mi                   # rank among masked-in
        owner = (n_inserts + rank) % self.num_shards
        return mask & (owner == self.shard_id())

    def prepare_insert(self, state):
        # after N global round-robin inserts shard s has ceil((N - s) / S)
        s = self.num_shards
        fill = (state.n_inserts + (s - 1) - self.shard_id()) // s
        state = jax.tree_util.tree_map(lambda x: x, state)
        state.ptr = (fill % self.local_capacity).astype(jnp.int32)
        return state

    def finalize_insert(self, state, prev_n_inserts: Array, mask: Array):
        state = jax.tree_util.tree_map(lambda x: x, state)
        state.ptr = jnp.zeros((), dtype=jnp.int32)   # re-derived next insert
        state.n_inserts = (prev_n_inserts
                           + jnp.sum(mask).astype(jnp.int32))
        return state


@dataclasses.dataclass(frozen=True)
class DistributedCache:
    """Capacity-sharded ``SemanticCache`` with the same method surface.

    ``cache`` is the *global* single-device description (full capacity,
    global partition); the sharded step runs a derived shard-local cache
    (capacity / regions divided by the shard count, same index / policy /
    fusion plugins) under ``shard_map`` with a ``_MeshComm`` seam. Methods
    that never cross shards — ``expire``, ``gather_topk``,
    ``update_policy``, ``update_band``, ``_maybe_fuse`` — delegate to the
    global view directly (global slot ids ARE global row indices).

    Engine compatibility: ``config`` / ``partition`` / ``policy`` /
    ``index`` / ``fusion`` mirror the inner cache, and ``lookup`` /
    ``step`` / ``insert`` / ``refit`` take the same signatures, so
    ``CachedEngine`` and the async scheduler drive a mesh with zero
    call-site changes (DESIGN.md §19.4).
    """

    cache: SemanticCache
    mesh: Mesh
    cache_axes: tuple[str, ...] = ("data",)

    def __post_init__(self):
        object.__setattr__(self, "cache_axes", tuple(self.cache_axes))
        for a in self.cache_axes:
            if a not in self.mesh.shape:
                raise ValueError(f"mesh has no axis {a!r}: "
                                 f"{dict(self.mesh.shape)}")
        s = self.num_shards
        cfg = self.cache.config
        if cfg.capacity % s != 0:
            raise ValueError(f"capacity {cfg.capacity} not divisible by "
                             f"{s} shards")
        part = self.cache.partition
        local_part = None
        if part is not None:
            if any(sz % s for sz in part.sizes):
                raise ValueError(
                    f"per-tenant region sizes {part.sizes} must be "
                    f"multiples of the shard count {s} (size regions in "
                    f"shard-count multiples)")
            local_part = dataclasses.replace(
                part, starts=tuple(x // s for x in part.starts),
                sizes=tuple(x // s for x in part.sizes),
                capacity=cfg.capacity // s)
        local = SemanticCache(
            config=dataclasses.replace(cfg, capacity=cfg.capacity // s),
            index=self.cache.index, policy=self.cache.policy,
            partition=local_part, fusion=self.cache.fusion)
        comm = _MeshComm(
            axes=self.cache_axes,
            axis_sizes=tuple(int(self.mesh.shape[a])
                             for a in self.cache_axes),
            local_capacity=cfg.capacity // s)
        object.__setattr__(self, "local", local)
        object.__setattr__(self, "comm", comm)

    # -- engine-facing mirrors --------------------------------------------
    @property
    def config(self):
        return self.cache.config

    @property
    def partition(self):
        return self.cache.partition

    @property
    def policy(self):
        return self.cache.policy

    @property
    def index(self):
        return self.cache.index

    @property
    def fusion(self):
        return self.cache.fusion

    @property
    def num_shards(self) -> int:
        return shard_axes(self.mesh, self.cache_axes)

    @property
    def local_capacity(self) -> int:
        return self.cache.config.capacity // self.num_shards

    def shard_layout(self) -> dict:
        """JSON-able record of the sharded placement — written into
        checkpoint manifests and compared (or resharded against) on load."""
        return {
            "num_shards": self.num_shards,
            "cache_axes": list(self.cache_axes),
            "mesh_axes": [str(a) for a in self.mesh.axis_names],
            "mesh_shape": [int(self.mesh.shape[a])
                           for a in self.mesh.axis_names],
            "local_capacity": self.local_capacity,
        }

    # -- spec / placement helpers -----------------------------------------
    @property
    def _ax0(self):
        """The dim-0 PartitionSpec entry for capacity-sharded leaves."""
        return (self.cache_axes[0] if len(self.cache_axes) == 1
                else self.cache_axes)

    def _spec_sharded(self, x) -> P:
        """Leading axis split over the cache axes; scalars replicated."""
        if getattr(x, "ndim", 0) == 0:
            return P()
        return P(self._ax0, *([None] * (x.ndim - 1)))

    def _rt_specs(self, runtime: CacheRuntime) -> CacheRuntime:
        """Runtime-shaped pytree of PartitionSpecs: slab + index + tenancy
        leaves sharded on dim 0, stats / policy / fusion replicated."""
        tmap = jax.tree_util.tree_map
        rep = lambda x: P()  # noqa: E731
        return CacheRuntime(
            state=tmap(self._spec_sharded, runtime.state),
            stats=tmap(rep, runtime.stats),
            policy_state=tmap(rep, runtime.policy_state),
            index_state=tmap(self._spec_sharded, runtime.index_state),
            tenancy=tmap(self._spec_sharded, runtime.tenancy),
            fusion=tmap(rep, runtime.fusion),
        )

    def runtime_shardings(self, runtime: CacheRuntime) -> CacheRuntime:
        """NamedShardings mirroring ``_rt_specs`` (for device_put / jit)."""
        shard = lambda x: NamedSharding(  # noqa: E731
            self.mesh, self._spec_sharded(x))
        rep = lambda x: NamedSharding(self.mesh, P())  # noqa: E731
        tmap = jax.tree_util.tree_map
        return CacheRuntime(
            state=tmap(shard, runtime.state),
            stats=tmap(rep, runtime.stats),
            policy_state=tmap(rep, runtime.policy_state),
            index_state=tmap(shard, runtime.index_state),
            tenancy=tmap(shard, runtime.tenancy),
            fusion=tmap(rep, runtime.fusion),
        )

    def place(self, runtime: CacheRuntime) -> CacheRuntime:
        """device_put every leaf onto its mesh sharding."""
        return jax.tree_util.tree_map(
            jax.device_put, runtime, self.runtime_shardings(runtime))

    def init(self) -> CacheRuntime:
        """Fresh sharded runtime. Slab/stats/policy/fusion leaves come from
        the global init; per-shard leaf groups tile the *local* init along
        a new leading axis (index init is deterministic, so S tiled copies
        == S independent shard inits)."""
        g = self.cache.init()
        loc = self.local.init()
        s = self.num_shards
        tile = lambda x: (x if getattr(x, "ndim", 0) == 0  # noqa: E731
                          else jnp.concatenate([x] * s, axis=0))
        index_state = jax.tree_util.tree_map(tile, loc.index_state)
        tenancy = None
        if loc.tenancy is not None:
            tenancy = jax.tree_util.tree_map(
                lambda x: jnp.tile(x[None], (s,) + (1,) * x.ndim),
                loc.tenancy)
        return self.place(CacheRuntime(
            state=g.state, stats=g.stats, policy_state=g.policy_state,
            index_state=index_state, tenancy=tenancy, fusion=g.fusion))

    # -- global <-> shard-local views -------------------------------------
    def _to_local(self, rt: CacheRuntime) -> CacheRuntime:
        """Inside the shard body tenancy leaves arrive as (1, T) slices of
        the stacked (S, T) global; the local core wants (T,)."""
        if rt.tenancy is None:
            return rt
        return rt.replace(tenancy=jax.tree_util.tree_map(
            lambda x: x[0], rt.tenancy))

    def _from_local(self, rt: CacheRuntime) -> CacheRuntime:
        if rt.tenancy is None:
            return rt
        return rt.replace(tenancy=jax.tree_util.tree_map(
            lambda x: x[None], rt.tenancy))

    def _shard_call(self, body, operands: dict, operand_specs: dict,
                    out_specs):
        """Run ``body(operands)`` under shard_map. Optional call arguments
        are simply absent from the dict, so one wrapper serves every
        combination without None-leaf spec gymnastics; replication checking
        is off (the seam maintains replication invariants manually)."""
        return shard_map_nocheck(body, self.mesh, (operand_specs,),
                                 out_specs)(operands)

    # -- sharded methods (same signatures as SemanticCache) ----------------
    def lookup(self, runtime: CacheRuntime, queries: Array,
               now: Array | float, *, update_counters: bool = True,
               tenant_id: Array | None = None, window: Array | None = None,
               window_len: Array | None = None
               ) -> tuple[LookupResult, CacheRuntime]:
        rt_spec = self._rt_specs(runtime)
        ops = {"runtime": runtime, "queries": queries,
               "now": jnp.asarray(now, dtype=jnp.float32)}
        specs = {"runtime": rt_spec, "queries": P(), "now": P()}
        for name, v in (("tenant_id", tenant_id), ("window", window),
                        ("window_len", window_len)):
            if v is not None:
                ops[name], specs[name] = v, P()

        def body(o):
            rt = self._to_local(o["runtime"])
            res, rt = self.local.lookup(
                rt, o["queries"], o["now"],
                update_counters=update_counters,
                tenant_id=o.get("tenant_id"), window=o.get("window"),
                window_len=o.get("window_len"), comm=self.comm)
            return res, self._from_local(rt)

        return self._shard_call(body, ops, specs, (P(), rt_spec))

    def step(self, runtime: CacheRuntime, queries: Array,
             miss_values: Array, miss_value_lens: Array,
             now: Array | float, *, source_id: Array | None = None,
             peeked: LookupResult | None = None,
             valid: Array | None = None, tenant_id: Array | None = None,
             window: Array | None = None, window_len: Array | None = None
             ) -> tuple[LookupResult, CacheRuntime]:
        """The ONE fused step, compiled for this mesh: per-shard lookup →
        merged decide → per-tenant overrides → routed masked insert →
        stats/tenancy scatter, all inside one shard_map (DESIGN.md §19.3)."""
        rt_spec = self._rt_specs(runtime)
        ops = {"runtime": runtime, "queries": queries,
               "miss_values": miss_values,
               "miss_value_lens": miss_value_lens,
               "now": jnp.asarray(now, dtype=jnp.float32)}
        specs = {k: P() for k in ops}
        specs["runtime"] = rt_spec
        for name, v in (("source_id", source_id), ("peeked", peeked),
                        ("valid", valid), ("tenant_id", tenant_id),
                        ("window", window), ("window_len", window_len)):
            if v is not None:
                ops[name], specs[name] = v, P()

        def body(o):
            rt = self._to_local(o["runtime"])
            res, rt = self.local.step(
                rt, o["queries"], o["miss_values"], o["miss_value_lens"],
                o["now"], source_id=o.get("source_id"),
                peeked=o.get("peeked"), valid=o.get("valid"),
                tenant_id=o.get("tenant_id"), window=o.get("window"),
                window_len=o.get("window_len"), comm=self.comm)
            return res, self._from_local(rt)

        return self._shard_call(body, ops, specs, (P(), rt_spec))

    def insert(self, runtime: CacheRuntime, queries: Array, values: Array,
               value_lens: Array, now: Array | float, *,
               source_id: Array | None = None, mask: Array | None = None,
               tenant_id: Array | None = None) -> CacheRuntime:
        rt_spec = self._rt_specs(runtime)
        ops = {"runtime": runtime, "queries": queries, "values": values,
               "value_lens": value_lens,
               "now": jnp.asarray(now, dtype=jnp.float32)}
        specs = {k: P() for k in ops}
        specs["runtime"] = rt_spec
        for name, v in (("source_id", source_id), ("mask", mask),
                        ("tenant_id", tenant_id)):
            if v is not None:
                ops[name], specs[name] = v, P()

        def body(o):
            rt = self._to_local(o["runtime"])
            rt = self.local.insert(
                rt, o["queries"], o["values"], o["value_lens"], o["now"],
                source_id=o.get("source_id"), mask=o.get("mask"),
                tenant_id=o.get("tenant_id"), comm=self.comm)
            return self._from_local(rt)

        return self._shard_call(body, ops, specs, rt_spec)

    def refit(self, runtime: CacheRuntime, now: Array | float, rng: Array
              ) -> CacheRuntime:
        """Per-shard index rebuild over each shard's own rows; the rng is
        folded with the shard id so shards train independent structures."""
        rt_spec = self._rt_specs(runtime)
        ops = {"runtime": runtime,
               "now": jnp.asarray(now, dtype=jnp.float32), "rng": rng}
        specs = {"runtime": rt_spec, "now": P(), "rng": P()}

        def body(o):
            rt = self._to_local(o["runtime"])
            rng_s = jax.random.fold_in(o["rng"], self.comm.shard_id())
            return self._from_local(self.local.refit(rt, o["now"], rng_s))

        return self._shard_call(body, ops, specs, rt_spec)

    # -- shard-oblivious methods: delegate to the global view --------------
    def expire(self, runtime: CacheRuntime, now: Array | float):
        return self.cache.expire(runtime, now)

    def gather_topk(self, runtime: CacheRuntime, result: LookupResult):
        # merged topk_index entries are global slot ids == global row
        # indices (shard-major), so the global gather is already correct
        return self.cache.gather_topk(runtime, result)

    def update_policy(self, runtime: CacheRuntime, **kw):
        return self.cache.update_policy(runtime, **kw)

    def update_band(self, runtime: CacheRuntime, **kw):
        return self.cache.update_band(runtime, **kw)

    def _maybe_fuse(self, runtime: CacheRuntime, queries: Array,
                    window, window_len):
        return self.cache._maybe_fuse(runtime, queries, window, window_len)

    # -- PR-1 compat shim ---------------------------------------------------
    def make_lookup_insert(self):
        """Legacy fused lookup+insert entry point, now a thin shim over the
        unified ``step`` — it compiles for ANY index plugin (the old
        ExactIndex-only restriction is gone with the fork it guarded)."""
        @functools.partial(jax.jit, donate_argnums=(0,))
        def fused(runtime, queries, miss_values, miss_value_lens,
                  source_id, now):
            result, runtime = self.step(
                runtime, queries, miss_values, miss_value_lens, now,
                source_id=source_id)
            return runtime, (result.index, result.score, result.hit,
                             result.values, result.value_lens,
                             result.source_id)
        return fused
