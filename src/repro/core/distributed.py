"""Distributed semantic cache — paper §2.10 "Distributed Caching" / §5.4.

Sharding scheme (DESIGN.md §5):
  * the slab shards its *capacity* dimension over the ``data`` mesh axis —
    each data-parallel group owns ``capacity/shards`` entries (a Redis
    Cluster hash-slot analogue, but with deterministic round-robin routing);
  * queries are replicated across cache shards for lookup (they are a few
    hundred floats; the slab is the big operand);
  * lookup = per-shard fused top-k, then a global argmax combine with
    ``jax.lax.pmax`` over packed (score, global_slot) pairs — one small
    all-reduce instead of gathering any slab data;
  * the winning entry's value tokens are fetched with a masked ``psum``
    (owner contributes, everyone else contributes zeros);
  * inserts route round-robin by global insert clock — shard
    ``(n_inserts + row) % num_shards`` takes the row, keeping shards
    balanced without coordination;
  * across pods the cache shards over ``data`` within each pod and the
    ``pod`` axis joins the same combine, so a response cached in pod 0
    serves a query landing on pod 1.

State is one ``CacheRuntime`` (DESIGN.md §2): the slab shards over the
cache axes; stats, policy state and index state are replicated. The fused
``make_lookup_insert`` step is ``runtime -> runtime`` like the local
``SemanticCache.step``. Sharding a *stateful* index (IVF bucket tables hold
shard-local slot ids) is future work — the step requires an index whose
state pytree is leafless (e.g. ``ExactIndex``) and says so at build time.

Everything is ``shard_map`` + ``jax.lax`` collectives — no host round trips.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_nocheck
from repro.core import store
from repro.core.cache import SemanticCache
from repro.core.runtime import CacheRuntime
from repro.core.types import CacheConfig, CacheState, CacheStats

Array = jax.Array


def shard_axes(mesh: Mesh, cache_axes: Sequence[str]) -> int:
    return int(jnp.prod(jnp.asarray([mesh.shape[a] for a in cache_axes])))


def cache_sharding(mesh: Mesh, cache_axes: Sequence[str]) -> dict:
    """NamedShardings for a CacheState whose capacity dim shards over axes."""
    row = NamedSharding(mesh, P(tuple(cache_axes)))
    mat = NamedSharding(mesh, P(tuple(cache_axes), None))
    rep = NamedSharding(mesh, P())
    return dict(keys=mat, values=mat, value_lens=row, expiry=row, valid=row,
                freq=row, last_used=row, inserted_at=row, source_id=row,
                ptr=rep, n_inserts=rep)


def place_cache_state(state: CacheState, mesh: Mesh, cache_axes: Sequence[str]
                      ) -> CacheState:
    sh = cache_sharding(mesh, cache_axes)
    return CacheState(**{
        f.name: jax.device_put(getattr(state, f.name), sh[f.name])
        for f in dataclasses.fields(CacheState)})


@dataclasses.dataclass(frozen=True)
class DistributedCache:
    """Sharded wrapper around SemanticCache. ``cache_axes`` shard capacity."""

    cache: SemanticCache
    mesh: Mesh
    cache_axes: tuple[str, ...] = ("data",)

    @property
    def num_shards(self) -> int:
        n = 1
        for a in self.cache_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def local_config(self) -> CacheConfig:
        cfg = self.cache.config
        return dataclasses.replace(cfg, capacity=cfg.capacity // self.num_shards)

    def init(self) -> CacheRuntime:
        """Full runtime: slab sharded over ``cache_axes``, rest replicated."""
        runtime = self.cache.init()
        rep = NamedSharding(self.mesh, P())
        return runtime.replace(
            state=place_cache_state(runtime.state, self.mesh, self.cache_axes),
            stats=jax.device_put(runtime.stats, rep),
            policy_state=jax.device_put(runtime.policy_state, rep),
        )

    # ------------------------------------------------------------------ #
    def _shard_id(self):
        shard_id = jnp.zeros((), jnp.int32)
        mult = 1
        for a in reversed(self.cache_axes):
            shard_id = shard_id + jax.lax.axis_index(a) * mult
            mult *= self.mesh.shape[a]  # static; axis_size needs newer jax
        return shard_id

    def _local_lookup(self, state: CacheState, stats: CacheStats,
                      pstate: Array, queries: Array, now: Array):
        """Runs per-shard inside shard_map. Returns packed global winners."""
        axes = self.cache_axes
        shard_id = self._shard_id()
        local_cap = state.keys.shape[0]
        b = queries.shape[0]

        alive = store.alive_mask(state, now)
        istate = self.cache.index.init(self.local_config)  # leafless (checked)
        top_s, top_i = self.cache.index.search(
            istate, queries, state.keys, alive)
        best_s, best_i = top_s[:, 0], jnp.maximum(top_i[:, 0], 0)
        best_s = jnp.where(top_i[:, 0] >= 0, best_s, -jnp.inf)
        global_slot = shard_id * local_cap + best_i

        # pack (score, slot): lexicographic max == max score, tie -> max slot
        packed = jnp.stack([best_s, global_slot.astype(jnp.float32)], axis=-1)

        def combine(p):
            for a in axes:
                # pmax on score; to carry the winning slot, use the classic
                # two-field trick: compare scores, select slot of the winner.
                s = jax.lax.pmax(p[..., 0], a)
                winner = p[..., 0] >= s - 0.0  # == max on the winning shard
                slot = jnp.where(winner, p[..., 1], -1.0)
                slot = jax.lax.pmax(slot, a)
                p = jnp.stack([s, slot], axis=-1)
            return p

        packed = combine(packed)
        g_score, g_slot = packed[..., 0], packed[..., 1].astype(jnp.int32)

        # fetch winning values: owner shard contributes, psum broadcasts
        owner = g_slot // local_cap
        local_idx = jnp.where(owner == shard_id, g_slot % local_cap, 0)
        mine = (owner == shard_id) & (g_score > -jnp.inf)
        vals = jnp.where(mine[:, None], state.values[local_idx], 0)
        vlen = jnp.where(mine, state.value_lens[local_idx], 0)
        src = jnp.where(mine, state.source_id[local_idx], 0)
        # fused fetch: one psum of the concatenated (values | len | src)
        # payload instead of three collectives (§Perf iteration 3.2)
        packed = jnp.concatenate(
            [vals, vlen[:, None], src[:, None]], axis=1)
        for a in axes:
            packed = jax.lax.psum(packed, a)
        vals = packed[:, :-2]
        vlen = packed[:, -2]
        src = packed[:, -1]

        hit, pstate = self.cache.policy.decide(g_score, pstate)
        hit = hit & (g_score > -jnp.inf)

        # touch local LRU/LFU where this shard owns the hit
        state = store.touch(state, local_idx, now, hit & mine)
        stats = stats.record_lookups(b, jnp.sum(hit).astype(jnp.int32))
        return state, stats, pstate, (g_slot, g_score, hit, vals, vlen, src)

    def _local_insert(self, state: CacheState, stats: CacheStats, queries,
                      values, value_lens, source_id, mask, now):
        shard_id = self._shard_id()
        nshards = self.num_shards
        local_cap = state.keys.shape[0]
        # round-robin routing by (global insert clock + rank among *written*
        # rows) — masked-out rows must not consume round-robin positions
        mi = mask.astype(jnp.int32)
        rank = jnp.cumsum(mi) - mi
        owner = (state.n_inserts + rank) % nshards
        take = mask & (owner == shard_id)
        # Per-shard ring position is a pure function of the *replicated*
        # global clock: shard s has received ceil((n_inserts - s) / S)
        # rows so far. Deriving it here (instead of trusting state.ptr,
        # which would advance by a shard-dependent sum(take) and then be
        # forced through a replicated out-spec) keeps every shard's ring
        # consistent for any miss pattern.
        state = jax.tree_util.tree_map(lambda x: x, state)  # shallow copy
        state.ptr = ((state.n_inserts + nshards - 1 - shard_id)
                     // nshards) % local_cap
        new_state, _slots = store.insert(
            self.local_config, state, queries, values,
            value_lens, now, source_id=source_id, mask=take)
        # keep the *global* insert clock in sync on every shard; park ptr on
        # a replicated constant (it is recomputed from n_inserts on entry)
        n_global = state.n_inserts + jnp.sum(mask).astype(jnp.int32)
        new_state.n_inserts = n_global
        new_state.ptr = jnp.zeros_like(new_state.ptr)
        stats = dataclasses.replace(
            stats, inserts=stats.inserts + jnp.sum(mask).astype(jnp.int32))
        return new_state, stats

    # ------------------------------------------------------------------ #
    def make_lookup_insert(self):
        """Build the jit-able fused sharded step (runtime donated).

        Signature mirrors ``SemanticCache.step``::

            runtime, (slot, score, hit, values, value_lens, source_id) =
                step(runtime, queries, miss_values, miss_value_lens,
                     source_id, now)
        """
        if jax.tree_util.tree_leaves(self.cache.index.init(self.local_config)):
            raise NotImplementedError(
                "DistributedCache requires an index with leafless state "
                "(e.g. ExactIndex): sharding stateful index pytrees (IVF "
                "bucket tables hold shard-local slot ids) is future work")
        axes = self.cache_axes
        mesh = self.mesh
        row = P(tuple(axes))
        mat = P(tuple(axes), None)
        state_spec = CacheState(
            keys=mat, values=mat, value_lens=row, expiry=row, valid=row,
            freq=row, last_used=row, inserted_at=row, source_id=row,
            ptr=P(), n_inserts=P())
        stats_spec = CacheStats(lookups=P(), hits=P(), misses=P(),
                                expired_evictions=P(), inserts=P())
        rep = P()

        def local_step(state, stats, pstate, queries, miss_values,
                       miss_value_lens, source_id, now):
            state, stats, pstate, out = self._local_lookup(
                state, stats, pstate, queries, now)
            (slot, score, hit, vals, vlen, src) = out
            state, stats = self._local_insert(
                state, stats, queries, miss_values, miss_value_lens,
                source_id, ~hit, now)
            return state, stats, pstate, (slot, score, hit, vals, vlen, src)

        sharded = shard_map_nocheck(
            local_step, mesh,
            in_specs=(state_spec, stats_spec, rep, rep, rep, rep, rep, rep),
            out_specs=(state_spec, stats_spec, rep,
                       (rep, rep, rep, rep, rep, rep)))

        def step(runtime: CacheRuntime, queries, miss_values, miss_value_lens,
                 source_id, now):
            state, stats, pstate, out = sharded(
                runtime.state, runtime.stats, runtime.policy_state, queries,
                miss_values, miss_value_lens, source_id, now)
            return runtime.replace(state=state, stats=stats,
                                   policy_state=pstate), out

        return jax.jit(step, donate_argnums=(0,))
