"""CacheRuntime — the single pytree that holds *all* semantic-cache state
(DESIGN.md §2), plus the typed plugin seams (§8, §10).

Before this module existed, the cache's state was spread across four
separately-threaded objects: a slab ``CacheState``, a ``CacheStats`` counter
bundle, a raw ``policy_state`` array and an optional out-of-band
``IVFState``. Every caller (engine, distributed step, checkpointing) had to
know which pieces its index/policy combination needed, which forced
``isinstance`` branches and silently dropped state on checkpoint restore.

``CacheRuntime`` bundles the four into one registered-dataclass pytree so

* the whole serve step is a pure function ``runtime -> runtime`` that jits,
  donates and shards as a unit;
* checkpointing the cache is ``save(runtime)`` — adaptive-threshold and
  ANN-index state survive restarts for free;
* index and policy implementations are interchangeable behind the
  ``Index`` / ``Policy`` protocols with *uniform* signatures: a stateless
  index (ExactIndex) simply carries an empty state pytree.

The protocols are ``typing.Protocol``s rather than ABCs: plugins need no
import of this module to conform (structural typing), which keeps kernels
and third-party index structures decoupled from core.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

from repro.core.types import CacheConfig, CacheState, CacheStats

Array = jax.Array


@runtime_checkable
class Index(Protocol):
    """ANN index plugin seam (DESIGN.md §8).

    An index is a *static* (hashable, frozen-dataclass) strategy object; all
    its mutable state lives in an ``IndexState`` pytree threaded through the
    runtime. Implementations: ``ExactIndex`` (empty state), ``IVFIndex``
    (centroids + bucket table); future: HNSW.
    """

    def init(self, config: CacheConfig) -> Any:
        """Fresh index state with static shapes derived from ``config``."""
        ...

    def search(self, istate: Any, queries: Array, keys: Array, alive: Array,
               *, interval: tuple[Array, Array] | None = None
               ) -> tuple[Array, Array]:
        """(B,d) queries vs the slab -> (scores (B,k), slot ids (B,k)).

        ``alive`` is (N,) shared across the batch, or (B, N) for general
        per-row visibility. ``interval`` = per-row ``(starts, sizes)``
        operands restricting each row to a contiguous slot range on top of
        a shared (N,) ``alive`` — how the tenancy layer masks each query to
        its own slab region with O(B) operands instead of a (B, N) mask
        (§13.2, §14). Rows with no visible live slot must return exactly
        (-inf, -1)."""
        ...

    def absorb(self, istate: Any, slots: Array, keys: Array, mask: Array) -> Any:
        """Incrementally index freshly inserted slab rows (no rebuild)."""
        ...

    def refit(self, istate: Any, keys: Array, alive: Array, rng: Array) -> Any:
        """Full periodic rebuild (the paper's §2.4 HNSW rebalancing)."""
        ...


@runtime_checkable
class Policy(Protocol):
    """Hit-threshold policy plugin seam (DESIGN.md §10)."""

    def init_state(self) -> Array:
        ...

    def decide(self, scores: Array, state: Array) -> tuple[Array, Array]:
        """Best-match scores -> (hit mask, updated policy state)."""
        ...

    def update(self, state: Array, *, was_positive: Array, was_hit: Array
               ) -> Array:
        """Judged-outcome feedback (paper §2.10 control loop)."""
        ...


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheRuntime:
    """Everything a semantic cache mutates, as one jit-able pytree.

    Leaves:
      state        — the slab (keys/values/TTL/LRU bookkeeping),
      stats        — running hit/miss/insert counters,
      policy_state — threshold-policy state (e.g. adaptive (thr, ema) pair),
      index_state  — ANN-index state (empty for ExactIndex, IVFState for IVF),
      tenancy      — per-tenant ring pointers + accounting (``TenancyState``,
                     DESIGN.md §13.2); ``None`` for a single-tenant cache,
                     which keeps the treedef — and thus every compiled
                     program — identical to the pre-tenancy layout.
      fusion       — context-fusion weights (``FusionState``, DESIGN.md
                     §16.2) pooling a session's turn window into the lookup
                     key; ``None`` for a single-turn cache — the same
                     None-keeps-the-treedef contract as ``tenancy``, so
                     pre-session checkpoints and compiled programs are
                     untouched.
    """

    state: CacheState
    stats: CacheStats
    policy_state: Array
    index_state: Any
    tenancy: Any = None
    fusion: Any = None

    def replace(self, **kw) -> "CacheRuntime":
        return dataclasses.replace(self, **kw)
