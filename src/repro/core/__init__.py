"""GPT Semantic Cache — the paper's contribution as composable JAX.

Public API:
  CacheConfig, CacheState, CacheStats, LookupResult  (types)
  CacheRuntime, Index, Policy                         (runtime pytree + seams)
  SemanticCache                                       (orchestration)
  ExactIndex, IVFIndex, HNSWIndex                     (ANN indexes)
  FixedThreshold, PerCategoryThreshold, AdaptiveThreshold (policies)
  DistributedCache                                    (sharded cache)
"""
from repro.core.types import (CacheConfig, CacheState, CacheStats,
                              LookupResult, init_cache_state)
from repro.core.runtime import CacheRuntime, Index, Policy
from repro.core.cache import SemanticCache
from repro.core.index import ExactIndex, ExactState, IVFIndex, IVFState
from repro.core.hnsw import HNSWIndex
from repro.core.policy import (AdaptiveThreshold, FixedThreshold,
                               PerCategoryThreshold, make_policy)
from repro.core.distributed import DistributedCache

__all__ = [
    "CacheConfig", "CacheState", "CacheStats", "LookupResult",
    "init_cache_state", "CacheRuntime", "Index", "Policy", "SemanticCache",
    "ExactIndex", "ExactState", "IVFIndex", "IVFState", "HNSWIndex",
    "AdaptiveThreshold", "FixedThreshold", "PerCategoryThreshold",
    "make_policy", "DistributedCache",
]
