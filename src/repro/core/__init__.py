"""GPT Semantic Cache — the paper's contribution as composable JAX.

Public API:
  CacheConfig, CacheState, CacheStats, LookupResult  (types)
  SemanticCache                                       (orchestration)
  ExactIndex, IVFIndex, HNSWIndex                     (ANN indexes)
  FixedThreshold, PerCategoryThreshold, AdaptiveThreshold (policies)
  DistributedCache                                    (sharded cache)
"""
from repro.core.types import (CacheConfig, CacheState, CacheStats,
                              LookupResult, init_cache_state)
from repro.core.cache import SemanticCache
from repro.core.index import ExactIndex, IVFIndex, IVFState
from repro.core.hnsw import HNSWIndex
from repro.core.policy import (AdaptiveThreshold, FixedThreshold,
                               PerCategoryThreshold, make_policy)
from repro.core.distributed import DistributedCache

__all__ = [
    "CacheConfig", "CacheState", "CacheStats", "LookupResult",
    "init_cache_state", "SemanticCache", "ExactIndex", "IVFIndex", "IVFState",
    "HNSWIndex", "AdaptiveThreshold", "FixedThreshold", "PerCategoryThreshold",
    "make_policy", "DistributedCache",
]
