"""Hit-threshold policies (paper §2.6, §5.3; adaptive = paper §2.10 future work).

The paper uses a fixed cosine threshold of 0.8, selected by sweeping
0.6–0.9 in 0.05 steps (§5.3). We implement that fixed policy as the
faithful baseline, plus two extensions the paper names as future work:

  * per-category thresholds — "Customer Shopping QA" hits only 61.6% at a
    global 0.8 because its queries are semantically broader (§5.2); a
    category-specific threshold recovers hits without hurting precision.
  * adaptive thresholding — a control loop that nudges the threshold to
    track a target precision using observed positive-hit feedback
    (the paper's judge signal), i.e. threshold ← threshold + lr·(target − precision).

All policies are functional: ``decide(scores, state) -> (hit_mask, state)``
and conform to the ``repro.core.runtime.Policy`` protocol (uniform
``init_state`` / ``decide`` / ``update`` — DESIGN.md §10), so the engine and
distributed step never branch on the policy type.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FixedThreshold:
    """Paper-faithful: hit iff cosine >= threshold (default 0.8)."""

    threshold: float = 0.8

    def init_state(self) -> Array:
        return jnp.float32(self.threshold)

    def decide(self, scores: Array, state: Array) -> tuple[Array, Array]:
        return scores >= state, state

    def update(self, state: Array, *, was_positive: Array, was_hit: Array) -> Array:
        return state  # static


@dataclasses.dataclass(frozen=True)
class PerCategoryThreshold:
    """Category-indexed thresholds; categories supplied per query."""

    thresholds: tuple[float, ...]

    def init_state(self) -> Array:
        return jnp.asarray(self.thresholds, dtype=jnp.float32)

    def decide(self, scores: Array, state: Array, category: Array | None = None
               ) -> tuple[Array, Array]:
        if category is None:
            # The uniform Policy-protocol call cannot supply per-query
            # categories; failing loudly beats silently judging every query
            # at one threshold.
            raise ValueError(
                "PerCategoryThreshold needs per-query categories; the "
                "uniform SemanticCache path does not thread them — call "
                "decide(scores, state, category) directly, or use "
                "FixedThreshold/AdaptiveThreshold with SemanticCache")
        return scores >= state[category], state

    def update(self, state: Array, *, was_positive: Array, was_hit: Array) -> Array:
        return state  # static


@dataclasses.dataclass(frozen=True)
class AdaptiveThreshold:
    """Precision-tracking controller (beyond-paper; paper §2.10 names it).

    State is (threshold, ema_precision). After each judged hit we update an
    EMA of precision and step the threshold toward the precision target:
    too many false hits -> raise threshold; precision above target with
    headroom -> lower it to harvest more hits. Bounds keep it in the
    paper's swept range [0.6, 0.95].
    """

    init: float = 0.8
    target_precision: float = 0.97
    lr: float = 0.02
    ema: float = 0.9
    lo: float = 0.6
    hi: float = 0.95

    def init_state(self) -> Array:
        return jnp.asarray([self.init, self.target_precision], dtype=jnp.float32)

    def decide(self, scores: Array, state: Array) -> tuple[Array, Array]:
        return scores >= state[0], state

    def update(self, state: Array, *, was_positive: Array, was_hit: Array) -> Array:
        """Feed back judged outcomes for a batch. Shapes: (B,) bool each."""
        thr, prec = state[0], state[1]
        n_hit = jnp.sum(was_hit.astype(jnp.float32))
        batch_prec = jnp.where(
            n_hit > 0,
            jnp.sum((was_positive & was_hit).astype(jnp.float32)) / jnp.maximum(n_hit, 1.0),
            prec,  # no hits -> no evidence
        )
        prec = self.ema * prec + (1.0 - self.ema) * batch_prec
        step = self.lr * (self.target_precision - prec)
        thr = jnp.clip(thr + step, self.lo, self.hi)
        return jnp.stack([thr, prec])


def make_policy(kind: str, **kw):
    if kind == "fixed":
        return FixedThreshold(**kw)
    if kind == "per_category":
        return PerCategoryThreshold(**kw)
    if kind == "adaptive":
        return AdaptiveThreshold(**kw)
    raise ValueError(f"unknown policy {kind!r}")
