"""Cosine similarity and masked top-k — the scoring primitive (paper §2.6).

``cosine_similarity(u, v) = u·v / (|u||v|)``. Stored keys are L2-normalized
at insert time, so scoring a normalized query against the slab is a single
``(B, d) @ (d, N)`` matmul — this is the MXU-friendly reformulation of the
paper's per-pair cosine (see DESIGN.md §3). The Pallas kernel in
``repro.kernels.cosine_topk`` implements the same contract with explicit
VMEM blocking; this module is the pure-jnp reference used on CPU and as the
kernel oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = jnp.float32(-jnp.inf)


def l2_normalize(x: Array, axis: int = -1, eps: float = 1e-12) -> Array:
    """L2-normalize along ``axis`` (zero vectors map to zero)."""
    norm = jnp.linalg.norm(x, axis=axis, keepdims=True)
    return x / jnp.maximum(norm, eps)


def cosine_similarity(u: Array, v: Array, eps: float = 1e-12) -> Array:
    """Elementwise cosine similarity along the last axis (paper eq. in §2.6)."""
    un = jnp.linalg.norm(u, axis=-1)
    vn = jnp.linalg.norm(v, axis=-1)
    dot = jnp.sum(u * v, axis=-1)
    return dot / jnp.maximum(un * vn, eps)


def cosine_scores(queries: Array, keys: Array, valid: Array | None = None,
                  *, assume_normalized: bool = True) -> Array:
    """Batched scores: (B, d) x (N, d) -> (B, N); invalid slots get -inf.

    Args:
      queries: (B, d) query embeddings.
      keys: (N, d) slab keys.
      valid: (N,) bool slot-aliveness mask (validity ∧ not-expired), or
        (B, N) bool for per-row visibility — the multi-tenant path masks
        each query to its own slab region (DESIGN.md §13.2).
      assume_normalized: skip re-normalization (keys are normalized at insert).
    """
    if keys.dtype == jnp.int8:
        keys = keys.astype(jnp.float32) / 127.0
    if not assume_normalized:
        queries = l2_normalize(queries)
        keys = l2_normalize(keys)
    scores = jnp.einsum(
        "bd,nd->bn", queries, keys, preferred_element_type=jnp.float32
    )
    if valid is not None:
        mask = valid if valid.ndim == 2 else valid[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    return scores


def interval_visibility(alive: Array, starts: Array, sizes: Array) -> Array:
    """Expand per-row interval operands into a dense (B, N) visibility mask:
    row ``b`` sees the alive slots in ``[starts[b], starts[b] + sizes[b])``.
    ``alive`` is (N,) shared or already-per-row (B, N).

    This is the jnp-path materialization of what the interval-masked Pallas
    kernel builds from iota in VMEM (DESIGN.md §14) — on CPU the (B, N)
    bool is cheap; on TPU the kernel avoids it entirely.
    """
    cols = jnp.arange(alive.shape[-1], dtype=jnp.int32)[None, :]
    inside = (cols >= starts[:, None]) & (cols < (starts + sizes)[:, None])
    return (alive if alive.ndim == 2 else alive[None, :]) & inside


def masked_topk(scores: Array, k: int) -> tuple[Array, Array]:
    """Top-k over the last axis. Returns (values (..., k), indices (..., k))."""
    k = min(k, scores.shape[-1])
    return jax.lax.top_k(scores, k)


def best_match(scores: Array) -> tuple[Array, Array]:
    """Argmax + max over the last axis: (B, N) -> ((B,), (B,))."""
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    val = jnp.max(scores, axis=-1)
    return idx, val
