"""Config registry: ``--arch <id>`` resolves here.

The ten assigned architectures plus the paper's own components (the
MiniLM-class embedding encoder and the semantic-cache config).
"""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, pad_vocab

from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.grok_1_314b import CONFIG as GROK_1_314B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK_400B
from repro.configs.deepseek_7b import CONFIG as DEEPSEEK_7B
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.llama3_405b import CONFIG as LLAMA3_405B
from repro.configs.hymba_1p5b import CONFIG as HYMBA_1P5B
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.qwen2_vl_2b import CONFIG as QWEN2_VL_2B

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        MINITRON_8B, GROK_1_314B, LLAMA4_MAVERICK_400B, DEEPSEEK_7B, YI_6B,
        LLAMA3_405B, HYMBA_1P5B, MUSICGEN_LARGE, MAMBA2_130M, QWEN2_VL_2B,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(
            f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


__all__ = ["ARCHITECTURES", "INPUT_SHAPES", "ModelConfig", "InputShape",
           "get_arch", "get_shape", "pad_vocab"]
