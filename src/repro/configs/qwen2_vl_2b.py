"""qwen2-vl-2b — VLM backbone with M-RoPE, dynamic resolution
[arXiv:2409.12191]. Backbone only: the ViT encoder + projector is a stub
supplying ``n_prefix`` patch embeddings with (t, h, w) M-RoPE grid
positions; we implement the language decoder that consumes them."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),
    n_prefix=256,          # stub ViT patch embeddings (16x16 grid)
    rope_theta=1000000.0,
    source="Qwen2-VL-2B M-RoPE [arXiv:2409.12191]",
)
