"""yi-6b — llama-arch dense with aggressive GQA (kv=4) [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    source="Yi-6B GQA [arXiv:2403.04652]",
)
