"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    moe_topk=2,
    moe_interleave=1,
    source="Grok-1 8e top-2 MoE [hf:xai-org/grok-1]",
)
