"""llama4-maverick-400b-a17b — 128-expert top-1 MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family].

moe_interleave=2 (MoE on every second layer) matches the published
active-parameter count (~17B active / ~400B total).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    moe_topk=1,
    moe_interleave=2,
    source="Llama-4 Maverick MoE [hf:meta-llama/Llama-4-Scout-17B-16E]",
)
