"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    source="Mamba-2 130M SSD [arXiv:2405.21060]",
)
