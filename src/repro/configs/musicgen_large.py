"""musicgen-large — decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284]. Backbone only: the conv/codec frontend is a stub that
supplies conditioning frame embeddings (``n_prefix``); the decoder models
4 EnCodec codebooks with summed embeddings + per-codebook heads (delay
pattern handled in the data pipeline)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    n_codebooks=4,
    n_prefix=64,          # stub conditioning embeddings (T5-style prefix)
    vocab_pad_multiple=128,
    source="MusicGen-large decoder [arXiv:2306.05284]",
)
