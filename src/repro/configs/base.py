"""Architecture + input-shape config system.

One ``ModelConfig`` covers all six assigned arch families (dense / moe /
ssm / hybrid / audio / vlm); per-arch files under ``repro/configs/``
instantiate it with the exact assigned hyperparameters. ``reduced()``
derives the CPU smoke-test variant (<=2 layers, d_model<=512, <=4 experts)
of the same family.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


def pad_vocab(vocab: int, multiple: int = 512) -> int:
    """Megatron-style vocab padding so the embedding shards over `model`."""
    return int(math.ceil(vocab / multiple) * multiple)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attn-free ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    source: str = ""               # citation (paper/model card)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    moe_topk: int = 0
    moe_interleave: int = 1        # MoE every k-th layer (llama4: 2)
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (hymba) ------------------------------------------------------
    n_meta_tokens: int = 0
    global_attn_every: int = 0     # hybrid: full-attn layer period (else SWA)

    # --- positions -----------------------------------------------------------
    rope_theta: float = 10000.0
    mrope: bool = False            # qwen2-vl M-RoPE
    mrope_sections: tuple[int, int, int] = (16, 24, 24)

    # --- modality frontend stubs (audio/vlm) ---------------------------------
    n_prefix: int = 0              # frame/patch embeddings prepended (stub)
    n_codebooks: int = 1           # musicgen EnCodec codebooks

    # --- attention policy -----------------------------------------------------
    sliding_window: Optional[int] = None    # if set: SWA everywhere
    long_context_window: int = 8192         # window used for long_500k variant

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 512

    def __post_init__(self):
        if self.arch_type not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown arch_type {self.arch_type!r}")
        if self.arch_type == "moe" and (self.n_experts <= 0 or self.moe_topk <= 0):
            raise ValueError("moe arch needs n_experts and moe_topk")
        if self.arch_type in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError(f"{self.arch_type} arch needs ssm_state")
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab, self.vocab_pad_multiple)

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def n_layer_groups(self) -> int:
        return self.n_layers // self.moe_interleave

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, l = self.d_model, self.d_ff, self.n_layers
        total = self.padded_vocab * d  # embed
        total += self.padded_vocab * d * self.n_codebooks  # lm head(s)
        if self.has_attention:
            qkvo = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            total += l * qkvo
        if self.has_ssm:
            dz = 2 * self.d_inner + 2 * self.ssm_ngroups * self.ssm_state \
                + self.ssm_nheads
            total += l * (d * dz + self.d_inner * d)
        if self.is_moe:
            n_moe = l // self.moe_interleave
            n_dense = l - n_moe
            total += n_moe * self.n_experts * 3 * d * ff + n_moe * d * self.n_experts
            total += n_dense * 3 * d * ff
        elif ff > 0:
            total += l * 3 * d * ff
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, ff, l = self.d_model, self.d_ff, self.n_layers
        total = self.param_count()
        n_moe = l // self.moe_interleave
        total -= n_moe * (self.n_experts - self.moe_topk) * 3 * d * ff
        return total

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (runs 1 step on CPU)."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * self.moe_interleave if self.is_moe else 2,
            d_model=256,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else 0,
            head_dim=64,
            d_ff=512 if self.d_ff else 0,
            vocab=1024,
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            moe_topk=min(self.moe_topk, 2) if self.is_moe else 0,
            ssm_state=min(self.ssm_state, 16) if self.has_ssm else 0,
            capacity_factor=8.0,   # no drops at toy batch sizes (continuity tests)
            ssm_headdim=32 if self.has_ssm else 64,
            ssm_chunk=32,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            n_prefix=min(self.n_prefix, 16),
            mrope_sections=(8, 12, 12) if self.mrope else self.mrope_sections,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            long_context_window=64,
            vocab_pad_multiple=128,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workloads."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
