"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer, meta
tokens, SWA with periodic global layers [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    n_meta_tokens=128,
    global_attn_every=8,      # every 8th layer full attention, rest SWA
    sliding_window=1024,
    source="Hymba hybrid-head 1.5B [arXiv:2411.13676]",
)
