"""llama3-405b — frontier dense, GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=500000.0,
    source="Llama-3.1 405B [arXiv:2407.21783]",
)
