"""Fused Pallas IVF candidate search — in-kernel gather + scoring (DESIGN.md §15).

The IVF index (``repro.core.index.IVFIndex``) probes ``nprobe`` buckets per
query and scores the probed members. The jnp formulation materializes the
gathered candidates as a ``(B, M, d)`` tensor in HBM (``keys[cand]``) before
a separate einsum: at B=128, M=nprobe*cap=1024, d=768 that is ~400 MB of
slab rows written back to HBM and re-read — 3x the unavoidable traffic —
purely to satisfy XLA's gather-then-contract structure. This kernel removes
the round trip: candidate slab rows are DMA'd HBM -> VMEM *inside* the
kernel, scored on the MXU from VMEM, and folded into a running top-k, so
the ``(B, M, d)`` tensor never exists in HBM and the slab bytes are read
exactly once (streamed), skipping masked candidates entirely.

Tiling:
  grid = (B/BB, M/BM); the candidate axis M is minor (sequential), so the
  (BB, k) running top-k stays resident in VMEM across candidate tiles —
  the same running-merge structure as ``cosine_topk`` (§3), with the key
  *block* stream replaced by a gathered key *tile* stream.

Per grid step:
  1. the (BB, BM) tile of candidate slot ids arrives twice: an SMEM copy
     (scalar reads drive the DMA loop) and a VMEM copy (vector mask +
     result ids). Invisible candidates — dead bucket slots, other tenants'
     rows, TTL-expired slots, per-row duplicates — are pre-masked to -1 by
     the caller (``IVFIndex.candidates``), so visibility is one compare.
  2. gather: for each (row, candidate) with id >= 0, an async copy
     ``keys[id] -> scratch[row, cand]`` (ANY -> VMEM). All BB*BM copies are
     started before any is awaited — one semaphore counts completions — so
     the DMA engine sees the whole tile's worth of row fetches at once.
     Candidates with id < 0 start no DMA: an empty bucket costs nothing.
  3. score: the gathered (BB, BM, d) tile is dequantized in VMEM (int8
     slabs: uniform ``key_scale=1/127`` exactly as §14.3) and contracted
     row-by-row on the MXU — BB (1, d) x (d, BM) GEMMs.
  4. merge: masked scores (id < 0 -> NEG_INF) merge into the running
     (BB, k) top-k via the same k-step argmax-and-suppress as §3.

VMEM budget (BB=8, BM=128): scratch BB*BM*d bytes — 3.0 MiB at d=768 f32,
6.0 MiB at d=1536 f32, 1.5 MiB at d=1536 int8 — plus the (BB, d) query
block and (BB, BM) score tile; well under the 16 MiB/core ceiling. BB is
deliberately small: the scratch tile scales with BB*BM*d, and the batch
grid axis is parallel (independent row blocks), so small BB costs grid
steps, not occupancy.

Contract (shared with ``ref.ivf_topk_ref``): candidates with id -1 are
invisible; rows whose candidates are all -1 return exactly ``(-inf, -1)``
(§14.4). Returned ids are *slot ids* (the candidate values), not candidate
positions. int8 slabs dequant in-kernel via the uniform static
``key_scale = 1/127`` (the slab's symmetric scale from ``store.insert``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cosine_topk import NEG_INF, _iter_topk, _pad_to

Array = jax.Array


def _ivf_topk_kernel(q_ref, ids_smem, ids_vmem, k_ref, ts_ref, ti_ref,
                     scratch, sem, *, k: int, block_b: int, block_m: int,
                     key_scale: float | None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ts_ref[...] = jnp.full_like(ts_ref, NEG_INF)
        ti_ref[...] = jnp.full_like(ti_ref, -1)

    # -- gather phase: per-candidate row DMAs, all in flight before any wait.
    # The copy descriptors are reconstructed in the wait pass (same src/dst/
    # semaphore triple) — the standard start-here-wait-there Pallas pattern.
    def _copy(r, c):
        idx = ids_smem[r, c]
        return pltpu.make_async_copy(
            k_ref.at[pl.ds(idx, 1), :],
            scratch.at[pl.ds(r * block_m + c, 1), :],
            sem)

    for r in range(block_b):
        def _start(c, _, r=r):
            idx = ids_smem[r, c]

            @pl.when(idx >= 0)                      # masked candidate: no DMA
            def _():
                _copy(r, c).start()
            return 0
        jax.lax.fori_loop(0, block_m, _start, 0)
    for r in range(block_b):
        def _wait(c, _, r=r):
            idx = ids_smem[r, c]

            @pl.when(idx >= 0)
            def _():
                _copy(r, c).wait()
            return 0
        jax.lax.fori_loop(0, block_m, _wait, 0)

    # -- score phase: dequant in VMEM, then BB row-GEMMs on the MXU.
    kb = scratch[...].astype(jnp.float32)           # (BB*BM, d)
    if key_scale is not None:
        kb = kb * key_scale                         # uniform int8 dequant
    rows = []
    for r in range(block_b):
        qr = q_ref[pl.ds(r, 1), :]                  # (1, d)
        kr = kb[r * block_m:(r + 1) * block_m]      # (BM, d)
        rows.append(jax.lax.dot_general(
            qr, kr, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32))    # (1, BM)
    s = jnp.concatenate(rows, axis=0)               # (BB, BM)

    ids = ids_vmem[...]                             # (BB, BM) int32, -1 masked
    s = jnp.where(ids >= 0, s, NEG_INF)             # un-DMA'd scratch rows too

    # -- merge phase: block top-k, then merge with the running (BB, k) set.
    blk_s, blk_i = _iter_topk(s, ids, k)
    run_s, run_i = ts_ref[...], ti_ref[...]
    cand_s = jnp.concatenate([run_s, blk_s], axis=1)    # (BB, 2k)
    cand_i = jnp.concatenate([run_i, blk_i], axis=1)
    new_s, new_i = _iter_topk(cand_s, cand_i, k)
    ts_ref[...] = new_s
    ti_ref[...] = new_i


_STATIC = ("k", "block_b", "block_m", "interpret", "key_scale")


@functools.partial(jax.jit, static_argnames=_STATIC)
def ivf_topk_pallas(queries: Array, keys: Array, cand: Array, *, k: int = 4,
                    block_b: int = 8, block_m: int = 128,
                    interpret: bool = False, key_scale: float | None = None
                    ) -> tuple[Array, Array]:
    """Fused IVF candidate gather + score + top-k. See module docstring.

    queries (B, d) f32 normalized; keys (N, d) f32|bf16|int8 — the *whole*
    slab, left in HBM (ANY memory space) and gathered row-wise in-kernel;
    cand (B, M) int32 candidate slot ids with -1 marking invisible
    candidates (dead bucket slots, foreign tenants, expired, duplicates).
    Returns (scores (B, k) f32, slot ids (B, k) int32, -1 where empty).
    """
    b, d = queries.shape
    m = cand.shape[1]
    bb = min(block_b, max(1, b))
    bm = min(block_m, m)
    b_pad = -(-b // bb) * bb
    m_pad = -(-m // bm) * bm
    if keys.dtype == jnp.int8 and key_scale is None:
        key_scale = 1.0 / 127.0  # uniform slab dequant (§14.3)

    q = _pad_to(queries.astype(jnp.float32), b_pad, 0, 0.0)
    ids = _pad_to(_pad_to(cand.astype(jnp.int32), b_pad, 0, -1), m_pad, 1, -1)

    kernel = functools.partial(_ivf_topk_kernel, k=k, block_b=bb, block_m=bm,
                               key_scale=key_scale)
    ts, ti = pl.pallas_call(
        kernel,
        grid=(b_pad // bb, m_pad // bm),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j),
                         memory_space=pltpu.TPUMemorySpace.SMEM),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb * bm, d), keys.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(q, ids, ids, keys)
    ts = jnp.where(ts <= NEG_INF, -jnp.inf, ts)
    return ts[:b], ti[:b]
