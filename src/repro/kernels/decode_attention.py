"""Single-token decode attention Pallas TPU kernel, int8-KV aware.

The decode hot-spot (§Perf pair 4): one query token attends over the full
KV cache. The cache streams HBM -> VMEM in sequence blocks while running
online-softmax statistics stay resident — and for the int8 cache the
dequantization happens *after* the DMA, on the VMEM block, so HBM traffic
is the quantized payload (the 1.9x §Perf win realized at kernel level).

Layouts (one layer): q (B, H, D); k/v (B, S, HKV, D) in bf16/f32 or int8
with scales (B, S, HKV); slot_pos (S,) governs ring-buffer validity and
sliding-window masks (positions, not slot order). GQA is handled by the
caller reshaping q to (B, HKV, G, D); the kernel grid is (B*HKV, S/BS) with
the sequence axis minor, accumulating over blocks of BS cache slots.

VMEM per step (BS=512, D<=256): k,v blocks 2 x 512 x 256 x 4B = 1 MiB,
int8: 0.25 MiB — far under the 16 MiB budget; the GEMMs are (G, D) x
(D, BS) and (G, BS) x (BS, D) with D, BS multiples of 128 for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -3.0e38


def _decode_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, sp_ref, meta_ref,
                   o_ref, m_scr, l_scr, acc_scr, *, block_s: int,
                   quantized: bool, window: int | None, n_sink: int,
                   scale: float):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                            # (G, D) f32
    k = k_ref[0]                            # (BS, D)
    v = v_ref[0]
    if quantized:
        k = k.astype(jnp.float32) * ks_ref[0][:, None]     # (BS,1) scales
        v = v.astype(jnp.float32) * vs_ref[0][:, None]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (G, BS)

    pos = meta_ref[0]                        # current decode position
    spos = sp_ref[...][:, 0]                 # (BS,) absolute slot positions
    visible = (spos >= 0) & (spos <= pos)
    if window is not None:
        wmask = spos > pos - window
        if n_sink > 0:
            wmask = wmask | (spos < n_sink)
        visible = visible & wmask
    s = jnp.where(visible[None, :], s, NEG_INF)

    m_old = m_scr[...][:, 0]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
    corr = jnp.exp(m_old - m_new)
    p = jnp.where(visible[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    l_scr[...] = (l_scr[...][:, 0] * corr + jnp.sum(p, axis=1))[:, None]
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new[:, None]

    @pl.when(si == ns - 1)
    def _final():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "n_sink", "scale",
                                             "block_s", "interpret"))
def decode_attention_pallas(q: Array, k_cache: Array, v_cache: Array,
                            slot_pos: Array, pos: Array, *,
                            k_scale: Array | None = None,
                            v_scale: Array | None = None,
                            window: int | None = None, n_sink: int = 0,
                            scale: float | None = None, block_s: int = 512,
                            interpret: bool = False) -> Array:
    """q (B, 1, H, D); k/v (B, S, HKV, D) [+ scales (B, S, HKV) for int8].
    Returns (B, 1, H, D)."""
    b, _, h, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bs = min(block_s, s_len)
    assert s_len % bs == 0, (s_len, bs)
    quantized = k_cache.dtype == jnp.int8

    qg = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d).astype(jnp.float32)
    kg = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_len, d)
    vg = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_len, d)
    if quantized:
        ksg = k_scale.transpose(0, 2, 1).reshape(b * hkv, s_len)
        vsg = v_scale.transpose(0, 2, 1).reshape(b * hkv, s_len)
    else:   # dummy f32 operands keep the kernel signature static
        ksg = jnp.zeros((b * hkv, s_len), jnp.float32)
        vsg = ksg
    sp2 = slot_pos[:, None].astype(jnp.int32)           # (S, 1) >=2D for TPU
    meta = jnp.full((1,), pos, dtype=jnp.int32)

    grid = (b * hkv, s_len // bs)
    kernel = functools.partial(
        _decode_kernel, block_s=bs, quantized=quantized, window=window,
        n_sink=n_sink, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, bs, d), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((1, bs), lambda bh, si: (bh, si)),
            pl.BlockSpec((1, bs), lambda bh, si: (bh, si)),
            pl.BlockSpec((bs, 1), lambda bh, si: (si, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),   # meta: scalar position
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg, ksg, vsg, sp2, meta)
    return out.reshape(b, hkv, g, d).reshape(b, 1, h, d)
