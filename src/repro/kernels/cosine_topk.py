"""Fused cosine-similarity + top-k Pallas TPU kernel — the scoring hot-spot.

This is the TPU-native replacement for the paper's HNSW search (DESIGN.md §3):
one pass over the cache slab, blocked through VMEM, with the similarity GEMM
on the MXU and a running top-k merge held in VMEM across grid steps.

Tiling:
  grid = (B/BB, N/BN); the N axis is the minor (sequential) axis, so the
  output blocks (BB, k) stay resident in VMEM and accumulate the running
  top-k while key blocks (BN, d) stream HBM -> VMEM.

  BB=128, BN=512, d<=1536  ->  VMEM working set per step:
    keys  512 x 1536 x 4B = 3.0 MiB
    q     128 x 1536 x 4B = 0.75 MiB
    scores 128 x 512 x 4B = 0.25 MiB            << 16 MiB VMEM/core
  The GEMM contraction dim (d: 384/768/1536) and BN are multiples of 128,
  keeping the MXU systolic array fully tiled.

Top-k strategy: ``k`` is tiny (<=8). A k-step unrolled argmax-and-suppress
over the (BB, BN) score block is pure VPU work and avoids any sort network;
the per-block winners then merge with the resident (BB, k) running set via
one more k-step selection over the concatenated (BB, 2k) candidates.

Validity/TTL masking is fused: the ``valid`` column (f32 0/1, shaped (N, 1)
to satisfy TPU >=2D tiling) rides in with each key block and masked slots
score -inf — the kernel-level analogue of Redis lazy expiry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG_INF = -3.0e38  # finite -inf stand-in (python float: not a traced const)


def _iter_topk(scores: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """k-step argmax-and-suppress. scores (B, M) f32, ids (B, M) i32."""
    b, m = scores.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, m), 1)
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.max(scores, axis=1)
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        sel = jnp.take_along_axis(ids, arg[:, None], axis=1)[:, 0]
        out_s.append(best)
        out_i.append(jnp.where(best > NEG_INF, sel, -1))
        scores = jnp.where(cols == arg[:, None], NEG_INF, scores)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _cosine_topk_kernel(q_ref, k_ref, valid_ref, ts_ref, ti_ref, *,
                        k: int, block_n: int, dequant: bool,
                        scale_ref=None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ts_ref[...] = jnp.full_like(ts_ref, NEG_INF)
        ti_ref[...] = jnp.full_like(ti_ref, -1)

    q = q_ref[...]                      # (BB, d) f32
    kb = k_ref[...]                     # (BN, d) f32|bf16|int8
    if dequant:
        kb = kb.astype(jnp.float32) * scale_ref[...]  # (BN,1) per-row scale
    # MXU GEMM; contraction over d.
    s = jax.lax.dot_general(
        q, kb.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (BB, BN)
    vmask = valid_ref[...]              # (BN, 1) f32 0/1
    s = jnp.where((vmask[:, 0] > 0.5)[None, :], s, NEG_INF)

    base = j * block_n
    bb = s.shape[0]
    gids = base + jax.lax.broadcasted_iota(jnp.int32, (bb, s.shape[1]), 1)
    blk_s, blk_i = _iter_topk(s, gids, k)

    run_s, run_i = ts_ref[...], ti_ref[...]
    cand_s = jnp.concatenate([run_s, blk_s], axis=1)   # (BB, 2k)
    cand_i = jnp.concatenate([run_i, blk_i], axis=1)
    new_s, new_i = _iter_topk(cand_s, cand_i, k)
    ts_ref[...] = new_s
    ti_ref[...] = new_i


def _pad_to(x: Array, n: int, axis: int, fill) -> Array:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_n",
                                             "interpret"))
def cosine_topk_pallas(queries: Array, keys: Array, valid: Array, *,
                       k: int = 4, block_b: int = 128, block_n: int = 512,
                       interpret: bool = False) -> tuple[Array, Array]:
    """Fused masked cosine top-k. See module docstring for the contract.

    queries (B, d) f32 normalized; keys (N, d); valid (N,) bool.
    Returns (scores (B, k), indices (B, k) int32, -1 where masked/empty).
    """
    b, d = queries.shape
    n = keys.shape[0]
    bb = min(block_b, max(8, b))
    bn = min(block_n, n)
    # pad to tile multiples; padded keys are masked invalid
    b_pad = -(-b // bb) * bb
    n_pad = -(-n // bn) * bn
    q = _pad_to(queries.astype(jnp.float32), b_pad, 0, 0.0)
    kk = _pad_to(keys, n_pad, 0, 0.0)
    vm = _pad_to(valid.astype(jnp.float32)[:, None], n_pad, 0, 0.0)

    grid = (b_pad // bb, n_pad // bn)
    kernel = functools.partial(
        _cosine_topk_kernel, k=k, block_n=bn, dequant=False)
    ts, ti = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, kk, vm)
    ts = jnp.where(ts <= NEG_INF, -jnp.inf, ts)
    return ts[:b], ti[:b]


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_n",
                                             "interpret"))
def quant_cosine_topk_pallas(queries: Array, keys_q: Array, scales: Array,
                             valid: Array, *, k: int = 4, block_b: int = 128,
                             block_n: int = 512, interpret: bool = False
                             ) -> tuple[Array, Array]:
    """int8-slab variant: keys int8 + per-row f32 scale, dequant in VMEM.

    Cuts slab HBM traffic 4x vs f32 keys (the lookup is memory-bound at
    large N — see EXPERIMENTS.md §Perf); dequant happens after the DMA, on
    the block in VMEM, so the MXU still sees f32 operands.
    """
    b, d = queries.shape
    n = keys_q.shape[0]
    bb = min(block_b, max(8, b))
    bn = min(block_n, n)
    b_pad = -(-b // bb) * bb
    n_pad = -(-n // bn) * bn
    q = _pad_to(queries.astype(jnp.float32), b_pad, 0, 0.0)
    kk = _pad_to(keys_q, n_pad, 0, 0)
    sc = _pad_to(scales[:, None], n_pad, 0, 0.0)
    vm = _pad_to(valid.astype(jnp.float32)[:, None], n_pad, 0, 0.0)

    grid = (b_pad // bb, n_pad // bn)

    def kernel(q_ref, k_ref, s_ref, valid_ref, ts_ref, ti_ref):
        _cosine_topk_kernel(q_ref, k_ref, valid_ref, ts_ref, ti_ref,
                            k=k, block_n=bn, dequant=True, scale_ref=s_ref)

    ts, ti = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, kk, sc, vm)
    ts = jnp.where(ts <= NEG_INF, -jnp.inf, ts)
    return ts[:b], ti[:b]


def quantize_keys(keys: Array) -> tuple[Array, Array]:
    """Symmetric per-row int8 quantization: keys ≈ q * scale."""
    absmax = jnp.max(jnp.abs(keys), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(keys / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
