"""Fused cosine-similarity + top-k Pallas TPU kernel — the scoring hot-spot.

This is the TPU-native replacement for the paper's HNSW search (DESIGN.md §3):
one pass over the cache slab, blocked through VMEM, with the similarity GEMM
on the MXU and a running top-k merge held in VMEM across grid steps.

Tiling:
  grid = (B/BB, N/BN); the N axis is the minor (sequential) axis, so the
  output blocks (BB, k) stay resident in VMEM and accumulate the running
  top-k while key blocks (BN, d) stream HBM -> VMEM.

  BB=128, BN=512, d<=1536  ->  VMEM working set per step:
    keys  512 x 1536 x 4B = 3.0 MiB
    q     128 x 1536 x 4B = 0.75 MiB
    scores 128 x 512 x 4B = 0.25 MiB            << 16 MiB VMEM/core
  The GEMM contraction dim (d: 384/768/1536) and BN are multiples of 128,
  keeping the MXU systolic array fully tiled.

Top-k strategy: ``k`` is tiny (<=8). A k-step unrolled argmax-and-suppress
over the (BB, BN) score block is pure VPU work and avoids any sort network;
the per-block winners then merge with the resident (BB, k) running set via
one more k-step selection over the concatenated (BB, 2k) candidates.

Masking (all fused, all optional — DESIGN.md §14):
  * ``valid`` — shared (N,) aliveness (validity ∧ TTL), shipped as an
    (N, 1) f32 column riding with each key block: the kernel-level analogue
    of Redis lazy expiry.
  * per-row *intervals* — (B,) ``starts``/``sizes`` operands, one visible
    contiguous slot range per query row. The (BB, BN) visibility mask is
    built *inside* the kernel from block iota against the (BB, 1) interval
    operands, so per-row masking costs O(B) operand traffic instead of a
    (B, N) bool mask in HBM. This is the multi-tenant path: PartitionMap
    regions are contiguous by construction (§13.2).
  * dense per-row mask — a blocked (BB, BN) int8 mask operand for masks
    that are *not* contiguous ranges (e.g. future embedding-LSH bucket
    coalescing). Costs B*N bytes of HBM traffic; prefer intervals.

int8 slabs: keys stored as ``round(normalized * 127)`` (store.insert) score
through the same kernel with a uniform static ``key_scale = 1/127`` folded
into the in-VMEM dequant — entrypoints apply it automatically for int8 keys
so raw-int8 GEMMs (scores inflated x127) cannot happen. Per-row-scale
quantization (``quantize_keys``) uses the (N, 1) ``scales`` operand instead.

All-masked rows (empty tenant region, padded row) return exactly
``(-inf, -1)`` — the same contract as ``ref.cosine_topk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG_INF = -3.0e38  # finite -inf stand-in (python float: not a traced const)


def _iter_topk(scores: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """k-step argmax-and-suppress. scores (B, M) f32, ids (B, M) i32."""
    b, m = scores.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, m), 1)
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.max(scores, axis=1)
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        sel = jnp.take_along_axis(ids, arg[:, None], axis=1)[:, 0]
        out_s.append(best)
        out_i.append(jnp.where(best > NEG_INF, sel, -1))
        scores = jnp.where(cols == arg[:, None], NEG_INF, scores)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _cosine_topk_kernel(q_ref, k_ref, ts_ref, ti_ref, *,
                        k: int, block_n: int, key_scale: float | None,
                        scale_ref=None, valid_ref=None,
                        start_ref=None, size_ref=None, mask_ref=None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ts_ref[...] = jnp.full_like(ts_ref, NEG_INF)
        ti_ref[...] = jnp.full_like(ti_ref, -1)

    q = q_ref[...]                      # (BB, d) f32
    kb = k_ref[...]                     # (BN, d) f32|bf16|int8
    if scale_ref is not None:
        kb = kb.astype(jnp.float32) * scale_ref[...]  # (BN,1) per-row scale
    elif key_scale is not None:
        kb = kb.astype(jnp.float32) * key_scale       # uniform int8 dequant
    # MXU GEMM; contraction over d.
    s = jax.lax.dot_general(
        q, kb.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (BB, BN)

    base = j * block_n
    bb, bn = s.shape
    gids = base + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)

    if valid_ref is not None:
        vmask = valid_ref[...]          # (BN, 1) f32 0/1, shared by the batch
        s = jnp.where((vmask[:, 0] > 0.5)[None, :], s, NEG_INF)
    if start_ref is not None:
        # per-row interval visibility, built from iota in VMEM: row b sees
        # slots [start[b], start[b] + size[b]) — O(B) operands, no (B, N)
        # mask ever touches HBM
        start = start_ref[...]          # (BB, 1) int32
        size = size_ref[...]            # (BB, 1) int32
        s = jnp.where((gids >= start) & (gids < start + size), s, NEG_INF)
    if mask_ref is not None:
        # dense per-row mask block (BB, BN) int8 — the general
        # (non-contiguous) visibility path
        s = jnp.where(mask_ref[...] > 0, s, NEG_INF)

    blk_s, blk_i = _iter_topk(s, gids, k)

    run_s, run_i = ts_ref[...], ti_ref[...]
    cand_s = jnp.concatenate([run_s, blk_s], axis=1)   # (BB, 2k)
    cand_i = jnp.concatenate([run_i, blk_i], axis=1)
    new_s, new_i = _iter_topk(cand_s, cand_i, k)
    ts_ref[...] = new_s
    ti_ref[...] = new_i


def _pad_to(x: Array, n: int, axis: int, fill) -> Array:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _launch(queries: Array, keys: Array, *, valid=None, scales=None,
            key_scale=None, starts=None, sizes=None, row_mask=None,
            k: int, block_b: int, block_n: int, interpret: bool
            ) -> tuple[Array, Array]:
    """Shared pallas_call assembly for every kernel variant: pads operands
    to tile multiples, wires the optional mask/scale operands, slices the
    batch padding back off. Padded key columns are masked invalid (shared
    column / dense mask) or fall outside every interval (intervals never
    extend past N); padded batch rows get size-0 intervals / zero masks and
    are discarded by the final slice."""
    b, d = queries.shape
    n = keys.shape[0]
    bb = min(block_b, max(8, b))
    bn = min(block_n, n)
    b_pad = -(-b // bb) * bb
    n_pad = -(-n // bn) * bn
    if keys.dtype == jnp.int8 and scales is None and key_scale is None:
        # int8 slab = round(normalized * 127): uniform dequant, folded into
        # the in-VMEM cast. Raw-int8 scoring would inflate scores x127 and
        # make every threshold comparison spuriously hit.
        key_scale = 1.0 / 127.0

    operands = [_pad_to(queries.astype(jnp.float32), b_pad, 0, 0.0),
                _pad_to(keys, n_pad, 0, 0)]
    in_specs = [pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j: (j, 0))]
    ref_names = []

    def add(name, op, spec):
        operands.append(op)
        in_specs.append(spec)
        ref_names.append(name)

    if scales is not None:
        add("scale_ref",
            _pad_to(scales.astype(jnp.float32)[:, None], n_pad, 0, 0.0),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)))
    if valid is not None:
        add("valid_ref",
            _pad_to(valid.astype(jnp.float32)[:, None], n_pad, 0, 0.0),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)))
    if starts is not None:
        add("start_ref",
            _pad_to(starts.astype(jnp.int32)[:, None], b_pad, 0, 0),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)))
        add("size_ref",
            _pad_to(sizes.astype(jnp.int32)[:, None], b_pad, 0, 0),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)))
    if row_mask is not None:
        # int8, not f32: the mask is pure HBM traffic on a memory-bound op,
        # so ship 1 byte/element (B*N bytes total)
        rm = _pad_to(_pad_to(row_mask.astype(jnp.int8), b_pad, 0, 0),
                     n_pad, 1, 0)
        add("mask_ref", rm, pl.BlockSpec((bb, bn), lambda i, j: (i, j)))

    def kernel(q_ref, k_ref, *rest):
        refs = dict(zip(ref_names, rest[:len(ref_names)]))
        ts_ref, ti_ref = rest[len(ref_names):]
        _cosine_topk_kernel(q_ref, k_ref, ts_ref, ti_ref, k=k, block_n=bn,
                            key_scale=key_scale, **refs)

    ts, ti = pl.pallas_call(
        kernel,
        grid=(b_pad // bb, n_pad // bn),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    ts = jnp.where(ts <= NEG_INF, -jnp.inf, ts)
    return ts[:b], ti[:b]


_STATIC = ("k", "block_b", "block_n", "interpret", "key_scale")


@functools.partial(jax.jit, static_argnames=_STATIC)
def cosine_topk_pallas(queries: Array, keys: Array, valid: Array, *,
                       k: int = 4, block_b: int = 128, block_n: int = 512,
                       interpret: bool = False, key_scale: float | None = None
                       ) -> tuple[Array, Array]:
    """Fused masked cosine top-k. See module docstring for the contract.

    queries (B, d) f32 normalized; keys (N, d) f32|bf16|int8; valid (N,)
    bool shared across the batch. int8 keys dequant in-kernel (uniform
    ``key_scale``, default 1/127 — the slab's symmetric scale).
    Returns (scores (B, k), indices (B, k) int32, -1 where masked/empty).
    """
    return _launch(queries, keys, valid=valid, key_scale=key_scale,
                   k=k, block_b=block_b, block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=_STATIC)
def quant_cosine_topk_pallas(queries: Array, keys_q: Array, scales: Array,
                             valid: Array, *, k: int = 4, block_b: int = 128,
                             block_n: int = 512, interpret: bool = False,
                             key_scale: float | None = None
                             ) -> tuple[Array, Array]:
    """int8-slab variant: keys int8 + per-row f32 scale, dequant in VMEM.

    Cuts slab HBM traffic 4x vs f32 keys (the lookup is memory-bound at
    large N — see EXPERIMENTS.md §Perf); dequant happens after the DMA, on
    the block in VMEM, so the MXU still sees f32 operands.
    """
    del key_scale  # per-row scales take precedence by construction
    return _launch(queries, keys_q, scales=scales, valid=valid,
                   k=k, block_b=block_b, block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=_STATIC)
def cosine_topk_interval_pallas(queries: Array, keys: Array, valid: Array,
                                starts: Array, sizes: Array, *, k: int = 4,
                                block_b: int = 128, block_n: int = 512,
                                interpret: bool = False,
                                key_scale: float | None = None
                                ) -> tuple[Array, Array]:
    """Per-row interval-masked variant — the tenancy fast path (§13.2).

    Row ``b`` sees slots ``[starts[b], starts[b] + sizes[b])`` ∩ ``valid``.
    The interval operands are O(B); the (B, N) visibility mask is built from
    block iota in VMEM and never materializes in HBM. ``sizes[b] == 0``
    (empty region / padded row) returns exactly ``(-inf, -1)`` for that row.
    """
    return _launch(queries, keys, valid=valid, starts=starts, sizes=sizes,
                   key_scale=key_scale, k=k, block_b=block_b, block_n=block_n,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=_STATIC)
def quant_cosine_topk_interval_pallas(queries: Array, keys_q: Array,
                                      scales: Array, valid: Array,
                                      starts: Array, sizes: Array, *,
                                      k: int = 4, block_b: int = 128,
                                      block_n: int = 512,
                                      interpret: bool = False,
                                      key_scale: float | None = None
                                      ) -> tuple[Array, Array]:
    """Interval-masked int8 variant with per-row dequant scales."""
    del key_scale
    return _launch(queries, keys_q, scales=scales, valid=valid, starts=starts,
                   sizes=sizes, k=k, block_b=block_b, block_n=block_n,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=_STATIC)
def cosine_topk_masked_pallas(queries: Array, keys: Array, mask: Array, *,
                              k: int = 4, block_b: int = 128,
                              block_n: int = 512, interpret: bool = False,
                              key_scale: float | None = None
                              ) -> tuple[Array, Array]:
    """General per-row-masked variant: ``mask`` is (B, N) bool — full
    visibility (aliveness ∧ per-row) folded in by the caller. Streams the
    mask in (BB, BN) blocks; for contiguous regions prefer the interval
    variant (O(B) operands vs O(B·N) mask traffic)."""
    return _launch(queries, keys, row_mask=mask, key_scale=key_scale,
                   k=k, block_b=block_b, block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=_STATIC)
def quant_cosine_topk_masked_pallas(queries: Array, keys_q: Array,
                                    scales: Array, mask: Array, *,
                                    k: int = 4, block_b: int = 128,
                                    block_n: int = 512,
                                    interpret: bool = False,
                                    key_scale: float | None = None
                                    ) -> tuple[Array, Array]:
    """Dense-masked int8 variant with per-row dequant scales."""
    del key_scale
    return _launch(queries, keys_q, scales=scales, row_mask=mask,
                   k=k, block_b=block_b, block_n=block_n, interpret=interpret)


def quantize_keys(keys: Array) -> tuple[Array, Array]:
    """Symmetric per-row int8 quantization: keys ≈ q * scale."""
    absmax = jnp.max(jnp.abs(keys), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(keys / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
