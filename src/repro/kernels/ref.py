"""Pure-jnp oracles for every Pallas kernel in this package.

These define the numerical contract; kernel tests sweep shapes/dtypes and
``assert_allclose`` against these functions (interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

# python float, not jnp.float32(...): this module may be first imported
# *inside* a jit trace (index.py defers its kernels import), and a
# module-level device constant created under a trace leaks as a tracer
NEG_INF = float("-inf")


def cosine_topk_ref(queries: Array, keys: Array, valid: Array, k: int
                    ) -> tuple[Array, Array]:
    """Exact masked cosine top-k.

    Args:
      queries: (B, d) float32, assumed L2-normalized.
      keys: (N, d) float or quantized-dequantized values, normalized. int8
        keys are the uniform slab quantization (round(normalized * 127))
        and dequant by 1/127 before scoring — raw int8 GEMMs would inflate
        every score x127.
      valid: (N,) bool aliveness mask shared by the batch, or (B, N) bool
        per-row visibility.
      k: neighbours to return.
    Returns:
      (scores (B, k) f32 desc-sorted, indices (B, k) int32; -1 where masked).
      All-masked rows return exactly (-inf, -1) — the contract every kernel
      variant and index path must match.
    """
    if keys.dtype == jnp.int8:
        keys = keys.astype(jnp.float32) / 127.0
    scores = jnp.einsum("bd,nd->bn", queries, keys.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    mask = valid if valid.ndim == 2 else valid[None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(vals > NEG_INF, idx, -1)
    return vals, idx.astype(jnp.int32)


def quant_cosine_topk_ref(queries: Array, keys_q: Array, scales: Array,
                          valid: Array, k: int) -> tuple[Array, Array]:
    """int8-quantized scoring oracle: dequantize then exact top-k.

    keys_q: (N, d) int8; scales: (N,) f32 per-row dequant scale.
    valid: (N,) shared or (B, N) per-row.
    """
    keys = keys_q.astype(jnp.float32) * scales[:, None]
    return cosine_topk_ref(queries, keys, valid, k)


def interval_mask(starts: Array, sizes: Array, n: int) -> Array:
    """(B,) interval operands -> (B, N) bool visibility mask: row ``b`` sees
    slots ``[starts[b], starts[b] + sizes[b])``. The jnp oracle for the
    iota-built mask the interval kernel never materializes."""
    cols = jnp.arange(n, dtype=jnp.int32)[None, :]
    return (cols >= starts[:, None]) & (cols < (starts + sizes)[:, None])


def cosine_topk_interval_ref(queries: Array, keys: Array, valid: Array,
                             starts: Array, sizes: Array, k: int
                             ) -> tuple[Array, Array]:
    """Oracle for the per-row interval-masked kernel (tenancy fast path):
    dense (B, N) mask = shared aliveness ∧ per-row interval, then exact
    top-k. ``sizes[b] == 0`` rows return (-inf, -1)."""
    mask = valid[None, :] & interval_mask(starts, sizes, keys.shape[0])
    return cosine_topk_ref(queries, keys, mask, k)


def quant_cosine_topk_interval_ref(queries: Array, keys_q: Array,
                                   scales: Array, valid: Array, starts: Array,
                                   sizes: Array, k: int
                                   ) -> tuple[Array, Array]:
    """Interval oracle over a per-row-scale int8 slab."""
    keys = keys_q.astype(jnp.float32) * scales[:, None]
    return cosine_topk_interval_ref(queries, keys, valid, starts, sizes, k)


def ivf_topk_ref(queries: Array, keys: Array, cand: Array, k: int
                 ) -> tuple[Array, Array]:
    """Oracle for the fused IVF candidate kernel (DESIGN.md §15): gather the
    candidate rows, score, top-k — the ``(B, M, d)`` HBM materialization the
    kernel exists to avoid, acceptable here because the oracle defines
    numerics, not traffic.

    Args:
      queries: (B, d) float32, assumed L2-normalized.
      keys: (N, d) slab; int8 is the uniform slab quantization and dequants
        by 1/127 exactly like ``cosine_topk_ref``.
      cand: (B, M) int32 candidate slot ids; -1 marks an invisible candidate
        (dead bucket slot, foreign tenant, expired, per-row duplicate —
        the caller folds all visibility into the ids, see
        ``IVFIndex.candidates``).
      k: neighbours to return.
    Returns:
      (scores (B, k) f32 desc-sorted, slot ids (B, k) int32). Rows whose
      candidates are all -1 return exactly (-inf, -1) — the §14.4 contract.
    """
    if keys.dtype == jnp.int8:
        keys = keys.astype(jnp.float32) / 127.0
    safe = jnp.maximum(cand, 0)
    gathered = keys[safe].astype(jnp.float32)            # (B, M, d) — in HBM
    scores = jnp.einsum("bd,bmd->bm", queries, gathered,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(cand >= 0, scores, NEG_INF)
    vals, pos = jax.lax.top_k(scores, k)
    ids = jnp.take_along_axis(cand, pos, axis=1)
    ids = jnp.where(vals > NEG_INF, ids, -1)
    return vals, ids.astype(jnp.int32)


def flash_attention_ref(q: Array, kk: Array, v: Array, *, causal: bool = True,
                        window: int | None = None, scale: float | None = None
                        ) -> Array:
    """Blockwise-attention oracle: plain softmax attention.

    Shapes: q (B, Lq, H, D), kk/v (B, Lk, H, D) — same head count (callers
    expand GQA groups before the kernel). Supports causal & sliding-window
    masks with the convention that query position i attends to key positions
    ``max(0, i - window + 1) .. i`` (absolute offset = Lk - Lq aligns ends).
    """
    b, lq, h, d = q.shape
    lk = kk.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v).astype(q.dtype)
