"""Flash-attention Pallas TPU kernel (prefill hot-spot of the miss path).

Online-softmax blockwise attention (Dao et al., adapted to TPU): the KV
sequence streams through VMEM while running (max, denom, acc) statistics
stay resident in VMEM scratch; the (Lq, Lk) score matrix is never
materialized. Supports causal and sliding-window masks and GQA natively —
KV is laid out per *KV head* and the BlockSpec index map routes each query
head to its KV group (no head expansion in HBM).

Tiling (defaults): BQ=256, BK=512, D<=256 per head
  q     256 x 256 x 4B  = 0.25 MiB
  k,v   512 x 256 x 4B  = 0.5 MiB total 1 MiB
  p     256 x 512 x 4B  = 0.5 MiB
  acc/m/l                 ~0.26 MiB          << 16 MiB VMEM
MXU dims (BQ, D, BK) are all multiples of 128 at the default config.

Layouts: q (BH, Lq, D) with BH = batch*heads; k/v (BHKV, Lk, D) with
BHKV = batch*kv_heads; heads-per-group g = H // HKV; q row bh maps to kv
row (bh // g). The jnp fallback/oracle is ``ref.flash_attention_ref``.

The grid is (BH, Lq/BQ, Lk/BK) with the KV axis minor (sequential). For
causal masks the fully-masked high-KV blocks are skipped with ``pl.when``
(they still occupy grid steps; the DMA cost is saved by the compiler's
dead-block elision on TPU — see EXPERIMENTS.md §Perf for the measured
effect of block pruning).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -3.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, lq: int, lk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # is this block reachable under the causal/window mask?
    q_lo = qi * block_q + (lk - lq)          # absolute position of first q row
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0]                        # (BQ, D)
        k = k_ref[0]                        # (BK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_old = m_scr[...][:, 0]            # (BQ,)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        corr = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_scr[...][:, 0] * corr + jnp.sum(p, axis=1)
        acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]
        l_scr[...] = l_new[:, None]
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _final():
        l = l_scr[...][:, 0]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, block_q: int = 256,
                           block_k: int = 512, interpret: bool = False
                           ) -> Array:
    """q (B, Lq, H, D); k/v (B, Lk, HKV, D). Returns (B, Lq, H, D)."""
    b, lq, h, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    assert h % hkv == 0, "query heads must be a multiple of kv heads"
    g = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    bq = min(block_q, lq)
    bk = min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0, (
        f"seq lens ({lq},{lk}) must tile by ({bq},{bk})")

    qh = q.transpose(0, 2, 1, 3).reshape(b * h, lq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, lk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, lk, d)

    grid = (b * h, lq // bq, lk // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, lq=lq, lk=lk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            # GQA routing: query-head row bh reads kv row bh // g
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq, d), q.dtype),
        scratch_shapes=[
            # (BQ, 1) running max / denom, (BQ, D) accumulator — VMEM scratch
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, lq, d).transpose(0, 2, 1, 3)
