"""Pallas TPU kernels for the perf-critical compute layers.

cosine_topk       — fused masked cosine-similarity + top-k over the cache slab
                    (the paper's search hot-spot; replaces HNSW on TPU).
                    Variants: shared (N,) mask, per-row interval operands
                    (the tenancy fast path — O(B) operands, mask built from
                    iota in VMEM), dense (B, N) blocked mask (general
                    non-contiguous visibility), each with f32 and int8 slabs
quant_cosine_topk — int8-slab variant with per-row dequant scales
                    (beyond-paper: 4x HBM traffic cut)
ivf_topk          — fused IVF candidate search: probed slab rows gathered
                    HBM -> VMEM *inside* the kernel and scored with a
                    running top-k merge, so the (B, M, d) gathered-candidate
                    tensor never materializes in HBM (DESIGN.md §15)
flash_attention   — online-softmax blockwise attention for the miss path
                    (prefill), GQA-aware, causal/sliding-window
decode_attention  — single-token attention over the (optionally int8) KV
                    cache: the decode hot-spot with fused dequantization

Each kernel has a pure-jnp oracle in ``ref.py`` and a dispatching wrapper in
``ops.py``; tests sweep shapes/dtypes in interpret mode against the oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.cosine_topk import (cosine_topk_interval_pallas,
                                       cosine_topk_masked_pallas,
                                       cosine_topk_pallas,
                                       quant_cosine_topk_interval_pallas,
                                       quant_cosine_topk_masked_pallas,
                                       quant_cosine_topk_pallas,
                                       quantize_keys)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.ivf_topk import ivf_topk_pallas

__all__ = ["ops", "ref", "cosine_topk_pallas",
           "cosine_topk_interval_pallas", "cosine_topk_masked_pallas",
           "quant_cosine_topk_pallas", "quant_cosine_topk_interval_pallas",
           "quant_cosine_topk_masked_pallas", "quantize_keys",
           "ivf_topk_pallas", "flash_attention_pallas",
           "decode_attention_pallas"]
