"""Jit'd dispatch wrappers for the Pallas kernels.

``cosine_topk`` picks the execution path:
  * TPU backend  -> compiled Pallas kernel,
  * anything else -> interpret-mode only when explicitly requested
    (``REPRO_PALLAS_INTERPRET=1``; it is Python-slow and meant for tests),
    otherwise the jnp oracle, which XLA fuses perfectly well on CPU.
The numerical contract is ``repro.kernels.ref``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cosine_topk import (cosine_topk_pallas,
                                       quant_cosine_topk_pallas,
                                       quantize_keys)

Array = jax.Array


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_requested() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def cosine_topk(queries: Array, keys: Array, valid: Array, *, k: int = 4
                ) -> tuple[Array, Array]:
    """Masked cosine top-k with automatic backend dispatch."""
    if _use_pallas():
        return cosine_topk_pallas(queries, keys, valid, k=k)
    if _interpret_requested():
        return cosine_topk_pallas(queries, keys, valid, k=k, interpret=True)
    return ref.cosine_topk_ref(queries, keys, valid, k)


def quant_cosine_topk(queries: Array, keys_q: Array, scales: Array,
                      valid: Array, *, k: int = 4) -> tuple[Array, Array]:
    """int8-slab masked cosine top-k."""
    if _use_pallas():
        return quant_cosine_topk_pallas(queries, keys_q, scales, valid, k=k)
    if _interpret_requested():
        return quant_cosine_topk_pallas(queries, keys_q, scales, valid, k=k,
                                        interpret=True)
    return ref.quant_cosine_topk_ref(queries, keys_q, scales, valid, k)


__all__ = ["cosine_topk", "quant_cosine_topk", "quantize_keys"]
