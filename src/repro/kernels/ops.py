"""Jit'd dispatch wrappers for the Pallas kernels.

``cosine_topk`` / ``cosine_topk_interval`` pick the execution path:
  * TPU backend  -> compiled Pallas kernel,
  * anything else -> interpret-mode only when explicitly requested
    (``REPRO_PALLAS_INTERPRET=1``; it is Python-slow and meant for tests and
    the CPU CI job that exercises the kernel code paths),
    otherwise the jnp oracle, which XLA fuses perfectly well on CPU.
The numerical contract is ``repro.kernels.ref``.

Per-row visibility (DESIGN.md §14) dispatches by mask shape:
  * ``valid`` (N,)   -> shared-mask kernel (single-tenant fast path);
  * interval operands -> iota-masked kernel, O(B) operand traffic — the
    tenancy path (contiguous PartitionMap regions);
  * ``valid`` (B, N) -> dense blocked-mask kernel — the general path for
    non-contiguous visibility.

``ivf_topk`` dispatches the fused IVF candidate kernel (in-kernel HBM ->
VMEM gather of probed slab rows, DESIGN.md §15) the same way, with an
explicit ``backend=`` override for parity tests.

int8 slabs dequant *inside* the kernels (uniform 1/127 — the slab's
symmetric scale from ``store.insert``) and inside the oracles, so no
dispatch path ever scores raw int8 keys.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.cosine_topk import (cosine_topk_interval_pallas,
                                       cosine_topk_masked_pallas,
                                       cosine_topk_pallas,
                                       quant_cosine_topk_masked_pallas,
                                       quant_cosine_topk_pallas,
                                       quantize_keys)
from repro.kernels.ivf_topk import ivf_topk_pallas

Array = jax.Array


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_requested() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def cosine_topk(queries: Array, keys: Array, valid: Array, *, k: int = 4
                ) -> tuple[Array, Array]:
    """Masked cosine top-k with automatic backend dispatch.

    ``valid`` is (N,) shared across the batch or (B, N) per-row; the (B, N)
    shape routes to the dense blocked-mask kernel on TPU (contiguous
    per-row regions should use ``cosine_topk_interval`` instead)."""
    if _use_pallas() or _interpret_requested():
        interpret = not _use_pallas()
        if valid.ndim == 2:
            return cosine_topk_masked_pallas(queries, keys, valid, k=k,
                                             interpret=interpret)
        return cosine_topk_pallas(queries, keys, valid, k=k,
                                  interpret=interpret)
    return ref.cosine_topk_ref(queries, keys, valid, k)


def cosine_topk_interval(queries: Array, keys: Array, valid: Array,
                         starts: Array, sizes: Array, *, k: int = 4
                         ) -> tuple[Array, Array]:
    """Per-row interval-masked cosine top-k — the tenancy fast path.

    Row ``b`` sees ``valid`` ∩ ``[starts[b], starts[b] + sizes[b])``. The
    kernel builds the per-row mask from iota in VMEM, so the operand cost
    is O(B) regardless of slab size."""
    if _use_pallas() or _interpret_requested():
        return cosine_topk_interval_pallas(queries, keys, valid, starts,
                                           sizes, k=k,
                                           interpret=not _use_pallas())
    return ref.cosine_topk_interval_ref(queries, keys, valid, starts, sizes,
                                        k)


def quant_cosine_topk(queries: Array, keys_q: Array, scales: Array,
                      valid: Array, *, k: int = 4) -> tuple[Array, Array]:
    """int8-slab masked cosine top-k (per-row dequant scales).

    ``valid`` is (N,) shared or (B, N) per-row — same shape dispatch as
    ``cosine_topk``."""
    if _use_pallas() or _interpret_requested():
        interpret = not _use_pallas()
        if valid.ndim == 2:
            return quant_cosine_topk_masked_pallas(queries, keys_q, scales,
                                                   valid, k=k,
                                                   interpret=interpret)
        return quant_cosine_topk_pallas(queries, keys_q, scales, valid, k=k,
                                        interpret=interpret)
    return ref.quant_cosine_topk_ref(queries, keys_q, scales, valid, k)


def ivf_topk(queries: Array, keys: Array, cand: Array, *, k: int = 4,
             backend: str = "auto") -> tuple[Array, Array]:
    """Fused IVF candidate search with automatic backend dispatch (§15).

    ``cand`` is (B, M) int32 candidate slot ids with -1 marking invisible
    candidates (the caller — ``IVFIndex.candidates`` — folds bucket
    validity, aliveness, tenancy intervals and per-row dedup into the ids).
    On TPU (or under ``REPRO_PALLAS_INTERPRET=1``) the fused kernel gathers
    the candidate slab rows HBM -> VMEM in-kernel, so the (B, M, d) gathered
    tensor of the jnp oracle never materializes in HBM. ``backend`` is
    ``'auto' | 'jnp' | 'pallas'`` — explicit values pin a path for parity
    tests and benchmarks.
    """
    if backend == "pallas" or (
            backend == "auto" and (_use_pallas() or _interpret_requested())):
        return ivf_topk_pallas(queries, keys, cand, k=k,
                               interpret=not _use_pallas())
    return ref.ivf_topk_ref(queries, keys, cand, k)


__all__ = ["cosine_topk", "cosine_topk_interval", "quant_cosine_topk",
           "ivf_topk", "quantize_keys"]
