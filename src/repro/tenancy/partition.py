"""Static slab partition map + device-side tenancy state (DESIGN.md §13.2).

``PartitionMap`` is the *static* half of tenancy: a frozen, hashable record
of which contiguous slab region each tenant owns and which (if any)
similarity threshold overrides the cache-wide policy for it. It is baked
into ``SemanticCache`` like the index/policy plugins: trace-time constants,
so one compiled ``step()`` serves every tenant mix — the per-row
``tenant_id`` vector is the only traced tenancy input.

``TenancyState`` is the *dynamic* half: per-tenant ring pointers and
accounting counters, carried as one more leaf group of the ``CacheRuntime``
pytree so it jits, donates, and checkpoints with the slab.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PartitionMap:
    """Contiguous per-tenant slab regions. Tenant ``t`` owns slots
    ``[starts[t], starts[t] + sizes[t])``; regions are disjoint and cover
    the slab exactly (enforced by the registry that builds the map).

    ``thresholds[t] < 0`` means "no override" (use the policy's decision);
    ``band_lo[t] < 0`` likewise means "no override" for the near-hit band's
    lower edge (DESIGN.md §17.2). ``band_lo`` defaults to all-no-override so
    every pre-band construction site keeps working unchanged.
    """

    names: tuple[str, ...]
    starts: tuple[int, ...]
    sizes: tuple[int, ...]
    thresholds: tuple[float, ...]
    capacity: int
    band_lo: tuple[float, ...] = ()

    def __post_init__(self):
        if not self.band_lo:
            object.__setattr__(self, "band_lo", (-1.0,) * len(self.names))
        if not (len(self.names) == len(self.starts) == len(self.sizes)
                == len(self.thresholds) == len(self.band_lo)):
            raise ValueError("partition field lengths disagree")
        if sum(self.sizes) != self.capacity:
            raise ValueError(f"regions sum to {sum(self.sizes)}, "
                             f"capacity is {self.capacity}")
        acc = 0
        for s, z in zip(self.starts, self.sizes):
            if s != acc or z < 1:
                raise ValueError("regions must be contiguous, in order and "
                                 "non-empty")
            acc += z

    def __len__(self) -> int:
        return len(self.names)

    def index(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"unknown tenant {name!r}; registered: "
                           f"{self.names}") from None

    def region(self, name: str) -> tuple[int, int]:
        i = self.index(name)
        return self.starts[i], self.sizes[i]

    def manifest(self) -> dict:
        """JSON-able layout record — the single definition used both when
        writing a checkpoint manifest and when verifying one on restore.
        ``band_lo`` appears only when some tenant overrides the band edge,
        so manifests of band-less partitions stay byte-identical to those
        written before the near-hit subsystem existed (checkpoint compat)."""
        m = {"names": list(self.names), "starts": list(self.starts),
             "sizes": list(self.sizes),
             "thresholds": list(self.thresholds)}
        if any(b >= 0.0 for b in self.band_lo):
            m["band_lo"] = list(self.band_lo)
        return m

    # -- trace-time constant arrays -------------------------------------- #
    def slot_owner(self) -> np.ndarray:
        """(capacity,) int32: owning tenant of every slab slot."""
        return _slot_owner(self.starts, self.sizes, self.capacity)

    def starts_array(self) -> Array:
        return jnp.asarray(self.starts, dtype=jnp.int32)

    def sizes_array(self) -> Array:
        return jnp.asarray(self.sizes, dtype=jnp.int32)

    def thresholds_array(self) -> Array:
        """(T,) float32; negative entries mean "no override"."""
        return jnp.asarray(self.thresholds, dtype=jnp.float32)

    def band_lo_array(self) -> Array:
        """(T,) float32 near-band lower-edge overrides; negative entries
        mean "no override" (use the band policy's τ_lo)."""
        return jnp.asarray(self.band_lo, dtype=jnp.float32)


@functools.lru_cache(maxsize=64)
def _slot_owner(starts: tuple[int, ...], sizes: tuple[int, ...],
                capacity: int) -> np.ndarray:
    owner = np.empty((capacity,), dtype=np.int32)
    for t, (s, z) in enumerate(zip(starts, sizes)):
        owner[s:s + z] = t
    return owner


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TenancyState:
    """Per-tenant mutable state, one ``CacheRuntime`` leaf group.

    Leaves (all leading dim = number of tenants):
      ptr       — ring insert pointer, an offset *within* the tenant's
                  region (the global scalar ``CacheState.ptr`` is unused
                  under tenancy);
      lookups   — committed lookups per tenant;
      hits      — committed hits per tenant;
      inserts   — rows written per tenant;
      evictions — inserts that overwrote a live (non-expired) entry, i.e.
                  intra-region capacity pressure. A tenant can only ever
                  evict itself — cross-tenant eviction is structurally
                  impossible with disjoint regions.
    """

    ptr: Array
    lookups: Array
    hits: Array
    inserts: Array
    evictions: Array

    @staticmethod
    def zeros(num_tenants: int) -> "TenancyState":
        def z():
            return jnp.zeros((num_tenants,), dtype=jnp.int32)
        return TenancyState(ptr=z(), lookups=z(), hits=z(), inserts=z(),
                            evictions=z())

    @property
    def num_tenants(self) -> int:
        return self.ptr.shape[-1]

    def reduced(self) -> "TenancyState":
        """Collapse per-shard stacking (DESIGN.md §19.4) to the (T,)
        single-view counters. The sum is *exact*: lookups/hits are
        attributed on one designated shard only and inserts/evictions on
        the owning shard, so each event is counted once globally. The
        summed ``ptr`` is total ring fill across shards, NOT a usable ring
        offset — each shard keeps its own. A 1-D (unsharded) state is
        returned unchanged."""
        if self.ptr.ndim == 1:
            return self

        def s(x):
            return jnp.sum(x, axis=tuple(range(x.ndim - 1)))
        return TenancyState(ptr=s(self.ptr), lookups=s(self.lookups),
                            hits=s(self.hits), inserts=s(self.inserts),
                            evictions=s(self.evictions))
