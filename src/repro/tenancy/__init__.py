"""Multi-tenant cache namespaces (DESIGN.md §13).

One device-resident semantic cache, many isolation domains: the registry
describes tenants (capacity shares, DRR admission weights, optional
threshold overrides), the partition map splits the slab into contiguous
per-tenant regions baked into the compiled step, and ``TenancyState``
carries per-tenant ring pointers + accounting inside the ``CacheRuntime``
pytree.
"""
from repro.tenancy.partition import PartitionMap, TenancyState
from repro.tenancy.registry import NO_OVERRIDE, TenantRegistry, TenantSpec

__all__ = ["PartitionMap", "TenancyState", "TenantRegistry", "TenantSpec",
           "NO_OVERRIDE"]
