"""Tenant registry — the host-side source of truth for multi-tenant serving
(DESIGN.md §13.1).

A *tenant* is an isolation domain sharing one device-resident cache: a
product surface, a customer org, a user cohort. The registry holds the
static per-tenant policy knobs — capacity share (or a hard slot quota), a
deficit-round-robin admission weight, and an optional per-tenant
similarity-threshold override — and compiles them into a ``PartitionMap``
that splits the single slab into contiguous per-tenant regions.

MeanCache (Gill et al., 2024) motivates the partitioning as both a privacy
requirement and a hit-rate win; SCALM (Li et al., 2024) motivates
per-stream admission/eviction knobs over global ones. Both are folded into
this one registry so the engine, scheduler and benchmarks read tenancy
configuration from a single object.
"""
from __future__ import annotations

import dataclasses

from repro.tenancy.partition import PartitionMap

#: Threshold sentinel: "no override, use the cache-wide policy".
NO_OVERRIDE = -1.0


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Static per-tenant configuration.

    Attributes:
      name: tenant identifier (the ``Request.tenant`` routing key).
      share: relative capacity share; the slab's free capacity (after hard
        quotas) is split proportionally to ``share`` across quota-less
        tenants.
      weight: deficit-round-robin admission weight (scheduler quantum):
        a weight-2 tenant gets twice the micro-batch slots of a weight-1
        tenant under contention.
      quota: hard slab-slot cap. ``None`` = proportional ``share`` sizing.
      threshold: per-tenant cosine hit-threshold override; ``None`` = use
        the cache-wide policy's threshold (a stricter tenant can demand
        higher-precision hits without forking the compiled step).
      band_lo: per-tenant near-hit band lower-edge override (DESIGN.md
        §17.2); ``None`` = use the band policy's τ_lo. The band's *upper*
        edge is definitionally the tenant's effective hit threshold, so a
        tenant overrides both edges via ``threshold`` + ``band_lo``.
    """

    name: str
    share: float = 1.0
    weight: float = 1.0
    quota: int | None = None
    threshold: float | None = None
    band_lo: float | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.share <= 0 or self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: share and weight must "
                             "be positive")
        if self.quota is not None and self.quota <= 0:
            raise ValueError(f"tenant {self.name!r}: quota must be positive")
        if self.threshold is not None and not 0.0 <= self.threshold <= 1.0:
            raise ValueError(f"tenant {self.name!r}: threshold must be "
                             "within [0, 1]")
        if self.band_lo is not None:
            if not 0.0 <= self.band_lo <= 1.0:
                raise ValueError(f"tenant {self.name!r}: band_lo must be "
                                 "within [0, 1]")
            if self.threshold is not None and self.band_lo > self.threshold:
                raise ValueError(f"tenant {self.name!r}: band_lo must not "
                                 "exceed the hit threshold")


@dataclasses.dataclass(frozen=True)
class TenantRegistry:
    """Ordered, immutable collection of tenants.

    Tenant *index* (position in ``tenants``) is the device-side id threaded
    through the compiled step; tenant *name* is the host-side routing key.
    """

    tenants: tuple[TenantSpec, ...]

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("registry needs at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")

    @staticmethod
    def uniform(names: "tuple[str, ...] | list[str]") -> "TenantRegistry":
        """Equal shares, equal weights, no overrides."""
        return TenantRegistry(tuple(TenantSpec(name=n) for n in names))

    def __len__(self) -> int:
        return len(self.tenants)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.tenants)

    def index(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(
                f"unknown tenant {name!r}; registered: {self.names}") from None

    def spec(self, name: str) -> TenantSpec:
        return self.tenants[self.index(name)]

    def weights(self) -> dict[str, float]:
        """DRR admission weights by tenant name (scheduler input)."""
        return {t.name: t.weight for t in self.tenants}

    # -- partition construction ------------------------------------------ #
    def partition(self, capacity: int) -> PartitionMap:
        """Split ``capacity`` slab slots into contiguous per-tenant regions.

        Hard quotas are honoured first; the remaining slots are split
        proportionally to ``share`` with largest-remainder rounding, so the
        regions always sum to exactly ``capacity`` and every tenant gets at
        least one slot.
        """
        n = len(self.tenants)
        if capacity < n:
            raise ValueError(f"capacity {capacity} < {n} tenants")
        sizes = [0] * n
        free = capacity
        quota_idx = [i for i, t in enumerate(self.tenants)
                     if t.quota is not None]
        elastic = [i for i, t in enumerate(self.tenants) if t.quota is None]
        for k, i in enumerate(quota_idx):
            # reserve one slot for every tenant not yet sized — later quota
            # tenants AND all elastic ones, wherever they appear in the
            # declaration order (the allocation must not depend on order)
            unsized_others = (len(quota_idx) - k - 1) + len(elastic)
            sizes[i] = min(self.tenants[i].quota,
                           max(free - unsized_others, 1))
            free -= sizes[i]
        if elastic:
            total_share = sum(self.tenants[i].share for i in elastic)
            exact = [free * self.tenants[i].share / total_share
                     for i in elastic]
            floors = [max(1, int(x)) for x in exact]
            rem = free - sum(floors)
            # largest fractional remainder first; ties broken by position
            order = sorted(range(len(elastic)),
                           key=lambda j: (-(exact[j] - int(exact[j])), j))
            j = 0
            while rem > 0:
                floors[order[j % len(order)]] += 1
                j += 1
                rem -= 1
            while rem < 0:                 # floors over-shot (tiny regions)
                k = max(range(len(floors)), key=lambda j: floors[j])
                floors[k] -= 1
                rem += 1
            for i, s in zip(elastic, floors):
                sizes[i] = s
        if min(sizes) < 1 or sum(sizes) != capacity:
            raise ValueError(f"bad partition sizes {sizes} for capacity "
                             f"{capacity}")
        starts, acc = [], 0
        for s in sizes:
            starts.append(acc)
            acc += s
        thresholds = tuple(
            NO_OVERRIDE if t.threshold is None else float(t.threshold)
            for t in self.tenants)
        band_lo = tuple(
            NO_OVERRIDE if t.band_lo is None else float(t.band_lo)
            for t in self.tenants)
        return PartitionMap(names=self.names, starts=tuple(starts),
                            sizes=tuple(sizes), thresholds=thresholds,
                            capacity=capacity, band_lo=band_lo)
