"""Load generators over the QA corpus (DESIGN.md §12.4).

Three traffic shapes against any async ``submit(Request) -> Response``:

  * ``run_open_loop``   — open-loop Poisson arrivals at a target QPS:
    requests fire on their arrival clock whether or not earlier ones have
    completed. This is the shape that exposes queueing delay and tail
    latency (closed-loop generators self-throttle and hide both).
  * ``run_closed_loop`` — N concurrent clients, each submitting its next
    request when the previous response lands (think: N chat sessions).
  * ``run_waves``       — lockstep waves of exactly ``wave`` concurrent
    submits. A wave equal to the scheduler's ``max_batch`` reproduces the
    sync engine's batch partitioning exactly, which is what the
    async-vs-sync equivalence checks rely on.

``build_workload`` draws the paper's §3.2 mixture (paraphrases of cached
questions + novel held-out queries) and can inject *duplicate bursts* —
``burst_size`` byte-identical copies of one query back to back — the
thundering-herd pattern in-flight coalescing exists to absorb.
"""
from __future__ import annotations

import asyncio
import dataclasses
import random
import time
from typing import Awaitable, Callable, Sequence

from repro.data.qa_dataset import QAPair, build_test_queries
from repro.serving.engine import Request, Response

Submit = Callable[[Request], Awaitable[Response]]


@dataclasses.dataclass
class LoadResult:
    """One generator run: responses in submission order + throughput."""

    responses: list[Response]
    wall_s: float

    @property
    def achieved_qps(self) -> float:
        return len(self.responses) / self.wall_s if self.wall_s > 0 else 0.0


def build_workload(pairs: Sequence[QAPair], n_requests: int, *,
                   paraphrase_ratio: float = 0.75,
                   burst_prob: float = 0.0, burst_size: int = 4,
                   seed: int = 1) -> list[Request]:
    """Paper-mixture request stream with optional duplicate bursts.

    With probability ``burst_prob`` a drawn query is emitted ``burst_size``
    times consecutively (identical bytes — the strongest coalescing case);
    otherwise once. Exactly ``n_requests`` requests are returned.
    """
    rng = random.Random(seed)
    base = build_test_queries(
        list(pairs), n_per_category=max(1, n_requests // 4 + burst_size),
        paraphrase_ratio=paraphrase_ratio, seed=seed)
    out: list[Request] = []
    i = 0
    while len(out) < n_requests:
        q = base[i % len(base)]
        i += 1
        copies = burst_size if (burst_prob > 0.0
                                and rng.random() < burst_prob) else 1
        req = Request(query=q.query, category=q.category,
                      source_id=q.source_id, semantic_key=q.semantic_key)
        for _ in range(min(copies, n_requests - len(out))):
            out.append(req)
    return out


async def run_open_loop(submit: Submit, requests: Sequence[Request],
                        rate_qps: float, *, seed: int = 0) -> LoadResult:
    """Open-loop Poisson: exponential inter-arrivals at ``rate_qps``."""
    rng = random.Random(seed)
    loop = asyncio.get_running_loop()
    start = loop.time()
    tasks: list[asyncio.Task] = []
    next_t = 0.0
    for req in requests:
        next_t += rng.expovariate(rate_qps)
        delay = start + next_t - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.create_task(submit(req)))
    responses = list(await asyncio.gather(*tasks))
    return LoadResult(responses=responses, wall_s=loop.time() - start)


async def run_closed_loop(submit: Submit, requests: Sequence[Request],
                          *, concurrency: int = 8) -> LoadResult:
    """Closed-loop: ``concurrency`` clients, one outstanding request each."""
    t0 = time.perf_counter()
    responses: list[Response | None] = [None] * len(requests)
    it = iter(range(len(requests)))

    async def client() -> None:
        for i in it:                      # single event loop: next() is safe
            responses[i] = await submit(requests[i])

    await asyncio.gather(*(client() for _ in range(max(1, concurrency))))
    return LoadResult(responses=list(responses),
                      wall_s=time.perf_counter() - t0)


async def run_waves(submit: Submit, requests: Sequence[Request],
                    *, wave: int) -> LoadResult:
    """Lockstep waves of ``wave`` concurrent submits (sync-batch analogue)."""
    t0 = time.perf_counter()
    responses: list[Response] = []
    for i in range(0, len(requests), wave):
        chunk = requests[i:i + wave]
        responses.extend(await asyncio.gather(*(submit(r) for r in chunk)))
    return LoadResult(responses=responses, wall_s=time.perf_counter() - t0)
