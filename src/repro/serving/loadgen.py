"""Load generators over the QA corpus (DESIGN.md §12.4).

Three traffic shapes against any async ``submit(Request) -> Response``:

  * ``run_open_loop``   — open-loop Poisson arrivals at a target QPS:
    requests fire on their arrival clock whether or not earlier ones have
    completed. This is the shape that exposes queueing delay and tail
    latency (closed-loop generators self-throttle and hide both).
  * ``run_closed_loop`` — N concurrent clients, each submitting its next
    request when the previous response lands (think: N chat sessions).
  * ``run_waves``       — lockstep waves of exactly ``wave`` concurrent
    submits. A wave equal to the scheduler's ``max_batch`` reproduces the
    sync engine's batch partitioning exactly, which is what the
    async-vs-sync equivalence checks rely on.

``build_workload`` draws the paper's §3.2 mixture (paraphrases of cached
questions + novel held-out queries) and can inject *duplicate bursts* —
``burst_size`` byte-identical copies of one query back to back — the
thundering-herd pattern in-flight coalescing exists to absorb.

``build_multi_tenant_workload`` (DESIGN.md §13.4) interleaves per-tenant
request streams with Zipf-skewed tenant popularity. Every tenant's stream
is drawn from its **own** ``random.Random`` seeded from ``(seed, tenant)``
— stable hashing, not Python's salted ``hash()`` — so adding or removing a
tenant never perturbs another tenant's request sequence: A/B runs that
differ only in the tenant set stay comparable per tenant.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import random
import time
from typing import Awaitable, Callable, Sequence

from repro.data.qa_dataset import QAPair, build_test_queries
from repro.serving.engine import Request, Response

Submit = Callable[[Request], Awaitable[Response]]


def tenant_rng(seed: int, tenant: str) -> random.Random:
    """A ``random.Random`` stream owned by ``(seed, tenant)``.

    The derivation is a stable SHA-256 of both, NOT ``hash()`` (which is
    salted per process): the same (seed, tenant) yields the same stream in
    every run, on every host, regardless of which other tenants exist.
    """
    digest = hashlib.sha256(f"{seed}\x1f{tenant}".encode()).digest()
    return random.Random(int.from_bytes(digest[:8], "big"))


def zipf_weights(n: int, skew: float = 1.0) -> list[float]:
    """Normalized Zipf popularity: weight of rank-i tenant ∝ 1/(i+1)^skew.
    ``skew=0`` is uniform; larger = one tenant dominates (the noisy-
    neighbour regime the DRR admission exists for)."""
    raw = [1.0 / (i + 1) ** skew for i in range(n)]
    total = sum(raw)
    return [w / total for w in raw]


@dataclasses.dataclass
class LoadResult:
    """One generator run: responses in submission order + throughput."""

    responses: list[Response]
    wall_s: float

    @property
    def achieved_qps(self) -> float:
        return len(self.responses) / self.wall_s if self.wall_s > 0 else 0.0


def build_workload(pairs: Sequence[QAPair], n_requests: int, *,
                   paraphrase_ratio: float = 0.75,
                   burst_prob: float = 0.0, burst_size: int = 4,
                   seed: int = 1) -> list[Request]:
    """Paper-mixture request stream with optional duplicate bursts.

    With probability ``burst_prob`` a drawn query is emitted ``burst_size``
    times consecutively (identical bytes — the strongest coalescing case);
    otherwise once. Exactly ``n_requests`` requests are returned.
    """
    rng = random.Random(seed)
    base = build_test_queries(
        list(pairs), n_per_category=max(1, n_requests // 4 + burst_size),
        paraphrase_ratio=paraphrase_ratio, seed=seed)
    out: list[Request] = []
    i = 0
    while len(out) < n_requests:
        q = base[i % len(base)]
        i += 1
        copies = burst_size if (burst_prob > 0.0
                                and rng.random() < burst_prob) else 1
        req = Request(query=q.query, category=q.category,
                      source_id=q.source_id, semantic_key=q.semantic_key)
        for _ in range(min(copies, n_requests - len(out))):
            out.append(req)
    return out


def build_multi_tenant_workload(
        pairs: Sequence[QAPair], n_requests: int, *,
        tenants: Sequence[str], skew: float = 1.0,
        paraphrase_ratio: float = 0.75,
        burst_prob: float = 0.0, burst_size: int = 4,
        seed: int = 1) -> list[Request]:
    """Zipf-skewed multi-tenant request stream (DESIGN.md §13.4).

    Tenant popularity follows ``zipf_weights(len(tenants), skew)`` in the
    order given (first tenant = heaviest). Each tenant draws its own
    paper-mixture stream — paraphrase choices, burst rolls and query
    sequence all come from ``tenant_rng(seed, tenant)`` — and a separate
    interleaving stream picks which tenant emits next. Consequences:

      * tenant T's request *sequence* is a pure function of
        (seed, T, n_requests): adding tenant C to an {A, B} run leaves A's
        and B's sequences byte-identical (only the interleaving changes);
      * duplicate bursts stay within one tenant — cross-tenant duplicates
        are never coalescable anyway (the key is (tenant, query)).
    """
    if not tenants:
        raise ValueError("need at least one tenant")
    weights = zipf_weights(len(tenants), skew)
    pick = random.Random(seed)               # interleaving stream only
    streams = {}
    for t in tenants:
        rng = tenant_rng(seed, t)
        base = build_test_queries(
            list(pairs),
            n_per_category=max(1, n_requests // 4 + burst_size),
            paraphrase_ratio=paraphrase_ratio,
            seed=rng.randrange(2 ** 31))
        streams[t] = {"rng": rng, "base": base, "i": 0, "carry": []}
    out: list[Request] = []
    while len(out) < n_requests:
        (t,) = pick.choices(tenants, weights=weights)
        s = streams[t]
        if not s["carry"]:
            q = s["base"][s["i"] % len(s["base"])]
            s["i"] += 1
            copies = burst_size if (burst_prob > 0.0 and
                                    s["rng"].random() < burst_prob) else 1
            req = Request(query=q.query, category=q.category,
                          source_id=q.source_id,
                          semantic_key=q.semantic_key, tenant=t)
            s["carry"] = [req] * copies
        out.append(s["carry"].pop())
    return out


async def run_open_loop(submit: Submit, requests: Sequence[Request],
                        rate_qps: float, *, seed: int = 0) -> LoadResult:
    """Open-loop Poisson: exponential inter-arrivals at ``rate_qps``."""
    rng = random.Random(seed)
    loop = asyncio.get_running_loop()
    start = loop.time()
    tasks: list[asyncio.Task] = []
    next_t = 0.0
    for req in requests:
        next_t += rng.expovariate(rate_qps)
        delay = start + next_t - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.create_task(submit(req)))
    responses = list(await asyncio.gather(*tasks))
    return LoadResult(responses=responses, wall_s=loop.time() - start)


async def run_closed_loop(submit: Submit, requests: Sequence[Request],
                          *, concurrency: int = 8) -> LoadResult:
    """Closed-loop: ``concurrency`` clients, one outstanding request each."""
    t0 = time.perf_counter()
    responses: list[Response | None] = [None] * len(requests)
    it = iter(range(len(requests)))

    async def client() -> None:
        for i in it:                      # single event loop: next() is safe
            responses[i] = await submit(requests[i])

    await asyncio.gather(*(client() for _ in range(max(1, concurrency))))
    return LoadResult(responses=list(responses),
                      wall_s=time.perf_counter() - t0)


async def run_waves(submit: Submit, requests: Sequence[Request],
                    *, wave: int) -> LoadResult:
    """Lockstep waves of ``wave`` concurrent submits (sync-batch analogue)."""
    t0 = time.perf_counter()
    responses: list[Response] = []
    for i in range(0, len(requests), wave):
        chunk = requests[i:i + wave]
        responses.extend(await asyncio.gather(*(submit(r) for r in chunk)))
    return LoadResult(responses=responses, wall_s=time.perf_counter() - t0)
