"""Load generators over the QA corpus (DESIGN.md §12.4).

Three traffic shapes against any async ``submit(Request) -> Response``:

  * ``run_open_loop``   — open-loop Poisson arrivals at a target QPS:
    requests fire on their arrival clock whether or not earlier ones have
    completed. This is the shape that exposes queueing delay and tail
    latency (closed-loop generators self-throttle and hide both).
  * ``run_closed_loop`` — N concurrent clients, each submitting its next
    request when the previous response lands (think: N chat sessions).
  * ``run_waves``       — lockstep waves of exactly ``wave`` concurrent
    submits. A wave equal to the scheduler's ``max_batch`` reproduces the
    sync engine's batch partitioning exactly, which is what the
    async-vs-sync equivalence checks rely on.

``build_workload`` draws the paper's §3.2 mixture (paraphrases of cached
questions + novel held-out queries) and can inject *duplicate bursts* —
``burst_size`` byte-identical copies of one query back to back — the
thundering-herd pattern in-flight coalescing exists to absorb.

``build_multi_turn_workload`` (DESIGN.md §16.6) builds *conversations* —
per-session turn sequences whose follow-up turns ("what about the second
option?") are elliptical: meaningless in isolation, resolvable only
against the session's prior turns. Conversations come in recording/replay
pairs sharing one dialogue state with differently-phrased follow-ups, so a
context-fused cache converts the replay's follow-ups into hits while a
stateless cache *cannot* (the raw texts are below threshold). Serve them
with ``turn_levels`` (sync) or ``run_sessions`` (async) — both keep each
session's turns strictly ordered.

``build_multi_tenant_workload`` (DESIGN.md §13.4) interleaves per-tenant
request streams with Zipf-skewed tenant popularity. Every tenant's stream
is drawn from its **own** ``random.Random`` seeded from ``(seed, tenant)``
— stable hashing, not Python's salted ``hash()`` — so adding or removing a
tenant never perturbs another tenant's request sequence: A/B runs that
differ only in the tenant set stay comparable per tenant.
"""
from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import random
import time
from typing import Awaitable, Callable, Sequence

from repro.data.qa_dataset import QAPair, build_test_queries
from repro.serving.engine import Request, Response

Submit = Callable[[Request], Awaitable[Response]]


def tenant_rng(seed: int, tenant: str) -> random.Random:
    """A ``random.Random`` stream owned by ``(seed, tenant)``.

    The derivation is a stable SHA-256 of both, NOT ``hash()`` (which is
    salted per process): the same (seed, tenant) yields the same stream in
    every run, on every host, regardless of which other tenants exist.
    """
    digest = hashlib.sha256(f"{seed}\x1f{tenant}".encode()).digest()
    return random.Random(int.from_bytes(digest[:8], "big"))


def zipf_weights(n: int, skew: float = 1.0) -> list[float]:
    """Normalized Zipf popularity: weight of rank-i tenant ∝ 1/(i+1)^skew.
    ``skew=0`` is uniform; larger = one tenant dominates (the noisy-
    neighbour regime the DRR admission exists for)."""
    raw = [1.0 / (i + 1) ** skew for i in range(n)]
    total = sum(raw)
    return [w / total for w in raw]


@dataclasses.dataclass
class LoadResult:
    """One generator run: responses in submission order + throughput."""

    responses: list[Response]
    wall_s: float

    @property
    def achieved_qps(self) -> float:
        return len(self.responses) / self.wall_s if self.wall_s > 0 else 0.0


def availability(responses: Sequence[object]) -> float:
    """Fraction of a run's slots answered with a usable response.

    Under fault injection (DESIGN.md §20) a generator run with
    ``return_exceptions=True`` yields a mix of ``Response`` objects,
    per-row ``BackendError`` / ``Overloaded`` exceptions, and failed
    ``Response`` rows carrying ``error``. A slot counts as available iff
    it holds a ``Response`` with no error — degraded responses count (the
    caller got an answer; that is the point of degraded serving)."""
    if not responses:
        return 0.0
    ok = sum(1 for r in responses
             if isinstance(r, Response) and not r.error)
    return ok / len(responses)


def build_workload(pairs: Sequence[QAPair], n_requests: int, *,
                   paraphrase_ratio: float = 0.75,
                   burst_prob: float = 0.0, burst_size: int = 4,
                   seed: int = 1) -> list[Request]:
    """Paper-mixture request stream with optional duplicate bursts.

    With probability ``burst_prob`` a drawn query is emitted ``burst_size``
    times consecutively (identical bytes — the strongest coalescing case);
    otherwise once. Exactly ``n_requests`` requests are returned.
    """
    rng = random.Random(seed)
    base = build_test_queries(
        list(pairs), n_per_category=max(1, n_requests // 4 + burst_size),
        paraphrase_ratio=paraphrase_ratio, seed=seed)
    out: list[Request] = []
    i = 0
    while len(out) < n_requests:
        q = base[i % len(base)]
        i += 1
        copies = burst_size if (burst_prob > 0.0
                                and rng.random() < burst_prob) else 1
        req = Request(query=q.query, category=q.category,
                      source_id=q.source_id, semantic_key=q.semantic_key)
        for _ in range(min(copies, n_requests - len(out))):
            out.append(req)
    return out


#: Elliptical follow-up phrasings. The *recording* conversation of a group
#: uses set A; its *replay* uses set B with the same entity — close enough
#: in meaning that the replay should reuse the recording's cached answer,
#: far enough in surface form that raw (unfused) embeddings score below
#: the hit threshold. Entity-bearing, ~half-overlapping token sets.
FOLLOWUP_TEMPLATES_A = (
    "what about {e}",
    "and for {e}",
    "does that also apply to {e}",
    "what happens with {e}",
)
FOLLOWUP_TEMPLATES_B = (
    "how about {e} then",
    "would it be different for {e}",
    "would the same hold for {e}",
    "and if we consider {e} instead",
)
#: Entity pool for follow-up ellipses. Entities are handed out WITHOUT
#: replacement across the whole workload (never reused between groups or
#: turns), so every follow-up's raw text is globally unique — the
#: "0 stateless hits" claim needs no luck. Content words are pairwise
#: distinct so same-template different-entity texts stay far apart.
FOLLOWUP_ENTITIES = (
    "the second option", "smaller models", "the free tier",
    "windows machines", "larger batches", "the older version",
    "mobile devices", "the enterprise plan", "overnight jobs",
    "first-time users", "the european region", "legacy hardware",
    "rate limits", "open source forks", "the command line",
    "older browsers", "the staging environment", "third party plugins",
    "long documents", "low memory phones", "the dark theme",
    "weekend traffic", "the python client", "cold starts",
    "encrypted backups", "the beta channel", "offline mode",
    "slow networks", "the admin console", "spot instances",
    "the audit log", "streaming responses",
)

#: Synthetic source-id space for follow-up turns, far above real qa_ids.
_CTX_SID_BASE = 1_000_000


def followup_source_id(base_qa_id: int, turn: int) -> int:
    """Ground-truth id of one dialogue state's turn-``turn`` answer."""
    return _CTX_SID_BASE + base_qa_id * 32 + turn


def build_multi_turn_workload(
        pairs: Sequence[QAPair], n_groups: int, *, turns: int = 3,
        tenants: Sequence[str] | None = None,
        seed: int = 1) -> list[list[Request]]:
    """Recording/replay conversation pairs (DESIGN.md §16.6).

    Returns ``2 * n_groups`` conversations of ``turns`` turns each. Group
    ``g`` is one *dialogue state* served twice:

      * the **recording** (session ``s{seed}-{g}r``): turn 0 asks a base
        corpus question verbatim (category ``ctx/open``); follow-ups are
        set-A ellipses over per-turn entities (``ctx/followup``). All of
        these miss a cold cache and populate it.
      * the **replay** (session ``s{seed}-{g}p``): turn 0 repeats the
        identical opening text (``ctx/open_repeat`` — a hit with or
        without fusion, and it reconstructs the same context window);
        follow-ups re-ask the *same* entities through set-B phrasings
        (``ctx/followup_repeat``). These are the measured rows: their raw
        texts score below threshold against everything cached, but their
        *fused* keys match the recording's fused follow-up keys.

    Recording and replay follow-ups share ``followup_source_id`` and a
    ``ctx|…`` semantic key, so the ground-truth judge scores replay hits
    exactly like paraphrase hits in the stateless workload. Each group
    draws a distinct base question, and entities are assigned WITHOUT
    replacement across the workload — every follow-up's raw text is
    globally unique, so a stateless cache serves **zero**
    ``ctx/followup_repeat`` hits (and zero false ones).

    Ordering contract: the returned list is ``recordings + replays``
    (first ``n_groups`` conversations are the recordings). Serve ALL
    recordings before any replay — a replay's hits depend on the
    recording's inserts ("record first, then replay"). ``turn_levels``
    each half separately for the sync engine, or ``run_sessions`` the
    halves in sequence for the async scheduler.
    """
    if n_groups < 1 or turns < 2:
        raise ValueError("need n_groups >= 1 and turns >= 2")
    if n_groups > len(pairs):
        raise ValueError(f"need {n_groups} distinct base questions but the "
                         f"corpus has {len(pairs)}")
    n_entities = n_groups * (turns - 1)
    if n_entities > len(FOLLOWUP_ENTITIES):
        raise ValueError(
            f"{n_groups} groups x {turns - 1} follow-ups need {n_entities} "
            f"distinct entities but the pool has {len(FOLLOWUP_ENTITIES)}; "
            "fewer groups/turns (or grow FOLLOWUP_ENTITIES)")
    rng = random.Random(seed)
    bases = rng.sample(list(pairs), n_groups)
    entity_deck = rng.sample(FOLLOWUP_ENTITIES, n_entities)
    recordings: list[list[Request]] = []
    replays: list[list[Request]] = []
    for g, base in enumerate(bases):
        tenant = tenants[g % len(tenants)] if tenants else "default"
        grng = tenant_rng(seed, f"ctx-group-{g}")
        entities = entity_deck[g * (turns - 1):(g + 1) * (turns - 1)]
        ta = [grng.randrange(len(FOLLOWUP_TEMPLATES_A))
              for _ in range(turns - 1)]
        tb = [grng.randrange(len(FOLLOWUP_TEMPLATES_B))
              for _ in range(turns - 1)]
        for out, sess_suffix, open_cat, follow_cat, templates, tidx in (
                (recordings, "r", "ctx/open", "ctx/followup",
                 FOLLOWUP_TEMPLATES_A, ta),
                (replays, "p", "ctx/open_repeat", "ctx/followup_repeat",
                 FOLLOWUP_TEMPLATES_B, tb)):
            session = f"s{seed}-{g}{sess_suffix}"
            conv = [Request(query=base.question, category=open_cat,
                            source_id=base.qa_id,
                            semantic_key=base.semantic_key,
                            tenant=tenant, session=session)]
            for t in range(1, turns):
                e = entities[t - 1]
                conv.append(Request(
                    query=templates[tidx[t - 1]].format(e=e),
                    category=follow_cat,
                    source_id=followup_source_id(base.qa_id, t),
                    semantic_key=f"ctx|{base.semantic_key}|{t}|{e}",
                    tenant=tenant, session=session))
            out.append(conv)
    return recordings + replays


def turn_levels(conversations: Sequence[Sequence[Request]]
                ) -> list[list[Request]]:
    """Transpose conversations into turn levels for the sync engine.

    Level ``k`` holds every conversation's ``k``-th turn. Serving each
    level as its own ``process()`` call guarantees a session's turn ``k``
    is appended to its window before turn ``k+1`` is looked up — two turns
    of one session co-batched would not see each other (§16.1).
    """
    depth = max((len(c) for c in conversations), default=0)
    return [[c[k] for c in conversations if k < len(c)]
            for k in range(depth)]


async def run_sessions(submit: Submit,
                       conversations: Sequence[Sequence[Request]],
                       *, concurrency: int = 8) -> LoadResult:
    """Closed-loop over conversations: each client plays whole
    conversations, awaiting every turn before submitting the next — the
    ordering contract sessions require (a turn's window must contain the
    previous turn). Responses come back in conversation-major turn order.
    """
    t0 = time.perf_counter()
    responses: dict[tuple[int, int], Response] = {}
    it = iter(range(len(conversations)))

    async def client() -> None:
        for ci in it:                     # single event loop: next() is safe
            for ti, req in enumerate(conversations[ci]):
                responses[(ci, ti)] = await submit(req)

    await asyncio.gather(*(client() for _ in range(max(1, concurrency))))
    ordered = [responses[k] for k in sorted(responses)]
    return LoadResult(responses=ordered, wall_s=time.perf_counter() - t0)


def build_multi_tenant_workload(
        pairs: Sequence[QAPair], n_requests: int, *,
        tenants: Sequence[str], skew: float = 1.0,
        paraphrase_ratio: float = 0.75,
        burst_prob: float = 0.0, burst_size: int = 4,
        seed: int = 1) -> list[Request]:
    """Zipf-skewed multi-tenant request stream (DESIGN.md §13.4).

    Tenant popularity follows ``zipf_weights(len(tenants), skew)`` in the
    order given (first tenant = heaviest). Each tenant draws its own
    paper-mixture stream — paraphrase choices, burst rolls and query
    sequence all come from ``tenant_rng(seed, tenant)`` — and a separate
    interleaving stream picks which tenant emits next. Consequences:

      * tenant T's request *sequence* is a pure function of
        (seed, T, n_requests): adding tenant C to an {A, B} run leaves A's
        and B's sequences byte-identical (only the interleaving changes);
      * duplicate bursts stay within one tenant — cross-tenant duplicates
        are never coalescable anyway (the key is (tenant, query)).
    """
    if not tenants:
        raise ValueError("need at least one tenant")
    weights = zipf_weights(len(tenants), skew)
    pick = random.Random(seed)               # interleaving stream only
    streams = {}
    for t in tenants:
        rng = tenant_rng(seed, t)
        base = build_test_queries(
            list(pairs),
            n_per_category=max(1, n_requests // 4 + burst_size),
            paraphrase_ratio=paraphrase_ratio,
            seed=rng.randrange(2 ** 31))
        streams[t] = {"rng": rng, "base": base, "i": 0, "carry": []}
    out: list[Request] = []
    while len(out) < n_requests:
        (t,) = pick.choices(tenants, weights=weights)
        s = streams[t]
        if not s["carry"]:
            q = s["base"][s["i"] % len(s["base"])]
            s["i"] += 1
            copies = burst_size if (burst_prob > 0.0 and
                                    s["rng"].random() < burst_prob) else 1
            req = Request(query=q.query, category=q.category,
                          source_id=q.source_id,
                          semantic_key=q.semantic_key, tenant=t)
            s["carry"] = [req] * copies
        out.append(s["carry"].pop())
    return out


async def run_open_loop(submit: Submit, requests: Sequence[Request],
                        rate_qps: float, *, seed: int = 0,
                        return_exceptions: bool = False) -> LoadResult:
    """Open-loop Poisson: exponential inter-arrivals at ``rate_qps``.

    ``return_exceptions=True`` (fault-injection runs, §20) keeps failed
    submits — shed ``Overloaded`` rejections, per-row backend errors — in
    the response list as exception objects instead of aborting the run;
    score the result with ``availability``."""
    rng = random.Random(seed)
    loop = asyncio.get_running_loop()
    start = loop.time()
    tasks: list[asyncio.Task] = []
    next_t = 0.0
    for req in requests:
        next_t += rng.expovariate(rate_qps)
        delay = start + next_t - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.create_task(submit(req)))
    responses = list(await asyncio.gather(
        *tasks, return_exceptions=return_exceptions))
    return LoadResult(responses=responses, wall_s=loop.time() - start)


async def run_closed_loop(submit: Submit, requests: Sequence[Request],
                          *, concurrency: int = 8) -> LoadResult:
    """Closed-loop: ``concurrency`` clients, one outstanding request each."""
    t0 = time.perf_counter()
    responses: list[Response | None] = [None] * len(requests)
    it = iter(range(len(requests)))

    async def client() -> None:
        for i in it:                      # single event loop: next() is safe
            responses[i] = await submit(requests[i])

    await asyncio.gather(*(client() for _ in range(max(1, concurrency))))
    return LoadResult(responses=list(responses),
                      wall_s=time.perf_counter() - t0)


async def run_waves(submit: Submit, requests: Sequence[Request],
                    *, wave: int,
                    return_exceptions: bool = False) -> LoadResult:
    """Lockstep waves of ``wave`` concurrent submits (sync-batch analogue).

    ``return_exceptions=True`` keeps per-slot failures in the response
    list (see ``run_open_loop``); lockstep waves plus a deterministic
    fault schedule keyed by backend call index make chaos runs exactly
    reproducible — the same requests land in the same batches, so the
    same calls hit the same fault windows every run (§20.1)."""
    t0 = time.perf_counter()
    responses: list[Response] = []
    for i in range(0, len(requests), wave):
        chunk = requests[i:i + wave]
        responses.extend(await asyncio.gather(
            *(submit(r) for r in chunk),
            return_exceptions=return_exceptions))
    return LoadResult(responses=responses, wall_s=time.perf_counter() - t0)
