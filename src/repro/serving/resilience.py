"""Resilient serving: fault injection, retries, breaker, degraded mode.

The paper's premise is that the cache shields users from the slow, expensive
LLM API — so the cache is exactly the asset that should keep answering when
the backend browns out. This module is the §20 fault layer (DESIGN.md §20):

``FaultyBackend``
    Deterministic, seedable fault schedules — error / timeout / latency-spike
    / brownout windows — over any backend. Windows are keyed by the wrapped
    backend's *call index* (the Nth ``generate()`` call), not wall-clock, so
    tests, loadgen, and the serve_bench chaos stage replay bit-identically.

``RetryPolicy``
    Exponential backoff with deterministic (hash-derived) jitter, bounded by
    the per-request deadline budget carried on ``Request.deadline_ms`` and
    the TCP wire: a retry whose backoff would overrun the caller's remaining
    SLO is not attempted.

``CircuitBreaker``
    closed → open on consecutive-failure or windowed error-rate trip →
    half-open probes after a cooldown → closed on probe success. While open,
    calls are short-circuited without touching the backend.

``ResilienceConfig``
    The bundle the engine takes (``CachedEngine(resilience=...)``). When the
    breaker is open, the budget is exhausted, or retries are spent, the
    engine re-routes failed miss rows through the band/synthesis machinery
    with a relaxed ``degraded_band_lo`` floor: serve the best cached
    neighbour, flag ``Response.degraded=True``, and never admit the answer
    to the slab (DESIGN.md §20.4).

``Overloaded``
    The explicit load-shed rejection raised by the scheduler when
    ``SchedulerConfig.overload_policy == "shed"`` and the queue is full —
    bounded queues instead of unbounded growth.

Everything here is additive: with ``resilience=None`` and no faults injected
the engine/scheduler byte-for-byte reproduce pre-§20 behaviour.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Sequence

from repro.serving.llm_backend import (BackendError, BackendResult,
                                       BackendTimeout, BackendUnavailable)

__all__ = [
    "FaultWindow", "FaultSchedule", "FaultyBackend",
    "RetryPolicy", "CircuitBreaker", "ResilienceConfig",
    "Overloaded", "BackendError", "BackendUnavailable", "BackendTimeout",
]


class Overloaded(RuntimeError):
    """Explicit load-shed rejection: the queue is full and the scheduler's
    ``overload_policy`` is ``"shed"``. The caller should back off; nothing
    was enqueued."""


def _hash_fraction(*parts: object) -> float:
    """Deterministic uniform [0, 1) from the given parts (no RNG state)."""
    h = hashlib.sha256("\x1f".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


# ---------------------------------------------------------------------------
# Fault injection
# ---------------------------------------------------------------------------

_FAULT_KINDS = ("error", "timeout", "latency_spike", "brownout")


@dataclasses.dataclass(frozen=True)
class FaultWindow:
    """One fault window over backend call indexes ``[start, stop)``.

    Kinds (DESIGN.md §20.1):
      - ``error``: every call in the window raises ``BackendUnavailable``.
      - ``timeout``: every call raises ``BackendTimeout`` (semantically the
        call consumed its budget before failing).
      - ``latency_spike``: calls succeed but carry ``extra_latency_s`` more
        reported (and, for blocking backends, slept) latency.
      - ``brownout``: each call fails with probability ``error_rate`` under
        a per-index deterministic coin — partial outage.
    """
    kind: str
    start: int
    stop: int
    error_rate: float = 1.0
    extra_latency_s: float = 0.0

    def __post_init__(self):
        if self.kind not in _FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_FAULT_KINDS}")
        if self.stop <= self.start:
            raise ValueError(f"empty fault window [{self.start}, {self.stop})")
        if not 0.0 <= self.error_rate <= 1.0:
            raise ValueError(f"error_rate must be in [0, 1], got {self.error_rate}")
        if self.extra_latency_s < 0.0:
            raise ValueError("extra_latency_s must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded set of fault windows over backend call indexes.

    ``fault_at(index)`` returns the window that fires for the given call
    index, or None. Brownout windows flip a per-(seed, index) hash coin, so
    the same schedule replayed over the same call sequence injects exactly
    the same faults — no RNG state, no wall-clock.
    """
    windows: tuple[FaultWindow, ...] = ()
    seed: int = 0

    def __init__(self, windows: Sequence[FaultWindow] = (), seed: int = 0):
        object.__setattr__(self, "windows", tuple(windows))
        object.__setattr__(self, "seed", seed)

    def fault_at(self, index: int) -> FaultWindow | None:
        for w in self.windows:
            if not (w.start <= index < w.stop):
                continue
            if w.kind == "brownout" and w.error_rate < 1.0:
                if _hash_fraction(self.seed, index) >= w.error_rate:
                    continue
            return w
        return None


class FaultyBackend:
    """Wrap any backend with a deterministic fault schedule.

    The wrapper keeps its own ``calls_started`` counter (one per
    ``generate()`` invocation, including ones that fault before reaching the
    inner backend) as the schedule key; every other attribute — including
    ``latency_per_call_s`` / ``cost_per_call_usd`` that the engine's
    per-query accounting probes, and the inner ``calls`` counter — delegates
    to the wrapped backend, so the wrapper is drop-in anywhere a backend is
    accepted.
    """

    def __init__(self, inner, schedule: FaultSchedule):
        self.inner = inner
        self.schedule = schedule
        self.calls_started = 0
        self.faults_injected = 0

    def __getattr__(self, name):
        # only reached for names not set on the wrapper itself
        return getattr(self.inner, name)

    def generate(self, queries: Sequence[str],
                 semantic_keys: Sequence[str] | None = None) -> BackendResult:
        idx = self.calls_started
        self.calls_started += 1
        w = self.schedule.fault_at(idx)
        if w is None or w.kind == "latency_spike":
            result = self.inner.generate(queries, semantic_keys)
            if w is not None:
                if getattr(self.inner, "block", False):
                    time.sleep(w.extra_latency_s)
                result = dataclasses.replace(
                    result, latency_s=result.latency_s + w.extra_latency_s)
            return result
        self.faults_injected += 1
        detail = f"call {idx} in window [{w.start}, {w.stop})"
        if w.kind == "timeout":
            raise BackendTimeout(f"injected timeout: {detail}")
        raise BackendUnavailable(f"injected {w.kind}: {detail}")


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter and deadline budgets.

    ``backoff_s(attempt, key=...)`` is a pure function of (policy, attempt,
    key): base · multiplier^(attempt-1), capped, then jittered by a
    hash-derived factor in [1-jitter, 1+jitter] — no RNG state, so retry
    timing replays exactly. ``allows`` enforces both the attempt cap and the
    deadline budget: a retry is only attempted if the elapsed time *plus the
    next backoff* still fits inside the caller's remaining SLO, so retries
    can never overrun ``Request.deadline_ms`` (DESIGN.md §20.3).
    """
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff bounds must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def backoff_s(self, attempt: int, key: str = "") -> float:
        base = min(self.base_backoff_s * self.multiplier ** max(attempt - 1, 0),
                   self.max_backoff_s)
        if self.jitter <= 0.0:
            return base
        frac = _hash_fraction(self.seed, key, attempt)      # [0, 1)
        return base * (1.0 + self.jitter * (2.0 * frac - 1.0))

    def allows(self, attempt: int, *, elapsed_s: float,
               next_backoff_s: float, budget_s: float | None = None) -> bool:
        """May attempt ``attempt + 1`` start after sleeping ``next_backoff_s``?"""
        if attempt >= self.max_attempts:
            return False
        if budget_s is not None and elapsed_s + next_backoff_s >= budget_s:
            return False
        return True


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """closed → open → half-open → closed state machine (DESIGN.md §20.3).

    Trips (closed → open) on either ``failure_threshold`` consecutive
    failures or a windowed error rate ≥ ``error_rate_threshold`` over the
    last ``window`` outcomes (only once the window is full, so a single
    early failure cannot trip it). While open, ``allow()`` short-circuits
    until ``cooldown_s`` has elapsed on the injected ``clock``; then the
    breaker goes half-open and admits up to ``half_open_probes`` probe
    calls. All probes succeeding closes the breaker (a recovery); any probe
    failing re-opens it (another trip).
    """

    def __init__(self, *, failure_threshold: int = 5, window: int = 16,
                 error_rate_threshold: float = 0.5, cooldown_s: float = 5.0,
                 half_open_probes: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < error_rate_threshold <= 1.0:
            raise ValueError("error_rate_threshold must be in (0, 1]")
        if half_open_probes < 1:
            raise ValueError("half_open_probes must be >= 1")
        self.failure_threshold = failure_threshold
        self.window = window
        self.error_rate_threshold = error_rate_threshold
        self.cooldown_s = cooldown_s
        self.half_open_probes = half_open_probes
        self.clock = clock
        self.state = "closed"
        self.trips = 0
        self.recoveries = 0
        self.short_circuits = 0
        self._consecutive = 0
        self._recent: list[bool] = []        # True = failure, last `window`
        self._opened_at = 0.0
        self._probes_admitted = 0
        self._probe_successes = 0

    def _trip(self) -> None:
        self.state = "open"
        self.trips += 1
        self._opened_at = self.clock()
        self._consecutive = 0
        self._recent.clear()
        self._probes_admitted = 0
        self._probe_successes = 0

    def allow(self) -> bool:
        """May the caller attempt a backend call right now?"""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self._opened_at >= self.cooldown_s:
                self.state = "half_open"
                self._probes_admitted = 0
                self._probe_successes = 0
            else:
                self.short_circuits += 1
                return False
        # half-open: admit a bounded number of probes
        if self._probes_admitted < self.half_open_probes:
            self._probes_admitted += 1
            return True
        self.short_circuits += 1
        return False

    def record_success(self) -> None:
        if self.state == "half_open":
            self._probe_successes += 1
            if self._probe_successes >= self.half_open_probes:
                self.state = "closed"
                self.recoveries += 1
                self._consecutive = 0
                self._recent.clear()
            return
        if self.state == "closed":
            self._consecutive = 0
            self._recent.append(False)
            del self._recent[:-self.window]

    def record_failure(self) -> None:
        if self.state == "half_open":
            self._trip()
            return
        if self.state != "closed":
            return
        self._consecutive += 1
        self._recent.append(True)
        del self._recent[:-self.window]
        if self._consecutive >= self.failure_threshold:
            self._trip()
        elif (len(self._recent) >= self.window
              and sum(self._recent) / len(self._recent)
              >= self.error_rate_threshold):
            self._trip()


# ---------------------------------------------------------------------------
# Engine-facing bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResilienceConfig:
    """Everything the engine's miss path needs to survive a faulty backend.

    ``degraded_band_lo=None`` defers the degraded floor to the band policy's
    ``degraded_lo`` (if a ``BandPolicy`` with one is installed), else 0.55.
    ``sleep``/``clock`` are injectable so tests and the serve_bench chaos
    stage run retry schedules without real wall-clock sleeps.
    """
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    breaker: CircuitBreaker | None = dataclasses.field(
        default_factory=CircuitBreaker)
    degraded_serving: bool = True
    degraded_band_lo: float | None = None
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        if self.degraded_band_lo is not None and not (
                0.0 <= self.degraded_band_lo <= 1.0):
            raise ValueError("degraded_band_lo must be in [0, 1]")
