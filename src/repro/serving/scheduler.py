"""Async continuous micro-batch scheduler with in-flight coalescing.

The paper measures one synchronous batch at a time (§2.5, Figures 2–4);
production traffic is *concurrent*. This module is the admission layer in
front of ``CachedEngine`` (DESIGN.md §12): requests arrive on an asyncio
event loop, wait in a bounded FIFO queue, and are flushed to the engine's
``serve_batch`` as micro-batches — on ``max_batch`` occupancy or on the
oldest request's ``max_wait_ms`` deadline, whichever comes first.

**In-flight coalescing** (DESIGN.md §12.3): concurrent requests with the
same semantic key (exact query string today; embedding-similarity
coalescing is a ROADMAP follow-up) attach as *waiters* to the one pending
entry — queued or already dispatched to the backend — so a thundering herd
of N identical misses costs ONE LLM call instead of N. Without a semantic
cache in front, this is the classic request-dedup proxy; with one, it
closes the window the paper leaves open between "first miss starts
generating" and "response is inserted", during which every duplicate would
also miss.

Invariants (tested in ``tests/test_scheduler.py``):
  * admission order is FIFO — a flush always takes the oldest entries,
    hence the oldest deadlines;
  * a full queue never deadlocks submitters: it forces an immediate
    oldest-deadline flush (backpressure, §12.2);
  * at most one ``serve_batch`` runs at a time (single-worker executor —
    the engine's runtime is owned linearly), while the event loop stays
    free to accept and coalesce new arrivals;
  * every accepted request's future is resolved exactly once, also on
    backend failure and on ``stop()`` (drain).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

from repro.serving.engine import CachedEngine, Request, Response


def coalesce_key(request: Request) -> str:
    """Semantic identity for in-flight dedup: exact query text (the
    embedding-similarity upgrade is named in ROADMAP open items)."""
    return request.query


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission-control knobs (DESIGN.md §12.2)."""

    max_batch: int = 32        # flush when this many requests are queued ...
    max_wait_ms: float = 5.0   # ... or when the oldest one has waited this long
    max_queue: int = 1024      # bounded queue; full -> immediate flush
    coalesce: bool = True      # in-flight duplicate merging (§12.3)

    def __post_init__(self):
        if self.max_batch <= 0 or self.max_queue <= 0:
            raise ValueError("max_batch and max_queue must be positive")
        if self.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")


class _Entry:
    """One queued leader request and its completion future."""

    __slots__ = ("request", "future", "arrival")

    def __init__(self, request: Request, future: asyncio.Future,
                 arrival: float):
        self.request = request
        self.future = future
        self.arrival = arrival


class AsyncScheduler:
    """Continuous micro-batching in front of one ``CachedEngine``.

    Usage::

        scheduler = AsyncScheduler(engine, SchedulerConfig(max_batch=32))
        await scheduler.start()
        response = await scheduler.submit(Request(query="..."))
        await scheduler.stop()      # drains the queue

    ``submit`` is safe to call from many concurrent tasks; the engine runs
    in a single worker thread so the device-side serve path is serialized
    while admission/coalescing continue on the event loop.
    """

    def __init__(self, engine: CachedEngine,
                 config: SchedulerConfig | None = None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        self._queue: deque[_Entry] = deque()
        # key -> list of (waiter future, arrival time); present from leader
        # enqueue until its response is delivered (covers queued AND
        # dispatched-to-backend windows — that is the "in-flight" part)
        self._pending: dict[str, list[tuple[asyncio.Future, float]]] = {}
        self._cond: asyncio.Condition | None = None
        self._loop_task: asyncio.Task | None = None
        self._executor: ThreadPoolExecutor | None = None
        self._force_flush = False
        self._stopping = False
        self._running = False
        self.batches_served = 0

    # -- lifecycle ------------------------------------------------------- #
    async def start(self) -> None:
        if self._running:
            return
        self._cond = asyncio.Condition()
        # fresh worker per start: stop() shut the previous one down, and a
        # drained scheduler may be started again
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-batch")
        self._stopping = False
        self._running = True
        self._loop_task = asyncio.create_task(self._run())

    async def stop(self) -> None:
        """Drain: serve everything already accepted, then shut down."""
        if not self._running:
            return
        async with self._cond:
            self._stopping = True
            self._cond.notify_all()
        await self._loop_task
        self._running = False
        self._executor.shutdown(wait=True)

    async def __aenter__(self) -> "AsyncScheduler":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- admission ------------------------------------------------------- #
    async def submit(self, request: Request) -> Response:
        """Enqueue one request and await its response.

        Duplicates of an in-flight key attach as waiters (no queue slot, no
        extra backend call); otherwise the request becomes that key's
        leader. A full queue blocks the submitter and forces an immediate
        flush of the oldest entries until a slot frees up.
        """
        if not self._running or self._stopping:
            raise RuntimeError("scheduler is not running")
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        arrival = time.perf_counter()
        key = coalesce_key(request)
        async with self._cond:
            # re-check under the lock: stop() may have begun draining
            # between the fast-path check above and lock acquisition, and
            # an entry enqueued after the drain would strand its future
            if not self._running or self._stopping:
                raise RuntimeError("scheduler is not running")
            if self.config.coalesce and key in self._pending:
                self._pending[key].append((fut, arrival))
                self.engine.metrics.record_coalesced(1)
            else:
                while len(self._queue) >= self.config.max_queue:
                    # backpressure (§12.2): demand an immediate oldest-
                    # deadline flush and wait for a freed slot
                    self._force_flush = True
                    self._cond.notify_all()
                    await self._cond.wait()
                    if self._stopping:
                        raise RuntimeError("scheduler stopped while queued")
                self._queue.append(_Entry(request, fut, arrival))
                if self.config.coalesce:
                    self._pending.setdefault(key, [])
                self._cond.notify_all()
        # awaited OUTSIDE the condition lock: the serve loop needs the lock
        # to resolve this future
        return await fut

    # -- scheduler loop --------------------------------------------------- #
    async def _run(self) -> None:
        while True:
            entries = await self._admit()
            if entries is None:
                return
            await self._serve(entries)

    async def _admit(self) -> list[_Entry] | None:
        """Block until a flush condition holds, then take the oldest
        ``<= max_batch`` entries (FIFO — oldest deadlines first)."""
        async with self._cond:
            while True:
                if self._queue:
                    age_ms = (time.perf_counter()
                              - self._queue[0].arrival) * 1000.0
                    if (len(self._queue) >= self.config.max_batch
                            or age_ms >= self.config.max_wait_ms
                            or self._force_flush or self._stopping):
                        self._force_flush = False
                        k = min(len(self._queue), self.config.max_batch)
                        entries = [self._queue.popleft() for _ in range(k)]
                        self._cond.notify_all()   # wake blocked submitters
                        return entries
                    timeout = self.config.max_wait_ms / 1000.0 - age_ms / 1000.0
                elif self._stopping:
                    return None
                else:
                    timeout = None
                try:
                    await asyncio.wait_for(self._cond.wait(), timeout)
                except asyncio.TimeoutError:
                    pass

    async def _serve(self, entries: list[_Entry]) -> None:
        """One engine round for one admission batch, off the event loop."""
        loop = asyncio.get_running_loop()
        batch = [e.request for e in entries]
        try:
            responses = await loop.run_in_executor(
                self._executor,
                lambda: self.engine.serve_batch(
                    batch, record_path_latency=False))
        except Exception as exc:                    # resolve, never strand
            async with self._cond:
                for e in entries:
                    for fut, _ in self._pending.pop(
                            coalesce_key(e.request), []):
                        if not fut.done():
                            fut.set_exception(exc)
                    if not e.future.done():
                        e.future.set_exception(exc)
            return
        self.batches_served += 1
        done = time.perf_counter()
        async with self._cond:
            for e, r in zip(entries, responses):
                # end-to-end latency: queue wait + service (the sync path's
                # samples are service-only; these are what a client sees)
                self.engine.metrics.record_latency(
                    "hit" if r.cached else "miss", done - e.arrival)
                if not e.future.done():
                    e.future.set_result(
                        dataclasses.replace(r, latency_s=done - e.arrival))
                # waiters inherit the leader's answer/decision; they paid
                # no lookup and no backend call
                for fut, w_arrival in self._pending.pop(
                        coalesce_key(e.request), []):
                    self.engine.metrics.record_latency(
                        "coalesced", done - w_arrival)
                    if not fut.done():
                        fut.set_result(dataclasses.replace(
                            r, coalesced=True, latency_s=done - w_arrival))
