"""Async continuous micro-batch scheduler with in-flight coalescing.

The paper measures one synchronous batch at a time (§2.5, Figures 2–4);
production traffic is *concurrent*. This module is the admission layer in
front of ``CachedEngine`` (DESIGN.md §12): requests arrive on an asyncio
event loop, wait in a bounded FIFO queue, and are flushed to the engine's
``serve_batch`` as micro-batches — on ``max_batch`` occupancy or on the
oldest request's ``max_wait_ms`` deadline, whichever comes first.

**In-flight coalescing** (DESIGN.md §12.3): concurrent requests with the
same semantic key attach as *waiters* to the one pending entry — queued or
already dispatched to the backend — so a thundering herd of N identical
misses costs ONE LLM call instead of N. Without a semantic cache in front,
this is the classic request-dedup proxy; with one, it closes the window
the paper leaves open between "first miss starts generating" and "response
is inserted", during which every duplicate would also miss.

**Embedding-similarity coalescing** (``SchedulerConfig.coalesce_sim``):
with a cosine threshold set, a request whose normalized text matches no
pending leader is additionally probed against the leaders' *embeddings* —
a SimHash LSH bucket collision (cheap prefilter, ``repro.embedding.lsh``)
nominates candidate leaders and an exact host-side cosine >=
``coalesce_sim`` verifies before attaching, so in-flight *paraphrases*
("how do I sort a list" / "how to sort lists") share one backend call too.
The verification step is what keeps the guarantee one-sided: an LSH false
collision is rejected by exact cosine, so distinct-meaning queries never
share a leader; a missed collision merely forfeits a dedup. Buckets are
scoped by (tenant, session), so similarity coalescing obeys exactly the
same isolation boundaries as the text path. ``None`` (default) keeps
today's text-equality behaviour bit for bit.

**Multi-tenant admission** (DESIGN.md §13.3): requests queue per tenant
and micro-batches are formed by *deficit round robin* over the backlogged
tenants — each rotation credits a tenant its (weight-proportional) quantum
and takes that many of its oldest requests — so a bursty tenant can fill
idle slots but can never starve the others out of a contended batch.
Backpressure is also per tenant: a tenant at its own queue bound blocks
(and forces a flush) without consuming other tenants' admission capacity.
With one tenant all of this degenerates to the original FIFO queue.

Invariants (tested in ``tests/test_scheduler.py`` / ``test_tenancy.py``):
  * admission order is FIFO within a tenant — a flush takes each tenant's
    oldest entries, and the flush trigger is the globally oldest deadline;
  * under contention a tenant's share of a micro-batch is proportional to
    its DRR weight, regardless of how deep its backlog is;
  * a full queue (global or per-tenant) never deadlocks submitters: it
    forces an immediate flush (backpressure, §12.2);
  * coalescing never crosses tenants: the dedup key is (tenant, query),
    so identical queries from different tenants each pay their own way;
  * at most one ``serve_batch`` runs at a time (single-worker executor —
    the engine's runtime is owned linearly), while the event loop stays
    free to accept and coalesce new arrivals;
  * every accepted request's future is resolved exactly once, also on
    backend failure and on ``stop()`` (drain).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.serving.engine import CachedEngine, Request, Response
from repro.serving.llm_backend import BackendError
from repro.serving.resilience import Overloaded


def normalize_query(text: str) -> str:
    """Whitespace/case-insensitive canonical form for coalescing: strip,
    casefold, collapse internal whitespace. Trivially-different duplicates
    ("How do I…", "  how do i …") now share one in-flight leader — the
    first step toward the ROADMAP's embedding-similarity coalescing."""
    return " ".join(text.split()).casefold()


def coalesce_key(request: Request) -> str:
    """Semantic identity for in-flight dedup: (tenant, session, normalized
    query).

    The tenant prefix makes cross-tenant coalescing structurally impossible
    — two tenants asking the same question must not share an answer object,
    let alone a cache decision (§13.3). The session component does the same
    for multi-turn context (§16.3): two sessions asking the identical
    follow-up *text* ("what about the second one?") are different dialogue
    states with different fused keys, so they must not share a leader —
    without it one session would receive an answer fused under the *other*
    session's context. Sessionless requests keep the exact pre-session key
    shape (empty middle component), so their coalescing is unchanged. The
    embedding-similarity upgrade is named in ROADMAP open items."""
    return (f"{request.tenant}\x1f{request.session}\x1f"
            f"{normalize_query(request.query)}")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission-control knobs (DESIGN.md §12.2, §13.3)."""

    max_batch: int = 32        # flush when this many requests are queued ...
    max_wait_ms: float = 5.0   # ... or when the oldest one has waited this long
    max_queue: int = 1024      # bounded total backlog; full -> immediate flush
    coalesce: bool = True      # in-flight duplicate merging (§12.3)
    coalesce_sim: float | None = None  # cosine bound for embedding-similarity
                                       # coalescing; None = text-equality only
    max_queue_per_tenant: int | None = None  # per-tenant backlog bound
                                             # (None -> max_queue)
    tenant_weights: dict | None = None       # DRR quanta by tenant name;
                                             # unlisted tenants weigh 1.0
    overload_policy: str = "block"           # full queue: "block" parks the
                                             # submitter until a slot frees
                                             # (pre-§20 behaviour); "shed"
                                             # raises Overloaded instead —
                                             # an explicit rejection beats
                                             # unbounded latency (§20.5)

    def __post_init__(self):
        if self.max_batch <= 0 or self.max_queue <= 0:
            raise ValueError("max_batch and max_queue must be positive")
        if self.overload_policy not in ("block", "shed"):
            raise ValueError(
                f"overload_policy must be 'block' or 'shed', "
                f"got {self.overload_policy!r}")
        if self.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        if self.coalesce_sim is not None \
                and not 0.0 < self.coalesce_sim <= 1.0:
            raise ValueError("coalesce_sim must be within (0, 1]")
        if self.max_queue_per_tenant is not None \
                and self.max_queue_per_tenant <= 0:
            raise ValueError("max_queue_per_tenant must be positive")
        if self.tenant_weights and \
                any(w <= 0 for w in self.tenant_weights.values()):
            raise ValueError("tenant weights must be positive")


class _Entry:
    """One queued leader request and its completion future."""

    __slots__ = ("request", "future", "arrival", "trace")

    def __init__(self, request: Request, future: asyncio.Future,
                 arrival: float, trace=None):
        self.request = request
        self.future = future
        self.arrival = arrival
        # RequestTrace when the engine's tracer is collecting, else the
        # shared NULL_TRACE (no per-request allocation, DESIGN.md §18.2)
        self.trace = trace


class AsyncScheduler:
    """Continuous micro-batching in front of one ``CachedEngine``.

    Usage::

        scheduler = AsyncScheduler(engine, SchedulerConfig(max_batch=32))
        await scheduler.start()
        response = await scheduler.submit(Request(query="..."))
        await scheduler.stop()      # drains the queue

    ``submit`` is safe to call from many concurrent tasks; the engine runs
    in a single worker thread so the device-side serve path is serialized
    while admission/coalescing continue on the event loop.
    """

    def __init__(self, engine: CachedEngine,
                 config: SchedulerConfig | None = None):
        self.engine = engine
        self.config = config or SchedulerConfig()
        # per-tenant FIFO queues + deficit-round-robin state (§13.3); a
        # single-tenant workload uses exactly one queue = the old FIFO
        self._queues: dict[str, deque[_Entry]] = {}
        self._rr: deque[str] = deque()     # backlogged tenants, rotation order
        self._deficit: dict[str, float] = {}
        self._qlen = 0                     # total backlog across tenants
        # key -> list of (waiter future, arrival time, waiter trace, waiter
        # request); present from leader enqueue until its response is
        # delivered (covers queued AND dispatched-to-backend windows —
        # that is the "in-flight" part)
        self._pending: dict[str, list[tuple]] = {}
        # embedding-similarity coalescing state (coalesce_sim, §12.3): the
        # LSH prefilter plus, per pending leader, its embedding and bucket
        # registrations (for cosine verification and cleanup)
        self._lsh = None
        self._leader_emb: dict[str, np.ndarray] = {}
        self._leader_buckets: dict[str, list[tuple]] = {}
        self._sim_buckets: dict[tuple, set[str]] = {}
        if self.config.coalesce_sim is not None:
            from repro.embedding.lsh import SimHashLSH
            self._lsh = SimHashLSH(engine.embedder.dim)
        self._cond: asyncio.Condition | None = None
        self._loop_task: asyncio.Task | None = None
        self._executor: ThreadPoolExecutor | None = None
        self._force_flush = False
        self._stopping = False
        self._running = False
        self.batches_served = 0

    def _waiter_trace(self, arrival: float, leader_key: str):
        """Trace for a coalesced waiter: its whole queue life is one
        ``coalesce_attach`` span (arrival -> attached to the in-flight
        leader); the ``respond`` span is added at resolution. Returns the
        shared NULL_TRACE when the tracer is off."""
        tr = self.engine.tracer.start()
        if tr:
            tr.add("coalesce_attach", arrival, time.perf_counter())
            tr.annotate(leader=leader_key)
        return tr

    def _weight(self, tenant: str) -> float:
        w = self.config.tenant_weights
        return w.get(tenant, 1.0) if w else 1.0

    def _tenant_of(self, request: Request) -> str | None:
        """Tenant tag for metrics — only when the engine actually runs a
        registry (a bare 'default' on a single-tenant engine is noise)."""
        return request.tenant if getattr(self.engine, "registry", None) \
            is not None else None

    def _oldest_arrival(self) -> float:
        return min(q[0].arrival for q in self._queues.values() if q)

    # -- embedding-similarity coalescing (coalesce_sim, §12.3) ----------- #
    def _similar_leader(self, request: Request,
                        emb: np.ndarray) -> str | None:
        """Pending leader whose embedding verifies cosine >= coalesce_sim
        against ``emb``, or None. The LSH bucket probe only *nominates*
        candidates (scoped to this request's tenant+session); the exact
        cosine check is what admits — a colliding-but-dissimilar leader is
        rejected here, so distinct-meaning queries never share a leader."""
        scope = (request.tenant, request.session)
        cands: set[str] = set()
        for t, b in enumerate(self._lsh.buckets(emb)):
            cands |= self._sim_buckets.get(scope + (t, b), set())
        from repro.embedding.lsh import cosine
        best, best_sim = None, float(self.config.coalesce_sim)
        for k in sorted(cands):            # deterministic tie-break
            if k in self._pending:
                sim = cosine(emb, self._leader_emb[k])
                if sim >= best_sim:
                    best, best_sim = k, sim
        return best

    def _register_leader(self, request: Request, key: str,
                         emb: np.ndarray) -> None:
        scope = (request.tenant, request.session)
        buckets = [scope + (t, b)
                   for t, b in enumerate(self._lsh.buckets(emb))]
        self._leader_emb[key] = emb
        self._leader_buckets[key] = buckets
        for bk in buckets:
            self._sim_buckets.setdefault(bk, set()).add(key)

    def _unregister_leader(self, key: str) -> None:
        """Drop a resolved leader's similarity state (no-op for keys that
        never registered — LSH off, or a pre-LSH leader)."""
        self._leader_emb.pop(key, None)
        for bk in self._leader_buckets.pop(key, ()):
            members = self._sim_buckets.get(bk)
            if members is not None:
                members.discard(key)
                if not members:
                    del self._sim_buckets[bk]

    # -- lifecycle ------------------------------------------------------- #
    async def start(self) -> None:
        if self._running:
            return
        self._cond = asyncio.Condition()
        # fresh worker per start: stop() shut the previous one down, and a
        # drained scheduler may be started again
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-batch")
        self._stopping = False
        self._running = True
        self._loop_task = asyncio.create_task(self._run())

    async def stop(self) -> None:
        """Drain: serve everything already accepted, then shut down."""
        if not self._running:
            return
        async with self._cond:
            self._stopping = True
            self._cond.notify_all()
        await self._loop_task
        self._running = False
        self._executor.shutdown(wait=True)

    async def __aenter__(self) -> "AsyncScheduler":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- admission ------------------------------------------------------- #
    async def submit(self, request: Request) -> Response:
        """Enqueue one request and await its response.

        Duplicates of an in-flight (tenant, query) key attach as waiters
        (no queue slot, no extra backend call); otherwise the request
        becomes that key's leader in its tenant's queue. A full queue —
        the tenant's own bound or the global one — blocks the submitter
        and forces an immediate flush until a slot frees up; other
        tenants' submitters are unaffected by a neighbour's full queue.
        """
        if not self._running or self._stopping:
            raise RuntimeError("scheduler is not running")
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        arrival = time.perf_counter()
        key = coalesce_key(request)
        tenant = request.tenant
        cap_tenant = self.config.max_queue_per_tenant or self.config.max_queue
        async with self._cond:
            # re-check under the lock: stop() may have begun draining
            # between the fast-path check above and lock acquisition, and
            # an entry enqueued after the drain would strand its future
            if not self._running or self._stopping:
                raise RuntimeError("scheduler is not running")
            sim_leader = None
            emb = None
            if self.config.coalesce and self._lsh is not None \
                    and key not in self._pending:
                # embedding probe only when the exact-text key missed: the
                # host-side hash embedding is cheap but not free
                emb = np.asarray(self.engine.embedder.embed(request.query),
                                 dtype=np.float32)
                sim_leader = self._similar_leader(request, emb)
            if self.config.coalesce and key in self._pending:
                self._pending[key].append(
                    (fut, arrival, self._waiter_trace(arrival, key),
                     request))
                self.engine.metrics.record_coalesced(
                    1, tenant=self._tenant_of(request))
            elif sim_leader is not None:
                # cosine-verified paraphrase of an in-flight leader (§12.3)
                self._pending[sim_leader].append(
                    (fut, arrival, self._waiter_trace(arrival, sim_leader),
                     request))
                self.engine.metrics.record_coalesced(
                    1, tenant=self._tenant_of(request))
            else:
                queue = self._queues.setdefault(tenant, deque())
                while (self._qlen >= self.config.max_queue
                       or len(queue) >= cap_tenant):
                    # backpressure (§12.2): demand an immediate flush and
                    # wait for a freed slot in *this* tenant's budget —
                    # or, under the shed policy (§20.5), reject loudly
                    # instead of queueing latency the caller never agreed to
                    self._force_flush = True
                    self._cond.notify_all()
                    if self.config.overload_policy == "shed":
                        self.engine.metrics.resilience.shed += 1
                        self.engine.metrics.resilience_seen = True
                        raise Overloaded(
                            f"queue full (tenant {tenant!r}: "
                            f"{len(queue)}/{cap_tenant}, total "
                            f"{self._qlen}/{self.config.max_queue}); "
                            "load shed — retry with backoff")
                    await self._cond.wait()
                    if self._stopping:
                        raise RuntimeError("scheduler stopped while queued")
                queue.append(_Entry(request, fut, arrival,
                                    trace=self.engine.tracer.start()))
                self._qlen += 1
                if tenant not in self._rr:
                    self._rr.append(tenant)
                if self.config.coalesce:
                    self._pending.setdefault(key, [])
                    if self._lsh is not None and emb is not None:
                        self._register_leader(request, key, emb)
                self._cond.notify_all()
        # awaited OUTSIDE the condition lock: the serve loop needs the lock
        # to resolve this future
        return await fut

    # -- scheduler loop --------------------------------------------------- #
    async def _run(self) -> None:
        while True:
            entries = await self._admit()
            if entries is None:
                return
            await self._serve(entries)

    def _form_batch(self) -> list[_Entry]:
        """Deficit-round-robin batch formation over backlogged tenants
        (§13.3). Each rotation credits the tenant its weight as quantum and
        takes that many of its oldest entries (FIFO within tenant). The
        deficit persists across batches while a tenant stays backlogged —
        that is what makes long-run shares weight-proportional — and resets
        when its queue drains (classic DRR, Shreedhar & Varghese 1996)."""
        out: list[_Entry] = []
        while len(out) < self.config.max_batch and self._qlen > 0:
            tenant = self._rr.popleft()
            queue = self._queues[tenant]
            if not queue:
                self._deficit[tenant] = 0.0
                continue              # drained earlier: drop from rotation
            self._deficit[tenant] = self._deficit.get(tenant, 0.0) \
                + self._weight(tenant)
            take = min(len(queue), int(self._deficit[tenant]),
                       self.config.max_batch - len(out))
            for _ in range(take):
                out.append(queue.popleft())
            self._qlen -= take
            self._deficit[tenant] -= take
            if queue:
                self._rr.append(tenant)   # still backlogged: keep rotating
            else:
                self._deficit[tenant] = 0.0
        return out

    async def _admit(self) -> list[_Entry] | None:
        """Block until a flush condition holds, then form one micro-batch.
        The flush trigger watches the *globally* oldest arrival, so no
        tenant's deadline is hostage to another tenant's traffic."""
        async with self._cond:
            while True:
                if self._qlen > 0:
                    age_ms = (time.perf_counter()
                              - self._oldest_arrival()) * 1000.0
                    if (self._qlen >= self.config.max_batch
                            or age_ms >= self.config.max_wait_ms
                            or self._force_flush or self._stopping):
                        self._force_flush = False
                        t_flush = time.perf_counter()
                        entries = self._form_batch()
                        if self.engine.tracer.collecting:
                            # queue-side spans (§18.1): queue_wait is
                            # arrival -> flush decision, batch_form the
                            # DRR assembly; the engine's contiguous stage
                            # clock picks up from the executor handoff
                            t_formed = time.perf_counter()
                            for e in entries:
                                e.trace.add("queue_wait", e.arrival,
                                            t_flush)
                                e.trace.add("batch_form", t_flush,
                                            t_formed)
                        self._cond.notify_all()   # wake blocked submitters
                        return entries
                    timeout = self.config.max_wait_ms / 1000.0 - age_ms / 1000.0
                elif self._stopping:
                    return None
                else:
                    timeout = None
                try:
                    await asyncio.wait_for(self._cond.wait(), timeout)
                except asyncio.TimeoutError:
                    pass

    async def _serve(self, entries: list[_Entry]) -> None:
        """One engine round for one admission batch, off the event loop."""
        loop = asyncio.get_running_loop()
        # deadline budgets (§20.3): the engine must see the budget that
        # REMAINS after queue wait, so retries can never push a request
        # past the SLO its caller stated at submit time. Requests without
        # a deadline pass through untouched (identical object).
        t_dispatch = time.perf_counter()
        batch = []
        for e in entries:
            r = e.request
            if r.deadline_ms is not None:
                waited_ms = (t_dispatch - e.arrival) * 1000.0
                r = dataclasses.replace(
                    r, deadline_ms=max(r.deadline_ms - waited_ms, 0.0))
            batch.append(r)
        try:
            responses = await loop.run_in_executor(
                self._executor,
                lambda: self.engine.serve_batch(
                    batch, record_path_latency=False,
                    traces=[e.trace for e in entries]))
        except Exception as exc:                    # resolve, never strand
            async with self._cond:
                for e in entries:
                    key = coalesce_key(e.request)
                    self._unregister_leader(key)
                    for fut, *_ in self._pending.pop(key, []):
                        if not fut.done():
                            fut.set_exception(exc)
                    if not e.future.done():
                        e.future.set_exception(exc)
            return
        self.batches_served += 1
        done = time.perf_counter()
        async with self._cond:
            for e, r in zip(entries, responses):
                tenant = self._tenant_of(e.request)
                key = coalesce_key(e.request)
                if r.error:
                    # per-row failure domain (§20.2): only the rows whose
                    # backend call actually failed reject — hit/near/
                    # degraded rows of the same flush resolved normally
                    exc = BackendError(r.error)
                    self._unregister_leader(key)
                    self.engine.metrics.record_latency(
                        "error", done - e.arrival, tenant=tenant)
                    if not e.future.done():
                        e.future.set_exception(exc)
                    if e.trace:
                        self.engine.tracer.finish(e.trace,
                                                  e2e_s=done - e.arrival)
                    for fut, w_arrival, wtr, _w_req in self._pending.pop(
                            key, []):
                        self.engine.metrics.record_latency(
                            "error", done - w_arrival, tenant=tenant)
                        if wtr:
                            self.engine.tracer.finish(
                                wtr, e2e_s=done - w_arrival)
                        if not fut.done():
                            fut.set_exception(exc)
                    continue
                # end-to-end latency: queue wait + service (the sync path's
                # samples are service-only; these are what a client sees)
                path = "degraded" if r.degraded else (
                    "hit" if r.cached else
                    ("near" if r.near_hit else "miss"))
                self.engine.metrics.record_latency(
                    path, done - e.arrival, tenant=tenant)
                if not e.future.done():
                    e.future.set_result(
                        dataclasses.replace(r, latency_s=done - e.arrival))
                if e.trace:
                    # true client-observed e2e: queue wait + service
                    self.engine.tracer.finish(e.trace,
                                              e2e_s=done - e.arrival)
                # waiters inherit the leader's answer/decision; they paid
                # no lookup and no backend call (and shared the leader's
                # tenant — the coalesce key guarantees it; similarity
                # waiters additionally passed the cosine >= coalesce_sim
                # verification against this leader)
                self._unregister_leader(key)
                for fut, w_arrival, wtr, w_req in self._pending.pop(
                        key, []):
                    # the waiter's latency files under its OWN "coalesced"
                    # path — folding it into the leader's hit/miss bucket
                    # would skew those paths' p99 (§18.5)
                    self.engine.metrics.record_latency(
                        "coalesced", done - w_arrival, tenant=tenant)
                    w_resp = dataclasses.replace(
                        r, coalesced=True, latency_s=done - w_arrival,
                        trace_id="", why=None)
                    if w_req.explain or self.engine.explain_all:
                        w_resp = dataclasses.replace(
                            w_resp, why=self._waiter_why(r, w_req, key))
                    if wtr:
                        t_att = wtr.spans[-1].t1 if wtr.spans else w_arrival
                        wtr.add("respond", t_att, done)
                        wtr.why = w_resp.why
                        w_resp = dataclasses.replace(
                            w_resp, trace_id=wtr.trace_id)
                        self.engine.tracer.finish(
                            wtr, e2e_s=done - w_arrival)
                    if not fut.done():
                        fut.set_result(w_resp)

    @staticmethod
    def _waiter_why(r: Response, w_req: Request, leader_key: str) -> dict:
        """Attribution for a coalesced waiter (§18.3): the decision is
        ``coalesced`` (this request paid nothing), ``coalesced_into`` names
        the leader, and the leader's own record — when it carried one —
        rides along with its decision demoted to ``leader_decision``."""
        leader_decision = ("degraded" if r.degraded
                          else "hit" if r.cached
                          else "near_hit" if r.near_hit else "miss")
        why = dict(r.why) if r.why is not None else {
            "score": round(float(r.score), 6),
            "tenant": w_req.tenant, "session": w_req.session}
        why.update(decision="coalesced", coalesced_into=leader_key,
                   leader_decision=leader_decision)
        return why
