"""LLM backends for the miss path.

``SimulatedLLMBackend`` — the offline stand-in for the OpenAI API the paper
calls on cache misses: returns the corpus's gold answer for known/paraphrased
queries (keyed by the query's semantic source) and a templated answer
otherwise, charging a configurable latency + dollar cost per call. This is
what the paper-metric benchmarks use (DESIGN.md §9).

``ModelBackend`` — a real JAX model (any of the ten architectures, usually
reduced) behind the same interface: tokenize, prefill, greedy-decode. This
is the end-to-end production path exercised by examples and tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.qa_dataset import QAPair
from repro.data.tokenizer import HashTokenizer


class BackendError(RuntimeError):
    """A backend ``generate`` call failed for the rows that needed it.

    This is the exception the serving stack resolves *per row* (DESIGN.md
    §20.2): cache-hit / near-hit / degraded rows in the same micro-batch
    are served normally and only the true-miss rows whose call failed see
    it. Subclasses distinguish the fault families the resilience layer
    reacts to differently (a timeout consumed deadline budget; an
    unavailable backend did not)."""


class BackendUnavailable(BackendError):
    """The backend refused or errored the call (5xx / connection reset)."""


class BackendTimeout(BackendError):
    """The call consumed its time budget without producing an answer."""


@dataclasses.dataclass
class BackendResult:
    answers: list[str]
    latency_s: float          # simulated/measured wall time for the batch
    cost_usd: float           # API cost charged


class SimulatedLLMBackend:
    """Gold-answer oracle with an API latency/cost model.

    Latency model: per-call base + per-token generation time (defaults
    approximate a hosted GPT-class API: ~0.8 s/call as in the paper's
    uncached measurements). Cost model: $ per call (flat, conservative).
    """

    def __init__(self, pairs: Sequence[QAPair], *,
                 latency_per_call_s: float = 0.8,
                 cost_per_call_usd: float = 0.002,
                 block: bool = False):
        # ``block=True`` actually sleeps one API round-trip per generate()
        # call (a batch of misses shares one RTT, like a batched API call)
        # so the async scheduler's measured wall-clock latencies are real —
        # the tail-latency benchmark needs elapsed time, not bookkeeping.
        self.by_key = {p.semantic_key: p.answer for p in pairs}
        self.by_question = {p.question: p.answer for p in pairs}
        self.latency_per_call_s = latency_per_call_s
        self.cost_per_call_usd = cost_per_call_usd
        self.block = block
        self.calls = 0

    def generate(self, queries: Sequence[str],
                 semantic_keys: Sequence[str] | None = None) -> BackendResult:
        answers = []
        for i, q in enumerate(queries):
            if q in self.by_question:
                answers.append(self.by_question[q])
            elif semantic_keys is not None and semantic_keys[i] in self.by_key:
                answers.append(self.by_key[semantic_keys[i]])
            else:
                answers.append(f"Here is a detailed answer to: {q}")
        self.calls += len(queries)
        if self.block:
            time.sleep(self.latency_per_call_s)
        return BackendResult(
            answers=answers,
            latency_s=self.latency_per_call_s * len(queries),
            cost_usd=self.cost_per_call_usd * len(queries))


class ModelBackend:
    """Greedy decoding with a real (usually reduced) architecture."""

    def __init__(self, model, params, tokenizer: HashTokenizer, *,
                 max_prompt_len: int = 64, max_new_tokens: int = 24):
        self.model = model
        self.params = params
        self.tokenizer = tokenizer
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.calls = 0
        self._decode_jit = jax.jit(self.model.decode_step)

    def generate(self, queries: Sequence[str],
                 semantic_keys: Sequence[str] | None = None) -> BackendResult:
        t0 = time.perf_counter()
        toks, lens = self.tokenizer.encode_batch(queries, self.max_prompt_len)
        b = toks.shape[0]
        cache_size = self.max_prompt_len + self.max_new_tokens + 8
        tokens = jnp.asarray(toks)
        _, caches, _ = self.model.forward(self.params, tokens,
                                          collect_cache=True,
                                          cache_size=cache_size)
        # greedy decode (note: per-row prompt lengths are padded to the same
        # length; pad tokens are part of the prompt — acceptable for the toy
        # serving path)
        logits, _ = self.model.forward(self.params, tokens)
        nt = jnp.argmax(logits[:, -1:], axis=-1)
        out = [nt]
        for _ in range(self.max_new_tokens - 1):
            dl, caches = self._decode_jit(self.params, caches, nt)
            nt = jnp.argmax(dl, axis=-1)
            out.append(nt)
        gen = np.asarray(jnp.concatenate(out, axis=1))
        answers = [self.tokenizer.decode(gen[i]) for i in range(b)]
        self.calls += b
        return BackendResult(answers=answers,
                             latency_s=time.perf_counter() - t0,
                             cost_usd=0.0)
