"""Serving runtime: the paper's cached query-handling system."""
from repro.serving.engine import Batcher, CachedEngine, Request, Response
from repro.serving.llm_backend import (BackendResult, ModelBackend,
                                       SimulatedLLMBackend)
from repro.serving.metrics import CategoryMetrics, ServingMetrics

__all__ = ["Batcher", "CachedEngine", "Request", "Response", "BackendResult",
           "ModelBackend", "SimulatedLLMBackend", "CategoryMetrics",
           "ServingMetrics"]
