"""Serving runtime: the paper's cached query-handling system, plus the
async continuous-batching layer in front of it (DESIGN.md §12)."""
from repro.serving.engine import Batcher, CachedEngine, Request, Response
from repro.serving.llm_backend import (BackendError, BackendResult,
                                       BackendTimeout, BackendUnavailable,
                                       ModelBackend, SimulatedLLMBackend)
from repro.serving.loadgen import (LoadResult, availability,
                                   build_multi_tenant_workload,
                                   build_multi_turn_workload, build_workload,
                                   run_closed_loop, run_open_loop,
                                   run_sessions, run_waves, tenant_rng,
                                   turn_levels, zipf_weights)
from repro.serving.metrics import (CategoryMetrics, ContextMetrics,
                                   NearHitMetrics, ResilienceMetrics,
                                   ServingMetrics, TenantMetrics)
from repro.serving.resilience import (CircuitBreaker, FaultSchedule,
                                      FaultWindow, FaultyBackend, Overloaded,
                                      ResilienceConfig, RetryPolicy)
from repro.serving.scheduler import (AsyncScheduler, SchedulerConfig,
                                     coalesce_key, normalize_query)
from repro.serving.server import AsyncCacheServer

__all__ = ["Batcher", "CachedEngine", "Request", "Response", "BackendResult",
           "BackendError", "BackendTimeout", "BackendUnavailable",
           "ModelBackend", "SimulatedLLMBackend", "CategoryMetrics",
           "ContextMetrics", "NearHitMetrics", "ResilienceMetrics",
           "ServingMetrics", "TenantMetrics",
           "CircuitBreaker", "FaultSchedule", "FaultWindow", "FaultyBackend",
           "Overloaded", "ResilienceConfig", "RetryPolicy",
           "AsyncScheduler", "SchedulerConfig", "coalesce_key",
           "normalize_query", "AsyncCacheServer", "LoadResult",
           "availability", "build_workload", "build_multi_tenant_workload",
           "build_multi_turn_workload", "tenant_rng", "turn_levels",
           "zipf_weights", "run_closed_loop", "run_open_loop",
           "run_sessions", "run_waves"]
