"""Serving metrics: the paper's evaluation quantities (Figures 2–4, Table 1).

Per-category counters for lookups / hits / positive hits plus latency and
cost accumulators for the cached and uncached paths. ``summary()`` emits
exactly the rows the paper reports: cache-hit rate, API-call reduction,
positive-hit rate, average response time with/without cache, cost saved.

Beyond-paper serving additions (DESIGN.md §12): per-path latency samples
("hit" / "miss" / "coalesced") summarized as p50/p95/p99 percentiles, and
a ``coalesced_calls`` counter — requests that attached to an in-flight
duplicate instead of paying their own lookup/backend call. The paper-table
rows of ``summary()`` are unchanged; the new quantities ride along under
new keys.

Multi-tenant serving (DESIGN.md §13) adds a per-tenant breakdown under the
same contract: ``record_batch(..., tenants=...)`` and
``record_latency(..., tenant=...)`` accumulate per-tenant hit/miss counts,
coalesced counts and per-tenant latency percentiles, surfaced under
``summary()["tenants"]`` without touching any existing row.

Multi-turn serving (DESIGN.md §16) adds context-hit rows the same way:
``record_batch(..., contexts=...)`` splits every lookup into the
*context-fused* bucket (the row was looked up under a non-empty session
turn window) vs the *single-turn* bucket, surfaced under
``summary()["context"]`` — the quantities the context table reports
(context hit rate vs single-turn hit rate, and context positive-hit
precision, which must clear the same >97% bar as stateless serving).

Generative near-hit serving (DESIGN.md §17) rides the same contract:
``record_batch(..., nears=..., near_served=...)`` counts band rows
([τ_lo, τ_hi) lookups), how many of them the synthesizer actually served
(vs abstained back to the full backend call), judged synthesis precision
and the marginal synthesis cost/latency, surfaced under
``summary()["near"]`` without touching any existing row.
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict


_PCTS = (50.0, 95.0, 99.0)

#: Histogram bucket upper bounds (seconds) for the Prometheus exposition
#: (repro.obs.export). Spans sub-millisecond cache hits to multi-second
#: backend calls; the +Inf bucket is implicit (``count`` closes it).
LATENCY_BUCKETS_S = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                     0.25, 0.5, 1.0, 2.5, 5.0)


def percentiles(samples: list[float]) -> dict:
    """p50/p95/p99 (linear interpolation, numpy-compatible) of one path."""
    if not samples:
        return {"count": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0}
    xs = sorted(samples)
    out = {"count": len(xs)}
    for p in _PCTS:
        rank = (len(xs) - 1) * p / 100.0
        lo = int(rank)
        hi = min(lo + 1, len(xs) - 1)
        val = xs[lo] + (xs[hi] - xs[lo]) * (rank - lo)
        out[f"p{int(p)}_s"] = round(val, 6)
    return out


class LatencyReservoir:
    """Bounded latency sample buffer (DESIGN.md §18.5).

    ``record_latency`` used to append every sample to an unbounded
    ``list[float]`` per path/tenant — a slow memory leak under sustained
    load. This keeps three bounded things instead:

      * exact scalars: ``count`` and ``total_s`` over ALL samples ever;
      * a uniform random reservoir (Vitter's Algorithm R) of at most
        ``cap`` samples, so percentile estimates stay statistically
        honest over the full stream, not just a recent window;
      * per-bucket counts over ``LATENCY_BUCKETS_S`` — exact histogram
        counters for the Prometheus exposition, O(len(buckets)) memory.

    The replacement RNG is seeded per-reservoir, so runs reproduce.
    ``summary()`` matches the ``percentiles()`` row shape except that
    ``count`` reports the true stream length, not the reservoir size.
    """

    __slots__ = ("cap", "count", "total_s", "samples", "_rng", "_buckets")

    def __init__(self, cap: int = 2048, seed: int = 0x5eed):
        if cap <= 0:
            raise ValueError("cap must be positive")
        self.cap = cap
        self.count = 0
        self.total_s = 0.0
        self.samples: list[float] = []
        self._rng = random.Random(seed)
        self._buckets = [0] * (len(LATENCY_BUCKETS_S) + 1)   # last = +Inf

    def add(self, seconds: float) -> None:
        self.count += 1
        self.total_s += seconds
        for b, le in enumerate(LATENCY_BUCKETS_S):
            if seconds <= le:
                self._buckets[b] += 1
                break
        else:
            self._buckets[-1] += 1
        if len(self.samples) < self.cap:
            self.samples.append(seconds)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self.samples[j] = seconds

    def __len__(self) -> int:
        return len(self.samples)

    def summary(self) -> dict:
        row = percentiles(self.samples)
        row["count"] = self.count           # true stream length, not |reservoir|
        return row

    def bucket_rows(self) -> list[tuple[float, int]]:
        """``(upper_bound_s, count)`` per bucket, +Inf last, non-cumulative."""
        bounds = list(LATENCY_BUCKETS_S) + [float("inf")]
        return list(zip(bounds, self._buckets))


@dataclasses.dataclass
class CategoryMetrics:
    lookups: int = 0
    hits: int = 0
    positive_hits: int = 0
    judged_hits: int = 0
    cache_latency_s: float = 0.0
    llm_latency_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    @property
    def positive_rate(self) -> float:
        return self.positive_hits / self.judged_hits if self.judged_hits else 0.0

    @property
    def api_call_fraction(self) -> float:
        return 1.0 - self.hit_rate


@dataclasses.dataclass
class TenantMetrics:
    """Host-side per-tenant accounting (mirrors the device-side
    ``TenancyState`` counters, plus latency samples only the host sees)."""

    lookups: int = 0
    hits: int = 0
    coalesced: int = 0
    latency_samples: dict = dataclasses.field(
        default_factory=lambda: defaultdict(LatencyReservoir))
    # path -> LatencyReservoir (bounded, §18.5)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


@dataclasses.dataclass
class ContextMetrics:
    """One bucket of the context-fused vs single-turn split (§16)."""

    lookups: int = 0
    hits: int = 0
    positive_hits: int = 0
    judged_hits: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    @property
    def positive_rate(self) -> float:
        return self.positive_hits / self.judged_hits if self.judged_hits else 0.0

    def row(self) -> dict:
        return {"lookups": self.lookups, "cache_hits": self.hits,
                "hit_rate": round(self.hit_rate, 4),
                "positive_hits": self.positive_hits,
                "positive_rate": round(self.positive_rate, 4)}


@dataclasses.dataclass
class NearHitMetrics:
    """Band-row accounting for the generative near-hit path (§17.5).

    ``band`` counts lookups landing in [τ_lo, τ_hi); ``served`` is the
    subset the synthesizer converted (the backend calls saved beyond exact
    reuse); the rest abstained back to a full call. ``positives/judged``
    is synthesis precision under the ground-truth judge — the quantity the
    serve-bench near-hit stage asserts > 0.9.
    """

    band: int = 0
    served: int = 0
    positives: int = 0
    judged: int = 0
    synthesis_cost_usd: float = 0.0
    synthesis_time_s: float = 0.0

    @property
    def conversion_rate(self) -> float:
        return self.served / self.band if self.band else 0.0

    @property
    def precision(self) -> float:
        return self.positives / self.judged if self.judged else 0.0

    def row(self) -> dict:
        return {"band_lookups": self.band,
                "near_hits_served": self.served,
                "abstained": self.band - self.served,
                "conversion_rate": round(self.conversion_rate, 4),
                "positive_near_hits": self.positives,
                "near_precision": round(self.precision, 4),
                "synthesis_cost_usd": round(self.synthesis_cost_usd, 6),
                "synthesis_time_s": round(self.synthesis_time_s, 6)}


@dataclasses.dataclass
class ResilienceMetrics:
    """Fault-path accounting for resilient serving (DESIGN.md §20.5).

    ``backend_failures`` counts failed backend calls (including failed
    retries); ``retries`` the §20.3 re-attempts and ``retry_successes``
    the calls a retry rescued; ``breaker_short_circuits`` the calls the
    open breaker refused without touching the backend. ``degraded_*``
    track the §20.4 fallback: rows served from a cached neighbour under
    the relaxed floor (never admitted to the slab), rows with no servable
    neighbour, and the judged quality of what was served. ``shed`` counts
    explicit Overloaded rejections from the scheduler's shed policy.
    """

    backend_failures: int = 0
    retries: int = 0
    retry_successes: int = 0
    breaker_short_circuits: int = 0
    degraded_served: int = 0
    degraded_failed: int = 0
    degraded_judged: int = 0
    degraded_positives: int = 0
    deadline_exhausted: int = 0
    shed: int = 0

    @property
    def degraded_precision(self) -> float:
        return self.degraded_positives / self.degraded_judged \
            if self.degraded_judged else 0.0

    def row(self) -> dict:
        return {"backend_failures": self.backend_failures,
                "retries": self.retries,
                "retry_successes": self.retry_successes,
                "breaker_short_circuits": self.breaker_short_circuits,
                "degraded_served": self.degraded_served,
                "degraded_failed": self.degraded_failed,
                "degraded_precision": round(self.degraded_precision, 4),
                "deadline_exhausted": self.deadline_exhausted,
                "shed": self.shed}


@dataclasses.dataclass
class ServingMetrics:
    per_category: dict = dataclasses.field(
        default_factory=lambda: defaultdict(CategoryMetrics))
    per_tenant: dict = dataclasses.field(
        default_factory=lambda: defaultdict(TenantMetrics))
    context: ContextMetrics = dataclasses.field(
        default_factory=ContextMetrics)       # session rows with a window
    single_turn: ContextMetrics = dataclasses.field(
        default_factory=ContextMetrics)       # stateless / first-turn rows
    context_seen: bool = False                # any contexts=... recorded?
    near: NearHitMetrics = dataclasses.field(
        default_factory=NearHitMetrics)       # band-row accounting (§17)
    near_seen: bool = False                   # any nears=... recorded?
    resilience: ResilienceMetrics = dataclasses.field(
        default_factory=ResilienceMetrics)    # fault-path accounting (§20)
    resilience_seen: bool = False             # resilience configured, or
                                              # any backend failure seen?
    total_cost_usd: float = 0.0
    baseline_cost_usd: float = 0.0          # what 100% API calls would cost
    cache_path_time_s: float = 0.0          # embed + lookup wall time
    llm_path_time_s: float = 0.0            # miss-path LLM latency
    baseline_time_s: float = 0.0            # all-queries-to-LLM latency
    queries: int = 0
    coalesced_calls: int = 0                # requests merged into in-flight
                                            # duplicates (scheduler, §12.3)
    latency_samples: dict = dataclasses.field(
        default_factory=lambda: defaultdict(LatencyReservoir))
    # path -> LatencyReservoir (bounded, §18.5)

    def record_latency(self, path: str, seconds: float,
                       tenant: str | None = None) -> None:
        """One request's end-to-end latency on ``path`` (hit/miss/coalesced).
        ``tenant`` additionally files the sample under that tenant's
        breakdown (multi-tenant serving, §13). Any path name is accepted;
        unknown names simply open a new bounded reservoir."""
        self.latency_samples[path].add(seconds)
        if tenant is not None:
            self.per_tenant[tenant].latency_samples[path].add(seconds)

    def record_coalesced(self, n: int = 1, tenant: str | None = None) -> None:
        """Count requests merged into an in-flight duplicate. Their
        end-to-end latency is recorded separately (at resolution time)
        via ``record_latency("coalesced", ...)``."""
        self.coalesced_calls += n
        if tenant is not None:
            self.per_tenant[tenant].coalesced += n

    def record_batch(self, categories, hits, positives, *, judged,
                     cache_time_s: float, llm_time_s: float,
                     llm_cost: float, baseline_cost: float,
                     baseline_time: float, tenants=None,
                     contexts=None, nears=None, near_served=None,
                     syn_cost: float = 0.0, syn_time: float = 0.0) -> None:
        if contexts is not None:
            self.context_seen = True
        if nears is not None:
            # band rows ([τ_lo, τ_hi) lookups) and the synthesized subset;
            # a served row's judged outcome arrives in ``positives`` at the
            # same index, exactly like an exact hit's does
            self.near_seen = True
            for i in range(len(categories)):
                if bool(nears[i]):
                    self.near.band += 1
                if near_served is not None and bool(near_served[i]):
                    self.near.served += 1
                    if judged is None or judged[i]:
                        self.near.judged += 1
                        if bool(positives[i]):
                            self.near.positives += 1
            self.near.synthesis_cost_usd += syn_cost
            self.near.synthesis_time_s += syn_time
        for i, cat in enumerate(categories):
            m = self.per_category[cat]
            m.lookups += 1
            if bool(hits[i]):
                m.hits += 1
                if judged is None or judged[i]:
                    m.judged_hits += 1
                    if bool(positives[i]):
                        m.positive_hits += 1
            m.cache_latency_s += cache_time_s / max(len(categories), 1)
            m.llm_latency_s += llm_time_s / max(len(categories), 1)
            if tenants is not None:
                t = self.per_tenant[tenants[i]]
                t.lookups += 1
                t.hits += int(bool(hits[i]))
            if contexts is not None:
                c = self.context if bool(contexts[i]) else self.single_turn
                c.lookups += 1
                if bool(hits[i]):
                    c.hits += 1
                    if judged is None or judged[i]:
                        c.judged_hits += 1
                        if bool(positives[i]):
                            c.positive_hits += 1
        self.total_cost_usd += llm_cost
        self.baseline_cost_usd += baseline_cost
        self.cache_path_time_s += cache_time_s
        self.llm_path_time_s += llm_time_s
        self.baseline_time_s += baseline_time
        self.queries += len(categories)

    # ------------------------------------------------------------------ #
    def summary(self) -> dict:
        cats = {}
        for cat, m in sorted(self.per_category.items()):
            cats[cat] = {
                "lookups": m.lookups,
                "cache_hits": m.hits,
                "hit_rate": round(m.hit_rate, 4),
                "positive_hits": m.positive_hits,
                "positive_rate": round(m.positive_rate, 4),
                "api_call_fraction": round(m.api_call_fraction, 4),
            }
        avg_with = ((self.cache_path_time_s + self.llm_path_time_s)
                    / max(self.queries, 1))
        avg_without = self.baseline_time_s / max(self.queries, 1)
        tenants = {}
        for name, t in sorted(self.per_tenant.items()):
            tenants[name] = {
                "lookups": t.lookups,
                "cache_hits": t.hits,
                "hit_rate": round(t.hit_rate, 4),
                "coalesced_calls": t.coalesced,
                "latency_percentiles": {
                    path: res.summary()
                    for path, res in sorted(t.latency_samples.items())},
            }
        context = {}
        if self.context_seen:
            context = {"context": self.context.row(),
                       "single_turn": self.single_turn.row()}
        return {
            "categories": cats,
            "tenants": tenants,
            "context": context,
            "near": self.near.row() if self.near_seen else {},
            "resilience": self.resilience.row()
            if self.resilience_seen else {},
            "queries": self.queries,
            "total_cost_usd": round(self.total_cost_usd, 4),
            "baseline_cost_usd": round(self.baseline_cost_usd, 4),
            "cost_saving_pct": round(
                100 * (1 - self.total_cost_usd
                       / max(self.baseline_cost_usd, 1e-9)), 2),
            "avg_latency_with_cache_s": round(avg_with, 4),
            "avg_latency_without_cache_s": round(avg_without, 4),
            "coalesced_calls": self.coalesced_calls,
            "latency_percentiles": {
                path: res.summary()
                for path, res in sorted(self.latency_samples.items())},
        }
