"""CachedEngine — the paper's full query-handling workflow (§2.5, §2.8)
wired together: embed -> semantic-cache lookup -> hit? serve cached :
call LLM backend -> insert -> respond.

The engine is batched (requests are grouped by the ``Batcher``), functional
on the device side (one jitted lookup+insert step with a donated slab) and
keeps host-side bookkeeping (detokenization table, metrics) minimal. A
ground-truth judge callback replaces the paper's GPT-4o-mini validation
(DESIGN.md §9): judge(query, matched_source_id) -> bool.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import SemanticCache
from repro.core.types import CacheConfig
from repro.data.tokenizer import HashTokenizer
from repro.embedding.hash_embedder import HashEmbedder
from repro.serving.metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    query: str
    category: str = "default"
    source_id: int = -1          # ground-truth provenance (evaluation only)
    semantic_key: str = ""


@dataclasses.dataclass
class Response:
    answer: str
    cached: bool
    score: float
    latency_s: float


class Batcher:
    """Fixed-size batching with padding (sync analogue of a request queue)."""

    def __init__(self, batch_size: int = 32):
        self.batch_size = batch_size

    def batches(self, requests: Sequence[Request]):
        for i in range(0, len(requests), self.batch_size):
            yield list(requests[i:i + self.batch_size])


class CachedEngine:
    def __init__(self, cache_config: CacheConfig, backend, *,
                 embedder: HashEmbedder | None = None,
                 tokenizer: HashTokenizer | None = None,
                 judge: Callable[[Request, int], bool] | None = None,
                 batch_size: int = 32,
                 policy=None,
                 index=None,
                 rebuild_every: int = 2048,
                 use_fused_step: bool = True):
        # ``policy``: optional threshold policy (e.g. AdaptiveThreshold —
        # paper §2.10 future work). With an adaptive policy the engine feeds
        # judged hit outcomes back after every batch, closing the paper's
        # proposed precision-tracking control loop.
        # ``index``: optional ANN index (e.g. IVFIndex). IVF is rebuilt every
        # ``rebuild_every`` inserts — the analogue of the paper's periodic
        # HNSW rebalancing (§2.4).
        self.cache = SemanticCache(cache_config, policy=policy, index=index)
        self.policy_state = self.cache.init_policy()
        self.ivf_state = None
        self.rebuild_every = rebuild_every
        self._inserts_since_rebuild = 0
        self._rebuild_rng = jax.random.PRNGKey(17)
        self.backend = backend
        self.embedder = embedder or HashEmbedder(dim=cache_config.dim)
        self.tokenizer = tokenizer or HashTokenizer()
        self.judge = judge
        self.batcher = Batcher(batch_size)
        self.metrics = ServingMetrics()
        self.state, self.stats = self.cache.init()
        self._now = 0.0
        from repro.core.index import IVFIndex
        self._is_ivf = isinstance(self.cache.index, IVFIndex)
        if self._is_ivf:
            self._lookup_jit = jax.jit(
                lambda st, s, q, t, ps, ivf: self.cache.lookup(
                    st, s, q, t, policy_state=ps, ivf_state=ivf))
        else:
            self._lookup_jit = jax.jit(
                lambda st, s, q, t, ps: self.cache.lookup(
                    st, s, q, t, policy_state=ps))
        self._insert_jit = jax.jit(
            lambda st, s, q, v, vl, t, sid, m: self.cache.insert(
                st, s, q, v, vl, t, source_id=sid, mask=m))

    # ------------------------------------------------------------------ #
    def save_cache(self, path: str) -> None:
        """Persist the slab + counters (the Redis RDB-snapshot analogue):
        a restarted engine resumes serving hits immediately."""
        from repro.training.checkpoint import save_checkpoint
        save_checkpoint(path, {"state": self.state, "stats": self.stats},
                        metadata={"now": self._now,
                                  "dim": self.cache.config.dim,
                                  "capacity": self.cache.config.capacity})

    def load_cache(self, path: str) -> None:
        from repro.training.checkpoint import load_checkpoint
        template = {"state": self.state, "stats": self.stats}
        restored = load_checkpoint(path, template)
        self.state, self.stats = restored["state"], restored["stats"]
        self.ivf_state = None   # force a rebuild on the next IVF lookup

    def _maybe_rebuild_index(self) -> None:
        if self.ivf_state is None or \
                self._inserts_since_rebuild >= self.rebuild_every:
            self._rebuild_rng, k = jax.random.split(self._rebuild_rng)
            self.ivf_state = self.cache.rebuild_index(
                self.state, jnp.float32(self._now), k)
            self._inserts_since_rebuild = 0

    def tick(self, seconds: float) -> None:
        """Advance the TTL clock (tests drive expiry deterministically)."""
        self._now += seconds

    def warm(self, pairs) -> None:
        """Cache population phase (paper §3.1): embed+insert the corpus."""
        cfg = self.cache.config
        bs = 256
        for i in range(0, len(pairs), bs):
            chunk = pairs[i:i + bs]
            emb = jnp.asarray(self.embedder.embed_batch(
                [p.question for p in chunk]))
            toks, lens = self.tokenizer.encode_batch(
                [p.answer for p in chunk], cfg.value_len)
            sid = jnp.asarray([p.qa_id for p in chunk], dtype=jnp.int32)
            self.state, self.stats = self._insert_jit(
                self.state, self.stats, emb, jnp.asarray(toks),
                jnp.asarray(lens), jnp.float32(self._now), sid,
                jnp.ones((len(chunk),), dtype=bool))
            self._inserts_since_rebuild += len(chunk)

    # ------------------------------------------------------------------ #
    def process(self, requests: Sequence[Request]) -> list[Response]:
        out: list[Response] = []
        for batch in self.batcher.batches(requests):
            out.extend(self._process_batch(batch))
        return out

    def _process_batch(self, batch: list[Request]) -> list[Response]:
        cfg = self.cache.config
        t0 = time.perf_counter()
        emb = jnp.asarray(self.embedder.embed_batch([r.query for r in batch]))
        if self._is_ivf:
            self._maybe_rebuild_index()
            result, self.state, self.stats = self._lookup_jit(
                self.state, self.stats, emb, jnp.float32(self._now),
                self.policy_state, self.ivf_state)
        else:
            result, self.state, self.stats = self._lookup_jit(
                self.state, self.stats, emb, jnp.float32(self._now),
                self.policy_state)
        hit = np.asarray(result.hit)
        scores = np.asarray(result.score)
        matched_sid = np.asarray(result.source_id)
        cache_time = time.perf_counter() - t0

        # miss path: one LLM call for the missed rows (paper §2.5 step 2)
        miss_idx = [i for i in range(len(batch)) if not hit[i]]
        llm_time = 0.0
        llm_cost = 0.0
        answers: dict[int, str] = {}
        if miss_idx:
            res = self.backend.generate(
                [batch[i].query for i in miss_idx],
                [batch[i].semantic_key for i in miss_idx])
            llm_time += res.latency_s
            llm_cost += res.cost_usd
            # insert misses (store answer tokens + provenance); responses are
            # returned tokenizer-normalized so the hit and miss paths emit
            # byte-identical text for the same cache entry
            toks, lens = self.tokenizer.encode_batch(
                [res.answers[j] for j in range(len(miss_idx))], cfg.value_len)
            for j, i in enumerate(miss_idx):
                answers[i] = self.tokenizer.decode(toks[j])
            memb = emb[jnp.asarray(miss_idx)]
            sid = jnp.asarray([batch[i].source_id for i in miss_idx],
                              dtype=jnp.int32)
            self.state, self.stats = self._insert_jit(
                self.state, self.stats, memb, jnp.asarray(toks),
                jnp.asarray(lens), jnp.float32(self._now), sid,
                jnp.ones((len(miss_idx),), dtype=bool))
            self._inserts_since_rebuild += len(miss_idx)

        # hit path: detokenize cached responses
        vals = np.asarray(result.values)
        for i in range(len(batch)):
            if hit[i]:
                answers[i] = self.tokenizer.decode(vals[i])

        # judge hits (ground-truth oracle replaces GPT-4o-mini)
        positives = np.zeros((len(batch),), dtype=bool)
        if self.judge is not None:
            for i in range(len(batch)):
                if hit[i]:
                    positives[i] = self.judge(batch[i], int(matched_sid[i]))
            # adaptive-threshold feedback (paper §2.10): judged precision
            # nudges the threshold toward the target
            if hasattr(self.cache.policy, "update"):
                self.policy_state = self.cache.policy.update(
                    self.policy_state,
                    was_positive=jnp.asarray(positives),
                    was_hit=jnp.asarray(hit))

        # metrics: baseline = every query pays the LLM call
        n = len(batch)
        per_call = getattr(self.backend, "latency_per_call_s", None)
        baseline_time = (per_call or (llm_time / max(len(miss_idx), 1))) * n
        per_cost = getattr(self.backend, "cost_per_call_usd", 0.0)
        self.metrics.record_batch(
            [r.category for r in batch], hit, positives,
            judged=[self.judge is not None and bool(h) for h in hit],
            cache_time_s=cache_time, llm_time_s=llm_time,
            llm_cost=llm_cost, baseline_cost=per_cost * n,
            baseline_time=baseline_time)

        per_q_latency = (cache_time + llm_time) / n
        return [Response(answer=answers[i], cached=bool(hit[i]),
                         score=float(scores[i]), latency_s=per_q_latency)
                for i in range(len(batch))]
