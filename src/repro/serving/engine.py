"""CachedEngine — the paper's full query-handling workflow (§2.5, §2.8)
wired together: embed -> semantic-cache lookup -> hit? serve cached :
call LLM backend -> insert -> respond.

The engine is batched (requests are grouped by the ``Batcher``), functional
on the device side and keeps host-side bookkeeping (detokenization table,
metrics) minimal. All device state lives in one ``CacheRuntime`` pytree
(DESIGN.md §2) — the engine holds exactly one mutable reference,
``self.runtime``, and never branches on index or policy type.

Two serve paths (DESIGN.md §7):
  * fused (``use_fused_step=True``, default): a pure *peek* lookup learns
    the miss set, the backend answers the misses, then one compiled
    ``SemanticCache.step`` does lookup + masked full-batch insert — static
    shapes at every batch size, so no per-miss-count retraces;
  * separate: mutating lookup, then an insert of just the missed rows
    (retraces per distinct miss count; kept as the reference path).

A ground-truth judge callback replaces the paper's GPT-4o-mini validation
(DESIGN.md §9): judge(query, matched_source_id) -> bool.

Multi-tenancy (DESIGN.md §13): constructing the engine with a
``TenantRegistry`` partitions the slab into per-tenant regions and threads
each request's ``tenant`` through the same compiled step — same batch
shapes, same jit cache, but lookups/inserts are masked to each row's own
region and both ``ServingMetrics`` and the device-side ``TenancyState``
keep per-tenant accounting.

Multi-turn sessions (DESIGN.md §16): constructing the engine with a
``ContextFusion`` strategy attaches a ``SessionStore`` and threads each
request's ``session`` through the same compiled step — a (B, W, d) window
of the session's prior raw turn embeddings rides along as one more traced
operand, the fused key searches AND populates the slab, and sessionless
rows (empty ``session``) pass through bit-identically, so session and
stateless traffic share one compiled program.

Generative near-hits (DESIGN.md §17): constructing the engine with a
``Synthesizer`` (and a band policy — defaulted when ``policy=None``)
routes lookups scoring in [τ_lo, τ_hi) through host-side answer synthesis
from their top-k neighbours instead of a full backend call. Converted
rows are admitted back into the slab under their own key with the
dominant neighbour's provenance, judged like exact hits, and fed back
into the band's lower edge. Without a synthesizer the band masks are
all-False and every path is bit-identical to binary hit/miss serving.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import SemanticCache
from repro.core.runtime import CacheRuntime
from repro.core.types import CacheConfig
from repro.data.tokenizer import HashTokenizer
from repro.embedding.hash_embedder import HashEmbedder
from repro.obs.explain import build_why, effective_edges
from repro.obs.trace import Tracer
from repro.serving.metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    query: str
    category: str = "default"
    source_id: int = -1          # ground-truth provenance (evaluation only)
    semantic_key: str = ""
    tenant: str = "default"      # isolation domain (multi-tenant serving,
                                 # DESIGN.md §13); ignored without a registry
    session: str = ""            # conversation id (multi-turn context,
                                 # DESIGN.md §16); "" = stateless request;
                                 # ignored without a fusion strategy
    explain: bool = False        # attach a decision-attribution ``why``
                                 # record to the Response (DESIGN.md §18.3)
    deadline_ms: float | None = None
                                 # remaining latency budget (SLO) in ms; the
                                 # scheduler decrements queue wait before
                                 # dispatch, and the §20.3 retry loop never
                                 # sleeps past it. None = no deadline.


@dataclasses.dataclass
class Response:
    answer: str
    cached: bool
    score: float
    latency_s: float
    coalesced: bool = False   # served by attaching to an in-flight duplicate
                              # (async scheduler, DESIGN.md §12.3)
    context: bool = False     # looked up under a non-empty session turn
                              # window, i.e. the key was context-fused (§16)
    near_hit: bool = False    # synthesized from top-k neighbours in the
                              # [τ_lo, τ_hi) band (§17) — ``cached`` stays
                              # False: near-hits are provenance-distinct
                              # from exact reuse
    trace_id: str = ""        # RequestTrace id when tracing retained this
                              # request's journey ("" when tracing is off)
    why: dict | None = None   # decision attribution (§18.3); only set when
                              # the request opted in via Request.explain or
                              # the engine forces explain_responses=True
    degraded: bool = False    # served from the best cached neighbour under
                              # the relaxed degraded floor because the
                              # backend was unavailable / budget exhausted
                              # (§20.4) — never admitted to the slab
    error: str = ""           # non-empty when this row's backend call
                              # failed and no degraded answer was servable;
                              # the async scheduler converts it into a
                              # per-row BackendError (§20.2)


#: Row used to right-pad a partial batch up to the engine's fixed batch
#: size. Its empty query embeds to the zero vector (cosine 0 against every
#: slab key — always a miss), and the ``valid`` mask threaded through the
#: fused step guarantees pad rows never touch counters or the slab.
PAD_REQUEST = Request(query="", category="__pad__", source_id=-1)


class Batcher:
    """Fixed-size batching with padding (sync analogue of a request queue)."""

    def __init__(self, batch_size: int = 32):
        self.batch_size = batch_size

    def batches(self, requests: Sequence[Request]):
        for i in range(0, len(requests), self.batch_size):
            yield list(requests[i:i + self.batch_size])

    def pad(self, batch: list[Request]) -> tuple[list[Request], int]:
        """Right-pad ``batch`` to the fixed batch size (DESIGN.md §12.2).

        Returns ``(padded_batch, n_valid)``. Every admission batch — the
        final partial batch of a sync workload or a deadline flush from the
        async scheduler — then shares ONE compiled shape, instead of
        retracing the fused step per distinct ragged size. Callers must
        route only the first ``n_valid`` rows into metrics and responses.
        """
        n = len(batch)
        if n >= self.batch_size:
            return list(batch), n
        return list(batch) + [PAD_REQUEST] * (self.batch_size - n), n


class CachedEngine:
    def __init__(self, cache_config: CacheConfig, backend, *,
                 embedder: HashEmbedder | None = None,
                 tokenizer: HashTokenizer | None = None,
                 judge: Callable[[Request, int], bool] | None = None,
                 batch_size: int = 32,
                 policy=None,
                 index=None,
                 rebuild_every: int = 2048,
                 use_fused_step: bool = True,
                 registry=None,
                 fusion=None,
                 session_ttl_s: float | None = 1800.0,
                 max_sessions: int = 4096,
                 synthesizer=None,
                 tracer: Tracer | None = None,
                 events=None,
                 explain_responses: bool = False,
                 resilience=None,
                 mesh=None,
                 cache_axes: tuple = ("data",)):
        # ``policy``: optional threshold policy (e.g. AdaptiveThreshold —
        # paper §2.10 future work). With an adaptive policy the engine feeds
        # judged hit outcomes back after every batch, closing the paper's
        # proposed precision-tracking control loop.
        # ``index``: optional ANN index (e.g. IVFIndex). The index is refit
        # every ``rebuild_every`` inserts — the analogue of the paper's
        # periodic HNSW rebalancing (§2.4); a no-op for stateless indexes.
        # ``registry``: optional TenantRegistry — partitions the slab into
        # per-tenant regions and routes each Request.tenant through the
        # compiled step (DESIGN.md §13). None = single-tenant (unchanged).
        # ``fusion``: optional ContextFusion strategy (DESIGN.md §16) —
        # attaches a SessionStore (TTL ``session_ttl_s`` on the engine's
        # tick clock, LRU-capped at ``max_sessions``) and fuses each
        # session row's turn window into its lookup/insert key inside the
        # compiled step. None = single-turn (unchanged).
        # ``synthesizer``: optional near-hit Synthesizer (DESIGN.md §17) —
        # band rows ([τ_lo, τ_hi) scores) are served by composing from
        # their top-k neighbours instead of a full backend call, and the
        # synthesized answer is admitted into the slab under the query's
        # own key. Requires a band policy; passing a synthesizer with
        # policy=None defaults the policy to BandPolicy(tau_hi=threshold).
        # None = binary hit/miss (unchanged — the band masks are all-False
        # and the compiled step is identical to the band-less program).
        # ``tracer``: optional repro.obs.Tracer (DESIGN.md §18.1) — threads
        # per-request stage spans through serve_batch. None = a disabled
        # Tracer: every hook is the shared NULL_TRACE singleton, so the
        # hot path allocates nothing.
        # ``events``: optional repro.obs.EventLog — one structured event
        # per serve step (batch composition + CacheStats delta, §18.4).
        # ``explain_responses``: force a ``why`` record onto EVERY
        # response (demos/debugging); normally per-request opt-in via
        # Request.explain.
        # ``resilience``: optional ResilienceConfig (DESIGN.md §20) — the
        # miss path gains deadline-budgeted retries, a circuit breaker and
        # degraded-mode serving from cached neighbours. None = a single
        # backend attempt whose failure marks only its own rows (§20.2);
        # with no faults every path is bit-identical to the pre-§20 engine.
        # ``mesh``: optional jax.sharding.Mesh — wraps the cache in a
        # DistributedCache (DESIGN.md §19): the slab is sharded over
        # ``cache_axes`` and every jitted call below goes through the
        # shard_map'd step. None = single-device (unchanged).
        if synthesizer is not None and policy is None:
            from repro.generative.policy import BandPolicy
            policy = BandPolicy(tau_hi=cache_config.threshold)
        self.synthesizer = synthesizer
        self.registry = registry
        self.mesh = mesh
        num_shards = 1
        if mesh is not None:
            from repro.core.distributed import shard_axes
            num_shards = shard_axes(mesh, tuple(cache_axes))
        self._num_shards = num_shards
        partition = None
        if registry is not None:
            partition = registry.partition(cache_config.capacity)
            if min(partition.sizes) // num_shards < batch_size:
                # the per-tenant ring guarantees distinct slots only while a
                # batch's rows per tenant fit inside the tenant's region —
                # on a mesh, inside the *per-shard* slice of the region
                # (parked rows of a masked insert may wrap otherwise)
                raise ValueError(
                    f"smallest tenant region ({min(partition.sizes)} slots "
                    f"/ {num_shards} shard(s), tenant "
                    f"{partition.names[partition.sizes.index(min(partition.sizes))]!r}) "
                    f"is below the batch size ({batch_size}); grow the slab "
                    "or the tenant's share/quota")
            self._tenant_index = {n: i for i, n in enumerate(partition.names)}
        elif cache_config.capacity // num_shards < batch_size:
            raise ValueError(
                f"per-shard capacity ({cache_config.capacity} slots / "
                f"{num_shards} shard(s)) is below the batch size "
                f"({batch_size}); grow the slab or shrink the mesh")
        base_cache = SemanticCache(cache_config, policy=policy, index=index,
                                   partition=partition, fusion=fusion)
        if mesh is not None:
            from repro.core.distributed import DistributedCache
            self.cache = DistributedCache(base_cache, mesh,
                                          cache_axes=tuple(cache_axes))
        else:
            self.cache = base_cache
        self.fusion = fusion
        self.sessions = None
        if fusion is not None:
            from repro.context.session import SessionStore
            self.sessions = SessionStore(
                window=fusion.window, dim=cache_config.dim,
                ttl=session_ttl_s, max_sessions=max_sessions)
        self.runtime: CacheRuntime = self.cache.init()
        self.use_fused_step = use_fused_step
        self.rebuild_every = rebuild_every
        self._inserts_since_rebuild = 0
        self._needs_refit = True
        self._rebuild_rng = jax.random.PRNGKey(17)
        self.backend = backend
        self.embedder = embedder or HashEmbedder(dim=cache_config.dim)
        self.tokenizer = tokenizer or HashTokenizer()
        self.judge = judge
        self.batcher = Batcher(batch_size)
        self.metrics = ServingMetrics()
        self.tracer = tracer if tracer is not None else Tracer()
        self.events = events
        self.explain_all = explain_responses
        self.resilience = resilience
        self._now = 0.0
        # One uniform set of jitted pure functions — no index/policy
        # branches. The runtime is owned linearly (each call's output
        # replaces self.runtime), so its buffers are donated: slab updates
        # are in-place at the XLA level instead of copying the slab per
        # batch. The peek must NOT donate — the same runtime is fed to the
        # fused step right after.
        # ``tid`` is the per-row tenant-id vector (None on a single-tenant
        # engine — an empty pytree, so the compiled signature is unchanged).
        # ``w``/``wl`` are the per-row session turn windows (None on a
        # fusion-less engine — same empty-pytree trick, §16.3).
        self._lookup_jit = jax.jit(
            lambda rt, q, t, tid, w, wl: self.cache.lookup(
                rt, q, t, tenant_id=tid, window=w, window_len=wl),
            donate_argnums=(0,))
        self._peek_jit = jax.jit(
            lambda rt, q, t, tid, w, wl: self.cache.lookup(
                rt, q, t, update_counters=False, tenant_id=tid,
                window=w, window_len=wl)[0])
        self._insert_jit = jax.jit(
            lambda rt, q, v, vl, t, sid, m, tid: self.cache.insert(
                rt, q, v, vl, t, source_id=sid, mask=m, tenant_id=tid),
            donate_argnums=(0,))
        self._step_jit = jax.jit(
            lambda rt, q, mv, mvl, t, sid, peek, valid, tid, w, wl:
            self.cache.step(
                rt, q, mv, mvl, t, source_id=sid, peeked=peek, valid=valid,
                tenant_id=tid, window=w, window_len=wl),
            donate_argnums=(0,))
        # standalone fusion op for the separate (reference) path, which
        # must insert the same fused keys the fused step would
        self._fuse_jit = jax.jit(
            lambda rt, q, w, wl: self.cache._maybe_fuse(rt, q, w, wl))
        # top-k neighbour payload gather for the near-hit path (§17.3):
        # pure read of the slab, never donated — the runtime is reused by
        # the fused step right after, exactly like the peek
        self._gather_topk_jit = jax.jit(
            lambda rt, res: self.cache.gather_topk(rt, res))
        self._refit_jit = jax.jit(
            lambda rt, t, k: self.cache.refit(rt, t, k),
            donate_argnums=(0,))

    # -- runtime views (read-only conveniences) ------------------------- #
    @property
    def state(self):
        return self.runtime.state

    @property
    def stats(self):
        return self.runtime.stats

    @property
    def policy_state(self):
        return self.runtime.policy_state

    def tenant_stats(self) -> dict:
        """Device-side per-tenant accounting (TenancyState counters), keyed
        by tenant name. Empty dict on a single-tenant engine."""
        t = self.runtime.tenancy
        if t is None:
            return {}
        # on a mesh the counters are stacked per-shard (S, T); the reduce
        # is exact because each event is attributed on exactly one shard
        t = t.reduced()
        part = self.cache.partition
        return {
            name: {
                "lookups": int(t.lookups[i]),
                "hits": int(t.hits[i]),
                "misses": int(t.lookups[i]) - int(t.hits[i]),
                "inserts": int(t.inserts[i]),
                "evictions": int(t.evictions[i]),
                "region_slots": part.sizes[i],
            }
            for i, name in enumerate(part.names)
        }

    # ------------------------------------------------------------------ #
    def save_cache(self, path: str) -> None:
        """Persist the *entire* runtime (the Redis RDB-snapshot analogue):
        slab, counters, policy state and index state — a restarted engine
        resumes serving hits immediately, keeps its adapted threshold and
        pays no forced index rebuild."""
        from repro.training.checkpoint import save_checkpoint
        part = self.cache.partition
        save_checkpoint(path, {"runtime": self.runtime},
                        metadata={"now": self._now,
                                  "dim": self.cache.config.dim,
                                  "capacity": self.cache.config.capacity,
                                  "index": type(self.cache.index).__name__,
                                  "policy": type(self.cache.policy).__name__,
                                  # static partition map: restores must be
                                  # built with the same tenant layout or the
                                  # per-tenant ring pointers/regions disagree
                                  "partition": None if part is None
                                  else part.manifest(),
                                  "fusion": None if self.fusion is None
                                  else type(self.fusion).__name__,
                                  # mesh shape + shard layout: a restore
                                  # onto a different layout must go through
                                  # reshard_runtime, not a strict load
                                  "shard_layout": None if self.mesh is None
                                  else self.cache.shard_layout()})

    def load_cache(self, path: str, *, reshard: bool = True) -> None:
        import json
        import os
        from repro.training.checkpoint import (load_checkpoint,
                                               open_checkpoint,
                                               reshard_runtime)
        # Fusion-aware restore (§16.5). The fusion leaf group follows the
        # tenancy None-keeps-the-treedef contract, so the npz either has
        # "runtime/fusion/..." keys (session-era snapshot) or none at all.
        # open_checkpoint reads every member eagerly, so a truncated or
        # corrupt snapshot fails HERE with CheckpointCorruptError naming
        # the file (§20 crash-safety), not with an arbitrary zipfile
        # traceback halfway through the restore.
        flat = open_checkpoint(path)
        saved_keys = list(flat)
        has_fusion_keys = any(k.startswith("runtime/fusion/")
                              for k in saved_keys)
        if has_fusion_keys and self.fusion is None:
            # silently dropping learned fusion weights would change every
            # fused key this snapshot's slab entries were stored under
            raise ValueError(
                f"snapshot {path!r} carries context-fusion weights "
                "(runtime/fusion/*) but this engine has no fusion "
                "strategy; construct the engine with fusion=... to load it")
        # Shard-layout gate (§19.5): the manifest records the mesh shape
        # the snapshot was taken under. Same layout -> strict load; a
        # different shard count -> reshard-on-load (or refuse).
        meta = {}
        manifest = path + ".manifest.json"
        if os.path.exists(manifest):
            with open(manifest) as f:
                meta = json.load(f).get("metadata", {})
            # partition maps are static config: a snapshot taken under one
            # tenant layout silently mis-regions under another, so verify
            saved = meta.get("partition")
            part = self.cache.partition
            current = None if part is None else part.manifest()
            if saved != current:
                raise ValueError(
                    f"snapshot partition map {saved} does not match this "
                    f"engine's {current}; rebuild the engine with the "
                    "registry the snapshot was taken under")
        saved_layout = meta.get("shard_layout")
        saved_shards = 1 if saved_layout is None \
            else int(saved_layout["num_shards"])
        if saved_shards != self._num_shards:
            if not reshard:
                raise ValueError(
                    f"snapshot {path!r} was taken on {saved_shards} shard(s) "
                    f"but this engine runs {self._num_shards}; pass "
                    "reshard=True to re-place the entries on load")
            # Cross-layout restore: re-place live entries into this
            # layout's rings on the host, keep a fresh index and force a
            # refit (the saved buckets hold old-placement local slot ids).
            fresh = self.cache.init()
            restored_runtime = reshard_runtime(
                flat, fresh,
                old_shards=saved_shards, new_shards=self._num_shards,
                partition=self.cache.partition)
            needs_refit = True
        else:
            template_runtime = self.runtime
            if not has_fusion_keys and self.fusion is not None:
                # pre-session snapshot into a session-enabled engine is
                # fine: restore the shared leaves, keep this engine's fresh
                # fusion state (slab keys in that snapshot were never
                # fused, and raw single-turn lookups still match them
                # bit-identically)
                template_runtime = self.runtime.replace(fusion=None)
            restored = load_checkpoint(path, {"runtime": template_runtime})
            restored_runtime = restored["runtime"]
            # index state was checkpointed with the slab — no forced rebuild
            needs_refit = False
        if restored_runtime.fusion is None and self.runtime.fusion is not None:
            restored_runtime = restored_runtime.replace(
                fusion=self.runtime.fusion)
        if self.mesh is not None:
            restored_runtime = self.cache.place(restored_runtime)
        self.runtime = restored_runtime
        # restore the TTL clock: slab expiries are *absolute* deadlines, so
        # resuming at now=0 would extend every entry's remaining lifetime.
        # save_checkpoint names the manifest after the path it was *given*
        # (np.savez appends .npz to the data file only), so mirror that.
        self._now = float(meta.get("now", self._now))
        self._needs_refit = needs_refit
        self._inserts_since_rebuild = 0

    def _maybe_refit(self) -> None:
        if self._needs_refit or \
                self._inserts_since_rebuild >= self.rebuild_every:
            self._rebuild_rng, k = jax.random.split(self._rebuild_rng)
            self.runtime = self._refit_jit(
                self.runtime, jnp.float32(self._now), k)
            self._needs_refit = False
            self._inserts_since_rebuild = 0

    def tick(self, seconds: float) -> None:
        """Advance the TTL clock (tests drive expiry deterministically)."""
        self._now += seconds

    def _session_windows(self, batch):
        """Per-row session turn windows for a (possibly padded) batch.

        Returns ``(window (B, W, d), window_len (B,), has_ctx)`` — or
        ``(None, None, [False]*B)`` on a fusion-less engine (None is an
        empty pytree, so the compiled signature is unchanged). Sessionless
        and pad rows get a zero window with length 0, which the fusion op
        passes through bit-identically (§16.3) — session and stateless
        rows share one compiled program at every mix.
        """
        if self.sessions is None:
            return None, None, [False] * len(batch)
        win = np.zeros((len(batch), self.sessions.window_size,
                        self.sessions.dim), dtype=np.float32)
        wlen = np.zeros((len(batch),), dtype=np.int32)
        for i, r in enumerate(batch):
            if r is PAD_REQUEST or not r.session:
                continue
            w, c = self.sessions.window_for(r.tenant, r.session, self._now)
            win[i] = w
            wlen[i] = c
        return (jnp.asarray(win), jnp.asarray(wlen),
                [bool(c) for c in wlen])

    def _canonical_keys(self, result, emb, win, wlen) -> np.ndarray:
        """(B, d) canonical slab key per row (§16.1): the matched entry's
        stored key on a hit, the row's own fused key — exactly what the
        step inserted — on a miss. Appending these (not raw embeddings)
        makes two conversations in the same dialogue state converge to
        identical turn windows, so their fused keys match at every depth."""
        fused = self._fuse_jit(self.runtime, emb, win, wlen)
        matched = jnp.take(self.runtime.state.keys, result.index,
                           axis=0).astype(jnp.float32)
        if self.cache.config.key_dtype == jnp.int8:
            matched = matched / 127.0          # symmetric unit-row quant
        return np.asarray(jnp.where(result.hit[:, None], matched, fused),
                          dtype=np.float32)

    def _append_turns(self, batch, n_valid: int, keys_np: np.ndarray,
                      skip=()) -> None:
        """Push each served session row's canonical turn key (§16.1) —
        after the batch, so a turn's own key never fuses into its own
        lookup and co-batched turns of one session can't race. ``skip``
        holds failed/degraded row indexes (§20): those turns were never
        answered from the slab, so their keys must not advance the
        session window."""
        if self.sessions is None:
            return
        for i in range(n_valid):
            r = batch[i]
            if r.session and i not in skip:
                self.sessions.append(r.tenant, r.session, keys_np[i],
                                     self._now)

    def _tenant_ids(self, batch) -> "jax.Array | None":
        """(B,) int32 tenant ids for a (possibly padded) batch; None on a
        single-tenant engine. Pad rows route as tenant 0 — harmless, since
        the ``valid`` mask keeps them out of every counter and the slab."""
        if self.registry is None:
            return None
        ids = []
        for r in batch:
            if r is PAD_REQUEST:
                ids.append(0)
            else:
                try:
                    ids.append(self._tenant_index[r.tenant])
                except KeyError:
                    raise KeyError(
                        f"unknown tenant {r.tenant!r}; registered: "
                        f"{tuple(self._tenant_index)}") from None
        return jnp.asarray(ids, dtype=jnp.int32)

    def warm(self, pairs, tenant: str | None = None) -> None:
        """Cache population phase (paper §3.1): embed+insert the corpus.

        On a multi-tenant engine the corpus lands in ``tenant``'s region
        (default: the registry's first tenant) — warm each tenant
        separately with its own corpus."""
        cfg = self.cache.config
        # distinct-slot guarantee: one chunk must fit inside the (per-shard
        # slice of the) target ring, else parked/written rows can alias
        bs = min(256, cfg.capacity // self._num_shards)
        tid_value = None
        if self.registry is not None:
            name = tenant if tenant is not None else self.registry.names[0]
            tid_value = self.registry.index(name)
            bs = min(bs, self.cache.partition.sizes[tid_value]
                     // self._num_shards)
        elif tenant is not None:
            raise ValueError("warm(tenant=...) needs a tenant registry")
        for i in range(0, len(pairs), bs):
            chunk = pairs[i:i + bs]
            emb = jnp.asarray(self.embedder.embed_batch(
                [p.question for p in chunk]))
            toks, lens = self.tokenizer.encode_batch(
                [p.answer for p in chunk], cfg.value_len)
            sid = jnp.asarray([p.qa_id for p in chunk], dtype=jnp.int32)
            tid = None if tid_value is None else jnp.full(
                (len(chunk),), tid_value, dtype=jnp.int32)
            self.runtime = self._insert_jit(
                self.runtime, emb, jnp.asarray(toks),
                jnp.asarray(lens), jnp.float32(self._now), sid,
                jnp.ones((len(chunk),), dtype=bool), tid)
            self._inserts_since_rebuild += len(chunk)

    # ------------------------------------------------------------------ #
    def process(self, requests: Sequence[Request]) -> list[Response]:
        out: list[Response] = []
        for batch in self.batcher.batches(requests):
            out.extend(self.serve_batch(batch))
        return out

    def _generate_misses(self, batch, miss_idx):
        """Backend call + tokenizer round-trip for the missed rows.

        Returns (token rows, lens, decoded answers, llm_time, llm_cost).
        Responses are tokenizer-normalized so the hit and miss paths emit
        byte-identical text for the same cache entry.
        """
        cfg = self.cache.config
        res = self.backend.generate(
            [batch[i].query for i in miss_idx],
            [batch[i].semantic_key for i in miss_idx])
        toks, lens = self.tokenizer.encode_batch(
            [res.answers[j] for j in range(len(miss_idx))], cfg.value_len)
        answers = {i: self.tokenizer.decode(toks[j])
                   for j, i in enumerate(miss_idx)}
        return toks, lens, answers, res.latency_s, res.cost_usd

    def _split_expired(self, batch, miss_idx):
        """Split the miss set into rows whose deadline budget is already
        spent (they go straight to degraded serving, §20.3) and rows still
        worth a backend call. No-op without a resilience config."""
        if self.resilience is None:
            return {}, list(miss_idx)
        failed: dict[int, str] = {}
        gen_idx: list[int] = []
        for i in miss_idx:
            d = batch[i].deadline_ms
            if d is not None and d <= 0.0:
                self.metrics.resilience.deadline_exhausted += 1
                failed[i] = ("DeadlineExhausted: budget spent before the "
                             "backend call")
            else:
                gen_idx.append(i)
        return failed, gen_idx

    def _resolve_misses(self, batch, miss_idx):
        """One backend resolution for the miss rows: containment + retries.

        Returns ``(result_tuple, None)`` on success or ``(None, err_msg)``
        — the caller turns ``err_msg`` into per-row degraded/error
        responses (§20.2) instead of letting the exception fail the whole
        batch. With a resilience config the call is gated by the circuit
        breaker and retried under the §20.3 backoff/deadline-budget rules;
        without one it is a single attempt whose failure is still
        contained to its own rows.
        """
        r = self.resilience
        rm = self.metrics.resilience
        if r is None:
            try:
                return self._generate_misses(batch, miss_idx), None
            except Exception as exc:
                self.metrics.resilience_seen = True
                rm.backend_failures += 1
                return None, f"{type(exc).__name__}: {exc}"
        budget_s = None
        deadlines = [batch[i].deadline_ms for i in miss_idx
                     if batch[i].deadline_ms is not None]
        if deadlines:
            # one call serves every miss row, so the tightest row's budget
            # bounds the retry schedule for the whole set
            budget_s = min(deadlines) / 1000.0
        key = batch[miss_idx[0]].query
        start = r.clock()
        attempt = 0
        while True:
            if r.breaker is not None and not r.breaker.allow():
                rm.breaker_short_circuits += 1
                return None, ("BreakerOpen: circuit breaker is open; "
                              "backend call short-circuited")
            attempt += 1
            try:
                out = self._generate_misses(batch, miss_idx)
            except Exception as exc:
                if r.breaker is not None:
                    r.breaker.record_failure()
                rm.backend_failures += 1
                delay = r.retry.backoff_s(attempt, key=key)
                elapsed = r.clock() - start
                if not r.retry.allows(attempt, elapsed_s=elapsed,
                                      next_backoff_s=delay,
                                      budget_s=budget_s):
                    if (budget_s is not None
                            and attempt < r.retry.max_attempts):
                        rm.deadline_exhausted += 1
                    return None, f"{type(exc).__name__}: {exc}"
                rm.retries += 1
                r.sleep(delay)
                continue
            if r.breaker is not None:
                r.breaker.record_success()
            if attempt > 1:
                rm.retry_successes += 1
            return out, None

    def _degraded_floor(self) -> float:
        """Relaxed score floor for degraded serving: explicit config wins,
        else the band policy's ``degraded_lo`` edge, else 0.55 (§20.4)."""
        r = self.resilience
        if r is not None and r.degraded_band_lo is not None:
            return float(r.degraded_band_lo)
        dl = getattr(self.cache.policy, "degraded_lo", None)
        return 0.55 if dl is None else float(dl)

    def _serve_degraded(self, batch, failed, result):
        """Degraded-mode serving (§20.4): each failed miss row is offered
        the best cached neighbour at or above the relaxed degraded floor —
        synthesis first when a synthesizer is installed, else the dominant
        neighbour's stored answer verbatim. Returns row -> (answer, score,
        source_id). Served rows stay OUT of the slab (the caller clears
        their ``valid`` bits): a degraded answer is another entry's answer
        under the wrong key, and admitting it would keep poisoning exact
        lookups for this query long after the outage clears."""
        r = self.resilience
        if r is None or not r.degraded_serving or not failed:
            return {}
        floor = self._degraded_floor()
        rm = self.metrics.resilience
        payload = self._gather_topk_jit(self.runtime, result)
        nb_slot = np.asarray(result.topk_index)
        nb_score = np.asarray(payload["score"])
        nb_sid = np.asarray(payload["source_id"])
        nb_vals = np.asarray(payload["values"])
        out: dict[int, tuple[str, float, int]] = {}
        for i in sorted(failed):
            cand = [j for j in range(nb_slot.shape[1])
                    if nb_slot[i, j] >= 0 and nb_score[i, j] >= floor]
            if not cand:
                rm.degraded_failed += 1
                continue
            served = None
            if self.synthesizer is not None:
                from repro.generative.synthesize import Neighbour
                neighbours = [
                    Neighbour(slot=int(nb_slot[i, j]),
                              score=float(nb_score[i, j]),
                              source_id=int(nb_sid[i, j]),
                              answer=self.tokenizer.decode(nb_vals[i, j]))
                    for j in cand]
                syn = self.synthesizer.synthesize(batch[i].query, neighbours)
                if syn is not None:
                    served = (syn.answer, float(nb_score[i, cand[0]]),
                              int(syn.source_id))
            if served is None:
                j = cand[0]      # neighbours arrive score-descending
                served = (self.tokenizer.decode(nb_vals[i, j]),
                          float(nb_score[i, j]), int(nb_sid[i, j]))
            out[i] = served
            rm.degraded_served += 1
        return out

    def _synthesize_near(self, batch, n_valid: int, result):
        """Host-side near-hit synthesis (§17.3), shared by both serve paths.

        For every band row ([τ_lo, τ_hi) score) of ``result``, gather the
        row's visible top-k neighbours (one jitted slab read) and offer
        them to the synthesizer. Returns ``(syn_by_row, syn_time, syn_cost)``
        — ``syn_by_row`` maps row index -> ``Synthesis`` for the rows it
        converted; abstained rows are simply absent and fall back to the
        full backend call like any miss.
        """
        if self.synthesizer is None:
            return {}, 0.0, 0.0
        near = np.asarray(result.near)
        if not near[:n_valid].any():
            return {}, 0.0, 0.0
        from repro.generative.synthesize import Neighbour
        payload = self._gather_topk_jit(self.runtime, result)
        nb_slot = np.asarray(result.topk_index)
        nb_score = np.asarray(payload["score"])
        nb_sid = np.asarray(payload["source_id"])
        nb_vals = np.asarray(payload["values"])
        syn_by_row: dict[int, object] = {}
        syn_time = syn_cost = 0.0
        for i in range(n_valid):
            if not near[i]:
                continue
            neighbours = [
                Neighbour(slot=int(nb_slot[i, j]),
                          score=float(nb_score[i, j]),
                          source_id=int(nb_sid[i, j]),
                          answer=self.tokenizer.decode(nb_vals[i, j]))
                for j in range(nb_slot.shape[1]) if nb_slot[i, j] >= 0]
            syn = self.synthesizer.synthesize(batch[i].query, neighbours)
            if syn is not None:
                syn_by_row[i] = syn
                syn_time += syn.latency_s
                syn_cost += syn.cost_usd
        return syn_by_row, syn_time, syn_cost

    def _why_snapshot(self, result):
        """Decision-time attribution snapshot (§18.3): the policy state and
        the top-k neighbour payload, pulled to host BEFORE the fused step
        donates the runtime buffers and the judged feedback moves the
        edges — these are the values the decision was actually made under."""
        payload = self._gather_topk_jit(self.runtime, result)
        return (np.asarray(self.runtime.policy_state),
                {"slots": np.asarray(result.topk_index),
                 "scores": np.asarray(result.topk_score),
                 "source_ids": np.asarray(payload["source_id"])})

    def _build_whys(self, batch, n_valid, tid, hit, near_served, scores,
                    matched_idx, matched_sid, near_row, has_ctx,
                    syn_by_row, why_ps, why_topk):
        """Per-row ``why`` records for the rows that opted in (§18.3)."""
        tid_np = None if tid is None else np.asarray(tid)
        edges_by_tenant: dict = {}
        whys: list = [None] * n_valid
        for i in range(n_valid):
            if not (self.explain_all or batch[i].explain):
                continue
            tix = None if tid_np is None else int(tid_np[i])
            if tix not in edges_by_tenant:
                edges_by_tenant[tix] = effective_edges(
                    self.cache.policy, why_ps, self.cache.partition, tix)
            whys[i] = build_why(
                i, request=batch[i], hit=bool(hit[i]),
                near_served=bool(near_served[i]), score=float(scores[i]),
                matched_slot=int(matched_idx[i]),
                matched_source_id=int(matched_sid[i]),
                topk_slots=why_topk["slots"][i],
                topk_scores=why_topk["scores"][i],
                topk_source_ids=why_topk["source_ids"][i],
                edges=edges_by_tenant[tix],
                session_fused=bool(has_ctx[i]),
                synthesizer_present=self.synthesizer is not None,
                near_band=bool(near_row[i]),
                synthesis_source_id=(syn_by_row[i].source_id
                                     if i in syn_by_row else None))
        return whys

    def explain(self, query: str, *, tenant: str = "default",
                session: str = "") -> dict:
        """Offline decision attribution (§18.3): what WOULD the cache do
        with ``query`` right now, and why? Pure peek — no counters move,
        nothing is inserted, no synthesis or backend call is attempted
        (``in_band`` tells the near-hit story; ``dry_run`` marks the
        record as a what-if)."""
        req = Request(query=query, tenant=tenant, session=session,
                      explain=True)
        batch, _ = self.batcher.pad([req])
        tid = self._tenant_ids(batch)
        emb = jnp.asarray(self.embedder.embed_batch(
            [r.query for r in batch]))
        win, wlen, has_ctx = self._session_windows(batch)
        peek = self._peek_jit(self.runtime, emb, jnp.float32(self._now),
                              tid, win, wlen)
        why_ps, why_topk = self._why_snapshot(peek)
        tix = None if tid is None else int(np.asarray(tid)[0])
        edges = effective_edges(self.cache.policy, why_ps,
                                self.cache.partition, tix)
        why = build_why(
            0, request=req, hit=bool(np.asarray(peek.hit)[0]),
            near_served=False, score=float(np.asarray(peek.score)[0]),
            matched_slot=int(np.asarray(peek.index)[0]),
            matched_source_id=int(np.asarray(peek.source_id)[0]),
            topk_slots=why_topk["slots"][0],
            topk_scores=why_topk["scores"][0],
            topk_source_ids=why_topk["source_ids"][0],
            edges=edges, session_fused=bool(has_ctx[0]),
            synthesizer_present=False,
            near_band=bool(np.asarray(peek.near)[0]),
            synthesis_source_id=None)
        why["dry_run"] = True
        return why

    def serve_batch(self, batch: list[Request], *,
                    record_path_latency: bool = True,
                    traces: list | None = None) -> list[Response]:
        """Serve ONE admission batch: peek -> backend -> fused step commit.

        This is the pure device-side serve path (DESIGN.md §12.1): it does
        no re-batching of its own, so both the sync ``process()`` loop and
        the async continuous-batching scheduler drive it directly. On the
        fused path partial batches are right-padded to the fixed batch
        size (``Batcher.pad``); the ``valid`` mask keeps pad rows out of
        every counter, the judge, the metrics and the slab.

        ``record_path_latency=False`` skips the per-request hit/miss
        latency samples — the async scheduler records true end-to-end
        (queue wait + service) latencies itself instead of these
        batch-amortized service times.

        ``traces`` is an optional per-row list of ``RequestTrace``s (the
        async scheduler passes the entries' traces, already carrying their
        queue-side spans); engine stage spans are appended to each and the
        CALLER finishes them. When ``traces`` is None and the engine's
        tracer is collecting, serve_batch owns the traces itself: it
        starts one per real row and finishes it with the batch wall time
        (the sync ``process()`` path). When tracing is off there is no
        stage clock and no per-request allocation (§18.2).
        """
        n_valid = len(batch)
        if self.resilience is not None:
            # surface the resilience section in metrics summaries even
            # before the first fault (callers replace engine.metrics, so
            # this cannot live in __init__)
            self.metrics.resilience_seen = True
        clock = self.tracer.stage_clock()
        own_traces = False
        if clock is not None and traces is None:
            traces = [self.tracer.start() for _ in range(n_valid)]
            own_traces = True
        ev_stats0 = None
        if self.events is not None:
            ev_stats0 = {k: int(getattr(self.stats, k)) for k in
                         ("lookups", "hits", "misses", "inserts",
                          "expired_evictions")}
        if self.registry is not None and len(batch) > self.batcher.batch_size:
            # the per-tenant ring guarantees distinct slots only while a
            # batch's rows per tenant fit in the tenant's region, which the
            # constructor proved for batches up to batch_size; an oversized
            # admission batch (a mis-aligned SchedulerConfig.max_batch)
            # could silently collide slots, so fail loudly instead
            raise ValueError(
                f"tenant-partitioned engine got a {len(batch)}-row batch "
                f"but batch_size={self.batcher.batch_size}; align the "
                "scheduler's max_batch with the engine batch size "
                "(AsyncCacheServer's default config does)")
        if self.use_fused_step:
            batch, n_valid = self.batcher.pad(batch)
        cfg = self.cache.config
        n = len(batch)
        tid = self._tenant_ids(batch)
        if self.sessions is not None:
            # flush-path TTL sweep (§16.4): abandoned sessions die on the
            # next admission, not only if someone happens to touch them
            self.sessions.expire(self._now)
        t0 = time.perf_counter()
        emb = jnp.asarray(self.embedder.embed_batch([r.query for r in batch]))
        win, wlen, has_ctx = self._session_windows(batch)
        if clock is not None:
            clock.tick("embed")
        now = jnp.float32(self._now)
        self._maybe_refit()

        llm_time = 0.0
        llm_cost = 0.0
        answers: dict[int, str] = {}
        want_why = self.explain_all or any(
            batch[i].explain for i in range(n_valid))
        why_ps = why_topk = None

        if self.use_fused_step:
            # 1. pure peek: learn the miss set without committing any state
            #    (the only slab search this batch — step commits it, §7)
            peek = self._peek_jit(self.runtime, emb, now, tid, win, wlen)
            peek_hit = np.asarray(peek.hit)
            cache_time = time.perf_counter() - t0
            if clock is not None:
                clock.tick("device_step")
            if want_why:
                # attribution snapshot (§18.3) — BEFORE the fused step
                # donates the runtime and the policy feedback moves the
                # edges: these are the values the decision was made under
                why_ps, why_topk = self._why_snapshot(peek)
            # 1b. near-hit synthesis (§17.3): band rows the synthesizer
            #     converts skip the backend; abstained rows stay misses
            syn_by_row, syn_time, syn_cost = \
                self._synthesize_near(batch, n_valid, peek)
            if clock is not None:
                clock.tick("near_synthesis")
            miss_idx = [i for i in range(n_valid)
                        if not peek_hit[i] and i not in syn_by_row]
            # 2. backend answers the misses (paper §2.5 step 2). Failure
            #    containment (§20.2): a failed call marks only its own
            #    rows — hit/near rows of the same flush serve normally and
            #    the failed rows fall to degraded serving or an error row.
            miss_values = np.zeros((n, cfg.value_len), dtype=np.int32)
            miss_lens = np.zeros((n,), dtype=np.int32)
            failed, gen_idx = self._split_expired(batch, miss_idx)
            if gen_idx:
                out, err = self._resolve_misses(batch, gen_idx)
                if err is None:
                    toks, lens, answers, llm_time, llm_cost = out
                    miss_values[gen_idx] = np.asarray(toks)
                    miss_lens[gen_idx] = np.asarray(lens)
                else:
                    for i in gen_idx:
                        failed[i] = err
            degraded = self._serve_degraded(batch, failed, peek)
            if clock is not None:
                clock.tick("backend_call")
            # synthesized rows ride the same masked insert (insert mask is
            # ~hit, which includes band rows): the near-hit answer is
            # admitted under the query's own key (§17.4), carrying the
            # dominant neighbour's source_id as provenance
            sid_np = np.asarray([r.source_id for r in batch], dtype=np.int32)
            if syn_by_row:
                rows = sorted(syn_by_row)
                stoks, slens = self.tokenizer.encode_batch(
                    [syn_by_row[i].answer for i in rows], cfg.value_len)
                miss_values[rows] = np.asarray(stoks)
                miss_lens[rows] = np.asarray(slens)
                for j, i in enumerate(rows):
                    answers[i] = self.tokenizer.decode(stoks[j])
                    sid_np[i] = syn_by_row[i].source_id
            sid = jnp.asarray(sid_np)
            valid = np.zeros((n,), dtype=bool)
            valid[:n_valid] = True
            # failed AND degraded rows are never admitted (§20.4): a
            # cleared valid bit drops them from the step's insert mask and
            # every device counter, exactly like pad rows
            for i in failed:
                valid[i] = False
            # 3. one fused compiled step: commit the peek + masked insert
            t1 = time.perf_counter()
            result, self.runtime = self._step_jit(
                self.runtime, emb, jnp.asarray(miss_values),
                jnp.asarray(miss_lens), now, sid, peek, jnp.asarray(valid),
                tid, win, wlen)
            jax.block_until_ready(result.hit)  # count the commit in cache_time
            cache_time += time.perf_counter() - t1
            if clock is not None:
                clock.tick("insert")
            self._inserts_since_rebuild += \
                len(miss_idx) - len(failed) + len(syn_by_row)
        else:
            # reference path: pre-fuse once so the miss insert stores the
            # SAME fused key the lookup searched (parity with the fused
            # step, which fuses in-step)
            femb = emb if win is None else \
                self._fuse_jit(self.runtime, emb, win, wlen)
            result, self.runtime = self._lookup_jit(self.runtime, femb, now,
                                                    tid, None, None)
            lookup_hit = np.asarray(result.hit)
            cache_time = time.perf_counter() - t0
            if clock is not None:
                clock.tick("device_step")
            if want_why:
                why_ps, why_topk = self._why_snapshot(result)
            syn_by_row, syn_time, syn_cost = \
                self._synthesize_near(batch, n, result)
            if clock is not None:
                clock.tick("near_synthesis")
            miss_idx = [i for i in range(n)
                        if not lookup_hit[i] and i not in syn_by_row]
            # per-row insert payload: backend answers for misses, admitted
            # syntheses (§17.4) for converted band rows
            row_toks: dict[int, np.ndarray] = {}
            row_lens: dict[int, int] = {}
            row_sid: dict[int, int] = {}
            failed, gen_idx = self._split_expired(batch, miss_idx)
            if gen_idx:
                out, err = self._resolve_misses(batch, gen_idx)
                if err is None:
                    toks, lens, answers, llm_time, llm_cost = out
                    for j, i in enumerate(gen_idx):
                        row_toks[i] = np.asarray(toks[j])
                        row_lens[i] = int(lens[j])
                        row_sid[i] = batch[i].source_id
                else:
                    for i in gen_idx:
                        failed[i] = err
            # failed rows simply never enter row_toks, so the subset insert
            # below skips them (§20.4); unlike the fused path the mutating
            # lookup above already counted them — accepted on the
            # reference path
            degraded = self._serve_degraded(batch, failed, result)
            if clock is not None:
                clock.tick("backend_call")
            if syn_by_row:
                rows = sorted(syn_by_row)
                stoks, slens = self.tokenizer.encode_batch(
                    [syn_by_row[i].answer for i in rows], cfg.value_len)
                for j, i in enumerate(rows):
                    row_toks[i] = np.asarray(stoks[j])
                    row_lens[i] = int(slens[j])
                    row_sid[i] = syn_by_row[i].source_id
                    answers[i] = self.tokenizer.decode(np.asarray(stoks[j]))
            # one subset insert in row order — the same slot-assignment
            # order the fused step's masked full-batch insert produces
            ins = sorted(row_toks)
            if ins:
                memb = femb[jnp.asarray(ins)]
                sid = jnp.asarray([row_sid[i] for i in ins], dtype=jnp.int32)
                mtid = None if tid is None else tid[jnp.asarray(ins)]
                self.runtime = self._insert_jit(
                    self.runtime, memb,
                    jnp.asarray(np.stack([row_toks[i] for i in ins])),
                    jnp.asarray([row_lens[i] for i in ins], dtype=jnp.int32),
                    now, sid, jnp.ones((len(ins),), dtype=bool), mtid)
                self._inserts_since_rebuild += len(ins)
            if clock is not None:
                clock.tick("insert")

        if self.sessions is not None:
            self._append_turns(batch, n_valid,
                               self._canonical_keys(result, emb, win, wlen),
                               skip=failed)

        hit = np.asarray(result.hit)
        scores = np.asarray(result.score)
        matched_sid = np.asarray(result.source_id)
        near_row = np.asarray(result.near)
        near_served = np.zeros((n,), dtype=bool)
        for i in syn_by_row:
            near_served[i] = True

        # hit path: detokenize cached responses (real rows only)
        vals = np.asarray(result.values)
        for i in range(n_valid):
            if hit[i]:
                answers[i] = self.tokenizer.decode(vals[i])

        # judge hits (ground-truth oracle replaces GPT-4o-mini); pad rows
        # are never hits (valid-masked), so they contribute no feedback.
        # Synthesized near-hits are judged against their *synthesis*
        # provenance — the dominant neighbour's source_id (§17.3)
        positives = np.zeros((n,), dtype=bool)
        if self.judge is not None:
            for i in range(n_valid):
                if hit[i]:
                    positives[i] = self.judge(batch[i], int(matched_sid[i]))
                elif near_served[i]:
                    positives[i] = self.judge(
                        batch[i], int(syn_by_row[i].source_id))
            # adaptive-threshold feedback (paper §2.10): judged precision
            # nudges the threshold toward the target
            self.runtime = self.cache.update_policy(
                self.runtime,
                was_positive=jnp.asarray(positives & hit),
                was_hit=jnp.asarray(hit))
            if self.synthesizer is not None:
                # judged near-hit outcomes nudge the band's lower edge
                # (§17.2) — the near analogue of the adaptive threshold
                self.runtime = self.cache.update_band(
                    self.runtime,
                    was_positive=jnp.asarray(positives),
                    was_near=jnp.asarray(near_served))
        if self.judge is not None and degraded:
            # degraded answers are judged for OBSERVATION only (§20.4):
            # their precision is a brownout quality signal, but they never
            # feed the threshold/band adaptation — an outage must not move
            # the edges the healthy path serves under
            rm = self.metrics.resilience
            for i, d in degraded.items():
                rm.degraded_judged += 1
                if self.judge(batch[i], int(d[2])):
                    rm.degraded_positives += 1

        # metrics: baseline = every query pays the LLM call. Only the
        # n_valid real rows are recorded — pad rows must not move counters,
        # and neither do failed/degraded rows (§20.4): like the device-side
        # valid mask, the host accounting sees only the rows the cache
        # actually resolved; the fault path has its own counters.
        ok_rows = [i for i in range(n_valid) if i not in failed]
        per_call = getattr(self.backend, "latency_per_call_s", None)
        baseline_time = (per_call or (llm_time / max(len(miss_idx), 1))) \
            * len(ok_rows)
        per_cost = getattr(self.backend, "cost_per_call_usd", 0.0)
        self.metrics.record_batch(
            [batch[i].category for i in ok_rows],
            hit[ok_rows], positives[ok_rows],
            judged=[self.judge is not None
                    and (bool(hit[i]) or bool(near_served[i]))
                    for i in ok_rows],
            cache_time_s=cache_time, llm_time_s=llm_time + syn_time,
            llm_cost=llm_cost + syn_cost,
            baseline_cost=per_cost * len(ok_rows),
            baseline_time=baseline_time,
            tenants=None if self.registry is None else
            [batch[i].tenant for i in ok_rows],
            contexts=None if self.sessions is None else
            [has_ctx[i] for i in ok_rows],
            nears=None if self.synthesizer is None else near_row[ok_rows],
            near_served=None if self.synthesizer is None
            else near_served[ok_rows],
            syn_cost=syn_cost, syn_time=syn_time)

        whys = None
        if want_why:
            whys = self._build_whys(
                batch, n_valid, tid, hit, near_served, scores,
                np.asarray(result.index), matched_sid, near_row, has_ctx,
                syn_by_row, why_ps, why_topk)

        per_q_latency = (cache_time + llm_time + syn_time) / max(n_valid, 1)

        def _path_of(i: int) -> str:
            if i in degraded:
                return "degraded"
            if i in failed:
                return "error"
            if hit[i]:
                return "hit"
            return "near" if near_served[i] else "miss"

        if record_path_latency:
            for i in range(n_valid):
                self.metrics.record_latency(
                    _path_of(i), per_q_latency,
                    tenant=None if self.registry is None
                    else batch[i].tenant)

        def _mk_response(i: int) -> Response:
            tr_id = "" if traces is None or i >= len(traces) \
                else traces[i].trace_id
            w = None if whys is None else whys[i]
            if i in degraded:
                ans, sc, _sid = degraded[i]
                return Response(answer=ans, cached=False, score=sc,
                                latency_s=per_q_latency, context=has_ctx[i],
                                degraded=True, trace_id=tr_id, why=w)
            if i in failed:
                return Response(answer="", cached=False,
                                score=float(scores[i]),
                                latency_s=per_q_latency, context=has_ctx[i],
                                error=failed[i], trace_id=tr_id, why=w)
            return Response(answer=answers[i], cached=bool(hit[i]),
                            score=float(scores[i]), latency_s=per_q_latency,
                            context=has_ctx[i],
                            near_hit=bool(near_served[i]),
                            trace_id=tr_id, why=w)

        responses = [_mk_response(i) for i in range(n_valid)]
        if clock is not None:
            clock.tick("respond")
            if traces is not None:
                # engine spans tile serve_batch's wall time contiguously
                # (§18.1), so for the sync path span-sum == e2e by
                # construction; the scheduler prepends its queue-side
                # spans and finishes with the true arrival->resolve e2e
                batch_wall = sum(s.duration_s for s in clock.spans)
                for i in range(min(n_valid, len(traces))):
                    tr = traces[i]
                    if not tr:
                        continue
                    tr.spans.extend(clock.spans)
                    tr.annotate(row=i, batch_rows=n_valid,
                                path=_path_of(i))
                    if whys is not None and whys[i] is not None:
                        tr.why = whys[i]
                    if own_traces:
                        self.tracer.finish(tr, e2e_s=batch_wall)
        if self.events is not None:
            fault_kw = {} if not failed else {
                "failed": len(failed), "degraded": len(degraded)}
            self.events.emit(
                "serve_batch", rows=n_valid,
                hits=int(hit[:n_valid].sum()),
                near_hits=len(syn_by_row),
                backend_calls=len(miss_idx),
                cache_time_s=round(cache_time, 6),
                llm_time_s=round(llm_time + syn_time, 6),
                stats_delta={k: int(getattr(self.stats, k)) - ev_stats0[k]
                             for k in ev_stats0},
                **fault_kw)
        return responses
