"""AsyncCacheServer — the serving front-end that owns engine + scheduler.

Composes one ``CachedEngine`` with one ``AsyncScheduler`` (DESIGN.md §12)
behind two entry points:

  * **in-process**: ``await server.submit(...)`` / ``submit_request(...)``
    — what the load generators, benchmarks and tests drive;
  * **TCP (stdlib only)**: newline-delimited JSON over asyncio streams
    (``serve_tcp``) — one request object per line in, one response object
    per line out, pipelined: every line is scheduled as its own task, so a
    client that writes N lines before reading gets the same micro-batching
    and coalescing as N separate clients.

The wire format keeps to the engine's ``Request``/``Response`` fields::

    > {"id": 7, "query": "how do i sort a list in python",
       "category": "python_basics", "tenant": "acme"}
    < {"id": 7, "answer": ..., "cached": true, "score": 0.93,
       "latency_s": 0.004, "coalesced": false}

``tenant`` (optional, default "default") selects the isolation domain on a
multi-tenant engine (DESIGN.md §13): lookups/inserts stay inside that
tenant's slab region and coalescing never crosses tenants.

``session`` (optional) names a conversation on a session-enabled engine
(DESIGN.md §16): the request's lookup key is fused with the session's
prior-turn window, and the response line gains a ``context`` flag (true
when a non-empty window was fused in). A request line *without* the field
is today's stateless behaviour byte-for-byte — same Request defaults, same
response payload keys.

On a near-hit-enabled engine (a ``Synthesizer`` was attached, DESIGN.md
§17) every response line additionally carries ``near_hit`` (true when the
answer was synthesized from the band's top-k neighbours rather than served
verbatim or generated). Band-less engines emit exactly the pre-band
payload, byte for byte.

Responses may arrive out of request order (coalesced waiters resolve with
their leader's batch), so pipelined clients should send an ``id`` — it is
echoed verbatim in the matching response line.

Observability (DESIGN.md §18) rides the same additive discipline: a
request line with ``"explain": true`` gets ``why`` (decision attribution)
and ``trace_id`` on its response line; lines without the key get the
pre-observability payload byte for byte. ``GET /metrics`` — on the main
TCP port (sniffed off the first line) or on the dedicated
``serve_metrics`` HTTP listener — returns the Prometheus-style text
exposition; ``GET /traces`` and ``GET /events`` drain the retained
traces / the structured event ring as JSON lines.

Resilient serving (DESIGN.md §20) rides the same additive discipline:
``deadline_ms`` on a request line carries the caller's latency budget onto
``Request.deadline_ms`` (queue wait + retries never exceed it); on an
engine with a resilience config every response line gains ``degraded``
(true when the answer came from a cached neighbour because the backend
was unavailable); a shed rejection (``overload_policy="shed"``) answers
``{"error": ..., "overloaded": true}`` and a per-row backend failure
answers ``{"error": ...}`` for exactly the rows that needed the backend.

No third-party serving stack (HTTP frameworks, gRPC) is used — the repo's
offline constraint — but the seam is exactly where one would bolt on.
"""
from __future__ import annotations

import asyncio
import json

from repro.obs.export import MetricsExporter
from repro.serving.engine import CachedEngine, Request, Response
from repro.serving.resilience import Overloaded
from repro.serving.scheduler import AsyncScheduler, SchedulerConfig


class AsyncCacheServer:
    """Own the serving stack's lifecycle: start/stop, submit, TCP accept."""

    def __init__(self, engine: CachedEngine,
                 scheduler_config: SchedulerConfig | None = None):
        # one compiled shape end to end: the engine pads every admission
        # batch to its fixed batch size, so align it with the flush size
        cfg = scheduler_config or SchedulerConfig(
            max_batch=engine.batcher.batch_size)
        self.engine = engine
        self.scheduler = AsyncScheduler(engine, cfg)
        self.exporter = MetricsExporter(engine)
        self._tcp: asyncio.AbstractServer | None = None
        self._metrics_srv: asyncio.AbstractServer | None = None

    # -- lifecycle ------------------------------------------------------- #
    async def start(self) -> None:
        await self.scheduler.start()

    async def stop(self) -> None:
        if self._tcp is not None:
            self._tcp.close()
            await self._tcp.wait_closed()
            self._tcp = None
        if self._metrics_srv is not None:
            self._metrics_srv.close()
            await self._metrics_srv.wait_closed()
            self._metrics_srv = None
        await self.scheduler.stop()

    async def __aenter__(self) -> "AsyncCacheServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- in-process API --------------------------------------------------- #
    async def submit(self, query: str, *, category: str = "default",
                     source_id: int = -1, semantic_key: str = "",
                     tenant: str = "default", session: str = "",
                     explain: bool = False,
                     deadline_ms: float | None = None) -> Response:
        return await self.scheduler.submit(Request(
            query=query, category=category, source_id=source_id,
            semantic_key=semantic_key, tenant=tenant, session=session,
            explain=explain, deadline_ms=deadline_ms))

    async def submit_request(self, request: Request) -> Response:
        return await self.scheduler.submit(request)

    # -- TCP front-end ----------------------------------------------------- #
    async def serve_tcp(self, host: str = "127.0.0.1", port: int = 0) -> int:
        """Accept JSON-lines clients; returns the bound port (0 = ephemeral)."""
        self._tcp = await asyncio.start_server(self._handle, host, port)
        return self._tcp.sockets[0].getsockname()[1]

    # -- observability HTTP (stdlib-only, §18.4) ------------------------- #
    async def serve_metrics(self, host: str = "127.0.0.1",
                            port: int = 0) -> int:
        """Dedicated HTTP listener for ``/metrics`` / ``/traces`` /
        ``/events`` (``repro.launch.serve --metrics-port``). Returns the
        bound port (0 = ephemeral)."""
        async def handle(reader, writer):
            line = await reader.readline()
            if line:
                await self._serve_http(line, reader, writer)
            else:
                writer.close()
        self._metrics_srv = await asyncio.start_server(handle, host, port)
        return self._metrics_srv.sockets[0].getsockname()[1]

    def _http_body(self, path: str) -> tuple[str | None, str]:
        if path.rstrip("/") == "/metrics" or path == "/":
            return self.exporter.render(), "text/plain; version=0.0.4"
        if path.rstrip("/") == "/traces":
            lines = [json.dumps(t, sort_keys=True)
                     for t in self.engine.tracer.drain()]
            return ("\n".join(lines) + ("\n" if lines else ""),
                    "application/x-ndjson")
        if path.rstrip("/") == "/events":
            if self.engine.events is None:
                return "", "application/x-ndjson"
            return self.engine.events.to_jsonl(), "application/x-ndjson"
        return None, ""

    async def _serve_http(self, request_line: bytes,
                          reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        """Answer one HTTP/1.x GET and close — enough for any Prometheus-
        compatible scraper, with no HTTP framework (the offline constraint)."""
        try:
            parts = request_line.decode("latin-1").split()
            path = parts[1] if len(parts) > 1 else "/"
            while True:                       # drain request headers
                h = await reader.readline()
                if not h or h in (b"\r\n", b"\n"):
                    break
            body, ctype = self._http_body(path)
            status = "200 OK"
            if body is None:
                status, body, ctype = "404 Not Found", "not found\n", \
                    "text/plain"
            data = body.encode()
            writer.write(
                (f"HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n"
                 f"Content-Length: {len(data)}\r\n"
                 "Connection: close\r\n\r\n").encode() + data)
            await writer.drain()
        except (ConnectionError, OSError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        lock = asyncio.Lock()          # serialize writes, not serves

        async def one(line: bytes) -> None:
            req_id = None
            try:
                obj = json.loads(line)
                req_id = obj.get("id")
                resp = await self.submit(
                    obj["query"],
                    category=obj.get("category", "default"),
                    source_id=int(obj.get("source_id", -1)),
                    semantic_key=obj.get("semantic_key", ""),
                    tenant=obj.get("tenant", "default"),
                    session=obj.get("session", ""),
                    explain=bool(obj.get("explain", False)),
                    deadline_ms=None if obj.get("deadline_ms") is None
                    else float(obj["deadline_ms"]))
                payload = {"answer": resp.answer, "cached": resp.cached,
                           "score": resp.score, "latency_s": resp.latency_s,
                           "coalesced": resp.coalesced}
                if self.engine.resilience is not None:
                    # additive, gated on the resilience layer actually
                    # running — pre-§20 deployments keep the exact payload
                    payload["degraded"] = resp.degraded
                if "session" in obj:
                    # the context flag only exists for clients that opted
                    # into sessions — a sessionless request line gets
                    # exactly the pre-session payload, byte for byte
                    payload["context"] = resp.context
                if self.engine.synthesizer is not None:
                    # additive, gated on the server actually serving
                    # near-hits — band-less deployments keep the exact
                    # pre-band payload keys (§17.5)
                    payload["near_hit"] = resp.near_hit
                if obj.get("explain"):
                    # attribution is per-request opt-in (§18.3): only the
                    # lines that asked carry the extra keys, so non-opt-in
                    # clients keep the previous payload byte for byte
                    payload["why"] = resp.why
                    payload["trace_id"] = resp.trace_id
            except Overloaded as exc:  # shed (§20.5): explicit, retryable
                payload = {"error": str(exc), "overloaded": True}
            except Exception as exc:   # malformed line / scheduler stopped
                                       # / per-row BackendError (§20.2)
                payload = {"error": str(exc)}
            if req_id is not None:     # echo: responses can be out of order
                payload["id"] = req_id
            async with lock:
                writer.write(json.dumps(payload).encode() + b"\n")
                await writer.drain()

        # completed tasks discard themselves: a long-lived pipelined
        # connection must not accumulate one task object per line served
        tasks: set[asyncio.Task] = set()
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                if line.startswith(b"GET ") or line.startswith(b"HEAD "):
                    # /metrics scrape on the main port: an HTTP request
                    # line is never valid JSON, so the sniff is unambiguous
                    await self._serve_http(line, reader, writer)
                    return
                if line.strip():
                    t = asyncio.create_task(one(line))
                    tasks.add(t)
                    t.add_done_callback(tasks.discard)
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass
