"""Deterministic hash n-gram embedder — the offline MiniLM stand-in.

Signed feature hashing of word unigrams/bigrams and character 3/4-grams
into R^dim, TF-weighted, L2-normalized. Paraphrases share most n-grams so
their cosine similarity is high; unrelated queries share few. For this
workload (short customer-service queries with lexical paraphrase
perturbations) it reproduces the similarity *structure* the paper obtains
from all-MiniLM-L6-v2 — the substitution is recorded in DESIGN.md §9.

Everything is numpy (embedding happens host-side in the serving engine,
exactly as the paper calls an external embedding API), with a jnp batch
path for the fused device-side pipeline.
"""
from __future__ import annotations

import hashlib
import re

import numpy as np

_WORD = re.compile(r"\w+")


def _h(s: str, salt: int) -> int:
    return int.from_bytes(
        hashlib.blake2s(s.encode(), digest_size=8, salt=salt.to_bytes(8, "little")
                        ).digest(), "little")


class HashEmbedder:
    """text -> R^dim unit vector. Stateless and deterministic."""

    def __init__(self, dim: int = 384, char_ngrams: tuple[int, ...] = (3, 4),
                 word_weight: float = 1.0, char_weight: float = 0.7):
        self.dim = dim
        self.char_ngrams = char_ngrams
        self.word_weight = word_weight
        self.char_weight = char_weight

    def _features(self, text: str) -> dict[int, float]:
        text = text.lower().strip()
        words = _WORD.findall(text)
        feats: dict[int, float] = {}

        def add(tok: str, w: float):
            idx = _h(tok, 1) % self.dim
            sign = 1.0 if _h(tok, 2) & 1 else -1.0
            feats[idx] = feats.get(idx, 0.0) + sign * w

        for w_ in words:
            add("w:" + w_, self.word_weight)
        for a, b in zip(words, words[1:]):
            add("b:" + a + "_" + b, self.word_weight * 0.8)
        joined = " ".join(words)
        for n in self.char_ngrams:
            for i in range(len(joined) - n + 1):
                add(f"c{n}:" + joined[i:i + n], self.char_weight / max(len(joined), 1) * 10)
        return feats

    def embed(self, text: str) -> np.ndarray:
        v = np.zeros((self.dim,), dtype=np.float32)
        for idx, val in self._features(text).items():
            v[idx] += val
        n = np.linalg.norm(v)
        return v / max(n, 1e-12)

    def embed_batch(self, texts) -> np.ndarray:
        return np.stack([self.embed(t) for t in texts])
