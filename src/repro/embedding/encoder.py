"""MiniLM-class sentence-embedding encoder in JAX (the paper's "local
ONNX model" path, §2.2).

A 6-layer bidirectional transformer (384-dim, 12 heads — the
all-MiniLM-L6-v2 geometry the paper uses for its experiments) with mean
pooling over non-pad positions and L2 normalization, exactly the paper's
"normalized and pooled" recipe. Weights are randomly initialized (no
checkpoint downloads offline); the paper-metric experiments therefore use
the deterministic ``HashEmbedder`` (DESIGN.md §9) while this module provides
the production embedding path and is exercised by tests and the dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    vocab: int = 32768
    d_model: int = 384
    n_layers: int = 6
    n_heads: int = 12
    d_ff: int = 1536
    max_len: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


MINILM_L6 = EncoderConfig()


def init_encoder_params(rng: Array, cfg: EncoderConfig = MINILM_L6) -> dict:
    ks = jax.random.split(rng, 8)
    d, ff, l = cfg.d_model, cfg.d_ff, cfg.n_layers

    def nrm(key, shape, scale):
        return jax.random.normal(key, shape, dtype=jnp.float32) * scale

    return {
        "embed": nrm(ks[0], (cfg.vocab, d), 0.02),
        "pos_embed": nrm(ks[1], (cfg.max_len, d), 0.02),
        "blocks": {
            "norm1": jnp.ones((l, d)),
            "wqkv": nrm(ks[2], (l, d, 3 * d), d ** -0.5),
            "wo": nrm(ks[3], (l, d, d), d ** -0.5),
            "norm2": jnp.ones((l, d)),
            "w1": nrm(ks[4], (l, d, ff), d ** -0.5),
            "w2": nrm(ks[5], (l, ff, d), ff ** -0.5),
        },
        "final_norm": jnp.ones((d,)),
    }


def encode(params: dict, tokens: Array, lengths: Array,
           cfg: EncoderConfig = MINILM_L6) -> Array:
    """tokens (B, L) int32, lengths (B,) -> (B, d) unit embeddings."""
    b, l = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    mask = jnp.arange(l)[None, :] < lengths[:, None]          # (B, L)
    x = params["embed"][tokens] + params["pos_embed"][:l][None]

    def body(x, lp):
        xn = rms_norm(x, lp["norm1"])
        qkv = jnp.einsum("bld,de->ble", xn, lp["wqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, l, h, hd)
        k = k.reshape(b, l, h, hd)
        v = v.reshape(b, l, h, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
        s = jnp.where(mask[:, None, None, :], s, -1e30)       # bidirectional
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, l, cfg.d_model)
        x = x + jnp.einsum("bld,de->ble", o, lp["wo"])
        xn = rms_norm(x, lp["norm2"])
        y = jnp.einsum("bld,df->blf", xn, lp["w1"])
        y = jnp.einsum("blf,fd->bld", jax.nn.gelu(y), lp["w2"])
        return x + y, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"])
    # mean pooling over valid positions + L2 norm (paper §2.2)
    m = mask[..., None].astype(x.dtype)
    pooled = jnp.sum(x * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-12)
