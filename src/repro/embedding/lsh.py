"""SimHash LSH for host-side near-duplicate detection (DESIGN.md §12.3).

The scheduler's in-flight coalescing dedups by normalized query *text*; a
paraphrase arriving one millisecond behind its leader misses the window
and pays its own lookup/backend call. This module is the cheap host-side
bridge to *embedding-similarity* coalescing: random-hyperplane signatures
(Charikar 2002) bucket unit vectors so that the collision probability per
bit is ``1 - θ/π`` — near-duplicates collide in some table with high
probability, unrelated queries rarely do.

The LSH is a **prefilter only**: a bucket collision nominates candidates,
and the caller must verify true cosine similarity against its threshold
before coalescing (the scheduler does — ``_try_attach_similar``). That
two-step shape is what makes the guarantee one-sided: a missed collision
just forfeits a dedup (correctness unaffected), while a false collision
is caught by the exact cosine check, so distinct-meaning queries can
never share a leader.

Multiple short-signature tables (default 6 tables x 10 bits) trade a few
hundred bytes of state for recall: P[collide in >=1 table] =
``1 - (1 - p^bits)^tables``, ~0.97 for cosine 0.9 pairs at the defaults,
while cosine 0.5 pairs collide in <2% of submissions — and those few are
rejected by the verification step anyway.
"""
from __future__ import annotations

import numpy as np


class SimHashLSH:
    """Random-hyperplane signatures over unit vectors. Deterministic for a
    given (dim, tables, bits, seed) — two processes agree on buckets."""

    def __init__(self, dim: int, *, n_tables: int = 6, n_bits: int = 10,
                 seed: int = 1234):
        if n_tables < 1 or n_bits < 1 or n_bits > 62:
            raise ValueError("need n_tables >= 1 and 1 <= n_bits <= 62")
        rng = np.random.default_rng(seed)
        # (T, bits, dim) hyperplane normals; one sign pattern per table
        self.planes = rng.standard_normal(
            (n_tables, n_bits, dim)).astype(np.float32)
        self.n_tables = n_tables
        self.n_bits = n_bits
        self._weights = (1 << np.arange(n_bits, dtype=np.int64))

    def buckets(self, vec: np.ndarray) -> tuple[int, ...]:
        """One packed bucket id per table for a single vector."""
        bits = (self.planes @ np.asarray(vec, dtype=np.float32)) > 0.0
        return tuple(int(b) for b in (bits @ self._weights))


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    """Exact cosine for the verification step (safe on zero vectors)."""
    na = float(np.linalg.norm(a))
    nb = float(np.linalg.norm(b))
    if na == 0.0 or nb == 0.0:
        return 0.0
    return float(np.dot(a, b) / (na * nb))
