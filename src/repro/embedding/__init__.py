"""Embedding generation (paper §2.2): hash featurizer + JAX MiniLM-class
encoder. Both produce L2-normalized vectors compatible with the cache."""
from repro.embedding.hash_embedder import HashEmbedder
from repro.embedding.encoder import (EncoderConfig, MINILM_L6, encode,
                                     init_encoder_params)
from repro.embedding.lsh import SimHashLSH, cosine

__all__ = ["HashEmbedder", "EncoderConfig", "MINILM_L6", "encode",
           "init_encoder_params", "SimHashLSH", "cosine"]
