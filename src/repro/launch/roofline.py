"""Roofline-term derivation from dry-run artifacts (the §Roofline report).

Hardware model: TPU v5e —
  peak bf16 compute   197 TFLOP/s per chip
  HBM bandwidth       819 GB/s per chip
  ICI                 ~50 GB/s per link per direction

Terms per (arch, shape, mesh):
  compute    = HLO_FLOPs / (chips · peak)
  memory     = HLO_bytes / (chips · HBM_bw)
  collective = collective_bytes / (chips · link_bw)

cost_analysis() reports *global* (all-partition) flops for the SPMD module;
collective bytes are parsed per-module (one partition) and multiplied by
the chip count for the global figure, then normalized per chip again — the
two normalizations cancel, so the term below divides the per-partition
payload by the per-chip link bandwidth directly.

MODEL_FLOPS = 6·N·D (dense; N = params, D = tokens processed) or 6·N_active·D
for MoE — the "useful compute" yardstick; MODEL_FLOPS / HLO_FLOPs exposes
remat/dispatch overhead.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per direction)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # global FLOPs (1e9)
    hlo_gbytes: float            # global HBM bytes (1e9)
    collective_gbytes: float     # per-chip collective payload (1e9)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_gflops: float
    useful_ratio: float
    note: str = ""

    def row(self) -> dict:
        return dataclasses.asdict(self)


def tokens_processed(shape_kind: str, global_batch: int, seq_len: int) -> int:
    if shape_kind == "train":
        return global_batch * seq_len
    if shape_kind == "prefill":
        return global_batch * seq_len
    return global_batch  # decode: one token per sequence


def model_flops(n_active_params: int, n_tokens: int, train: bool) -> float:
    """6·N·D for training (fwd+bwd); 2·N·D for inference forward."""
    mult = 6.0 if train else 2.0
    return mult * n_active_params * n_tokens


# --------------------------------------------------------------------------- #
# Analytic FLOP / HBM models.
#
# XLA's cost_analysis() counts while-loop (scan) bodies ONCE — orders of
# magnitude off for scanned-layer models — so the compute and memory roofline
# terms use these closed-form models (standard MFU-style accounting, formulas
# below), with the raw HLO numbers kept in the artifacts as a cross-check.
# Collective traffic uses the loop-aware HLO walk in hlo_analysis.py.
# --------------------------------------------------------------------------- #

def analytic_flops(config, shape, cache_size: int | None = None) -> float:
    """Global FLOPs for one step of this (arch, shape)."""
    c = config
    dec = shape.kind == "decode"
    l_ctx_positions = cache_size if dec else shape.seq_len
    tokens = shape.global_batch * (1 if dec else shape.seq_len)
    d = c.d_model

    # per-layer window table (hybrid archs mix SWA and global)
    from repro.models.model import Model
    wins = Model(c)._window_list()

    def attn_ctx(win: int) -> float:
        full = l_ctx_positions if dec else shape.seq_len / 2.0
        if win and win > 0:
            return min(win, full)
        return full

    per_tok = 0.0
    for w in wins if c.has_attention else []:
        hq, hkv, dh = c.n_heads, c.n_kv_heads, c.head_dim
        per_tok += 2 * d * (2 * hq * dh + 2 * hkv * dh)      # qkvo projections
        per_tok += 4 * attn_ctx(w) * hq * dh                 # scores + values
    if c.has_ssm:
        from repro.models.ssm import ssm_dims
        dims = ssm_dims(c)
        h, p, n, q = dims["nheads"], dims["headdim"], dims["state"], c.ssm_chunk
        per_layer = (2 * d * dims["in_dim"] + 2 * dims["d_inner"] * d
                     + 2 * c.ssm_conv * dims["conv_dim"])
        if dec:
            per_layer += 5 * h * p * n                        # recurrent step
        else:
            per_layer += (q / 2) * h * (2 * n + 2 * p) + 5 * h * p * n
        per_tok += per_layer * c.n_layers
    n_moe = c.n_layers // c.moe_interleave if c.is_moe else 0
    n_dense_ffn = (c.n_layers - n_moe) if c.d_ff > 0 else 0
    per_tok += n_dense_ffn * 2 * 3 * d * c.d_ff
    if c.is_moe:
        per_tok += n_moe * (2 * 3 * d * c.d_ff * c.moe_topk + 2 * d * c.n_experts)

    head_tokens = tokens if shape.kind == "train" else shape.global_batch
    head = 2 * d * c.padded_vocab * head_tokens * c.n_codebooks

    fwd = per_tok * tokens + head
    if shape.kind == "train":
        return 4.0 * fwd          # fwd + bwd(2x) + remat re-fwd
    return fwd


def analytic_hbm_bytes_per_chip(config, shape, n_dp: int, n_mp: int,
                                cache_size: int | None = None,
                                kv_bytes: int = 2) -> float:
    """Per-chip HBM traffic (bytes) for one step."""
    c = config
    chips = n_dp * n_mp
    dec = shape.kind == "decode"
    p_bytes = c.param_count() * 2                            # bf16
    p_local = p_bytes / chips                                # FSDP+TP resident
    p_gathered = p_bytes / n_mp                              # after dp all-gather
    tokens_local = shape.global_batch * (1 if dec else shape.seq_len) / n_dp
    act = tokens_local * c.d_model * 2 * c.n_layers * 10     # activation traffic

    if shape.kind == "train":
        # fwd + remat-fwd + bwd weight reads (gathered), moments r/w (f32 x2),
        # grads reduce + param update
        moments = c.param_count() * 4 * 2 / chips
        return 3 * 2 * p_gathered + 2 * moments * 2 + 2 * p_local + act * 3
    if shape.kind == "prefill":
        kv_write = (c.n_layers * shape.global_batch * shape.seq_len
                    * c.n_kv_heads * c.head_dim * 2 * 2 / chips
                    if c.has_attention else 0.0)
        return 2 * p_gathered + act + kv_write
    # decode: weights stay *stationary* (GSPMD chooses activation psums over
    # weight gathers at one-token batches — confirmed in the compiled HLO:
    # decode collective traffic is ~activation-sized), so each chip reads its
    # resident 2D shard once per token + its local KV slice.
    kv = 0.0
    if c.has_attention and cache_size:
        # read + write; kv_bytes=1 for the int8-quantized cache (+ f32
        # scales, 4/head_dim per element)
        per_elem = kv_bytes + 4.0 / c.head_dim
        kv = (c.n_layers * shape.global_batch * cache_size
              * c.n_kv_heads * c.head_dim * per_elem * 2) / chips
    ssm_bytes = 0.0
    if c.has_ssm:
        from repro.models.ssm import ssm_dims
        dims = ssm_dims(c)
        ssm_bytes = (c.n_layers * shape.global_batch * dims["nheads"]
                     * dims["headdim"] * dims["state"] * 4 * 2) / max(n_dp, 1)
    return p_local + kv + ssm_bytes + act


def derive(arch: str, shape_name: str, shape_kind: str, mesh_name: str,
           chips: int, flops: float, bytes_accessed: float,
           collective_bytes_per_chip: float, n_active_params: int,
           global_batch: int, seq_len: int, note: str = "") -> RooflineTerms:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (chips * HBM_BW)
    collective_s = collective_bytes_per_chip / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda t: t[1])[0]
    n_tok = tokens_processed(shape_kind, global_batch, seq_len)
    mf = model_flops(n_active_params, n_tok, train=shape_kind == "train")
    return RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=bytes_accessed / 1e9,
        collective_gbytes=collective_bytes_per_chip / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom, model_gflops=mf / 1e9,
        useful_ratio=(mf / flops) if flops else 0.0, note=note)
