"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

THE FIRST TWO LINES below must run before any other import — jax locks the
device count on first initialization, and the production meshes need 512
placeholder host devices.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_arch, get_shape
from repro.core.cache import SemanticCache
from repro.core.distributed import DistributedCache
from repro.core.types import CacheConfig
from repro.launch import sharding as shlib
from repro.launch.hlo_analysis import collective_stats, op_histogram
from repro.launch.mesh import (data_axes_of, make_production_mesh,
                               model_axes_of)
from repro.launch.roofline import derive
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")

ADAMW = AdamWConfig()


def _named(mesh, spec_tree):
    is_p = lambda x: isinstance(x, P) or x is None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=is_p)


def build_fn_and_args(arch_name: str, shape_name: str, mesh, variant: str = ""):
    """Returns (jitted_fn, args_SDS_tuple) for one (arch, shape, mesh).

    ``variant`` selects §Perf optimization knobs: "attn" = explicit attention
    sharding constraints; "attn-sp" = + sequence-parallel residuals.
    """
    config = get_arch(arch_name)
    shape = get_shape(shape_name)
    dp = data_axes_of(mesh)
    mp = model_axes_of(mesh)
    remat_policy = "full"
    if "dots" in variant:
        remat_policy = "dots"
    elif "noremat" in variant:
        remat_policy = "none"
    model = Model(config, mesh=mesh, data_axes=dp, model_axes=mp,
                  opt_attn_sharding="attn" in variant,
                  opt_seq_parallel="sp" in variant,
                  remat_policy=remat_policy)

    pspec = shlib.param_pspecs(config, dp)
    params_sds = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    in_specs = shlib.input_specs(config, shape)
    bspecs = shlib.batch_pspecs(config, shape, dp)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(lambda p: init_adamw(p), params_sds)
        ospec = shlib.opt_pspecs(pspec)

        def train_step(params, opt_state, batch):
            def loss(p):
                return model.loss_fn(p, batch["tokens"],
                                     prefix_emb=batch.get("prefix_emb"),
                                     remat=True)
            loss_v, grads = jax.value_and_grad(loss)(params)
            params, opt_state, metrics = adamw_update(
                ADAMW, params, grads, opt_state)
            return params, opt_state, loss_v

        fn = jax.jit(
            train_step,
            in_shardings=(_named(mesh, pspec), _named(mesh, ospec),
                          _named(mesh, bspecs)),
            out_shardings=(_named(mesh, pspec), _named(mesh, ospec),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1))
        return fn, (params_sds, opt_sds, in_specs)

    if shape.kind == "prefill":
        cache_size = shape.seq_len

        def prefill(params, batch):
            logits, caches, _ = model.forward(
                params, batch["tokens"],
                prefix_emb=batch.get("prefix_emb"),
                collect_cache=True, cache_size=cache_size,
                logits_last_only=True)
            return logits, caches

        cache_spec = shlib.decode_cache_pspecs(config, shape.global_batch, dp)
        out_spec = (NamedSharding(mesh, P(dp if _div(shape.global_batch, mesh, dp)
                                          else None, None, None)),
                    _named(mesh, cache_spec))
        fn = jax.jit(prefill,
                     in_shardings=(_named(mesh, pspec), _named(mesh, bspecs)),
                     out_shardings=out_spec)
        return fn, (params_sds, in_specs)

    # decode
    kvq = "kvq" in variant
    cache_sds = shlib.decode_cache_specs(config, shape, quantized=kvq)
    cache_spec = shlib.decode_cache_pspecs(config, shape.global_batch, dp,
                                           quantized=kvq)
    bspec = dp if _div(shape.global_batch, mesh, dp) else None

    def decode(params, caches, batch):
        logits, caches = model.decode_step(params, caches, batch["tokens"])
        return logits, caches

    ndim_logits = 4 if config.n_codebooks > 1 else 3
    logits_spec = NamedSharding(mesh, P(bspec, *([None] * (ndim_logits - 1))))
    fn = jax.jit(decode,
                 in_shardings=(_named(mesh, pspec), _named(mesh, cache_spec),
                               _named(mesh, {"tokens": P(bspec, None, None)
                                             if config.n_codebooks > 1
                                             else P(bspec, None)})),
                 out_shardings=(logits_spec, _named(mesh, cache_spec)),
                 donate_argnums=(1,))
    return fn, (params_sds, cache_sds, in_specs)


def _div(n, mesh, axes):
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return n % total == 0 and n >= total


# --------------------------------------------------------------------------- #
# semantic-cache dry-run (the paper's technique on the production mesh)
# --------------------------------------------------------------------------- #

def build_cache_fn(mesh, *, capacity: int = 1_048_576, batch: int = 256,
                   dim: int = 384, variant: str = ""):
    cfg = CacheConfig(dim=dim, capacity=capacity, value_len=64, ttl=3600.0,
                      threshold=0.8,
                      key_dtype=jnp.int8 if "int8" in variant else jnp.float32)
    dc = DistributedCache(SemanticCache(cfg), mesh,
                          cache_axes=data_axes_of(mesh))
    runtime_sds = jax.eval_shape(dc.cache.init)  # full CacheRuntime pytree
    fn = dc.make_lookup_insert()
    args = (runtime_sds,
            jax.ShapeDtypeStruct((batch, dim), jnp.float32),
            jax.ShapeDtypeStruct((batch, 64), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32))
    return fn, args


# --------------------------------------------------------------------------- #
# artifact extraction
# --------------------------------------------------------------------------- #

def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def run_one(arch_name: str, shape_name: str, *, multi_pod: bool,
            out_dir: str = ARTIFACT_DIR, verbose: bool = True,
            variant: str = "") -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tag = f"{arch_name}_{shape_name}_{mesh_name}"
    if variant:
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            if verbose:
                print(f"[skip] {tag} (cached)")
            return art

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    config = get_arch(arch_name)
    shape = get_shape(shape_name)
    art: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "ok": False}
    t0 = time.time()
    art["variant"] = variant
    try:
        fn, args = build_fn_and_args(arch_name, shape_name, mesh, variant)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = _cost_dict(compiled)
        mem = _memory_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_stats(hlo)
        hist = op_histogram(hlo)

        from repro.launch.roofline import (analytic_flops,
                                           analytic_hbm_bytes_per_chip)
        from repro.launch.sharding import decode_cache_size
        n_mp = mesh.shape["model"]
        n_dp = chips // n_mp
        csize = decode_cache_size(config, shape) if shape.kind == "decode" \
            else None
        a_flops = analytic_flops(config, shape, cache_size=csize)
        a_bytes = analytic_hbm_bytes_per_chip(
            config, shape, n_dp, n_mp, cache_size=csize,
            kv_bytes=1 if "kvq" in variant else 2)
        art.update(ok=True, lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), cost=cost, memory=mem,
                   collectives=coll, op_histogram=hist,
                   flops=a_flops,                       # analytic (loop-true)
                   bytes_accessed=a_bytes * chips,      # analytic, global
                   hlo_flops=cost.get("flops", 0.0),    # raw XLA (loops x1)
                   hlo_bytes=cost.get("bytes accessed", 0.0),
                   active_params=config.active_param_count(),
                   total_params=config.param_count())
        rt = derive(arch_name, shape_name, shape.kind, mesh_name, chips,
                    flops=a_flops, bytes_accessed=a_bytes * chips,
                    collective_bytes_per_chip=coll["total_bytes"],
                    n_active_params=config.active_param_count(),
                    global_batch=shape.global_batch, seq_len=shape.seq_len)
        art["roofline"] = rt.row()
        if verbose:
            print(f"[ok] {tag}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"flops {art['flops']:.3g} coll {coll['total_bytes']:.3g}B "
                  f"dominant={rt.dominant}")
    except Exception as e:  # noqa: BLE001 — record the failure in the artifact
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[FAIL] {tag}: {art['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def run_cache(multi_pod: bool, out_dir: str = ARTIFACT_DIR,
              variant: str = "") -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tag = f"semantic-cache_lookup-insert_{mesh_name}"
    if variant:
        tag += f"__{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            return art
    mesh = make_production_mesh(multi_pod=multi_pod)
    art = {"arch": "semantic-cache", "shape": "lookup-insert",
           "mesh": mesh_name, "chips": int(mesh.devices.size), "ok": False}
    t0 = time.time()
    try:
        capacity, batch, dim = 1_048_576, 256, 384
        fn, args = build_cache_fn(mesh, capacity=capacity, batch=batch,
                                  dim=dim, variant=variant)
        compiled = fn.lower(*args).compile()
        cost = _cost_dict(compiled)
        coll = collective_stats(compiled.as_text())
        n_dp = art["chips"] // mesh.shape["model"]
        key_bytes = 1 if "int8" in variant else 4
        slab_local = capacity // n_dp * dim * key_bytes
        terms = {
            "compute_s": 2 * batch * (capacity // n_dp) * dim / 197e12,
            "memory_s": slab_local / 819e9,
            "collective_s": coll["total_bytes"] / 50e9,
        }
        terms["dominant"] = max(terms, key=lambda k: terms[k]
                                if k.endswith("_s") else -1).replace("_s", "")
        art.update(ok=True, compile_s=round(time.time() - t0, 2), cost=cost,
                   collectives=coll, memory=_memory_dict(compiled),
                   roofline=terms, variant=variant)
        print(f"[ok] {tag}")
    except Exception as e:  # noqa: BLE001
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {tag}: {art['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, help="input shape id (or all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 10 archs x 4 shapes (+ cache) on this mesh")
    ap.add_argument("--cache", action="store_true",
                    help="dry-run the distributed semantic cache step")
    ap.add_argument("--variant", default="",
                    help="perf variant: attn | attn-sp (see §Perf)")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    results = []
    for mp in meshes:
        if args.cache or args.all:
            results.append(run_cache(mp, args.out, variant=args.variant))
        if args.all:
            for arch in ARCHITECTURES:
                for shape in INPUT_SHAPES:
                    results.append(run_one(arch, shape, multi_pod=mp,
                                           out_dir=args.out))
        elif args.arch:
            shapes = list(INPUT_SHAPES) if args.shape in (None, "all") \
                else [args.shape]
            for shape in shapes:
                results.append(run_one(args.arch, shape, multi_pod=mp,
                                       out_dir=args.out,
                                       variant=args.variant))
    n_ok = sum(r.get("ok", False) for r in results)
    print(f"\n{n_ok}/{len(results)} dry-runs succeeded")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
