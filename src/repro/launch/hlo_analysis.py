"""Parse compiled/optimized HLO text for roofline inputs.

``cost_analysis()`` reports FLOPs/bytes but (a) does not include collective
traffic and (b) counts ``while`` bodies ONCE instead of trip_count times —
fatal for scan-over-layers models. This module recovers honest collective
traffic with a *loop-aware* walk of the HLO call graph:

  1. split the module text into computations,
  2. sum collective payload bytes per computation (all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute),
  3. propagate along call edges (fusion ``calls=``, while ``body=`` /
     ``condition=``, conditional branches), multiplying while bodies by the
     ``known_trip_count`` XLA attaches to unrolled-scan loops.

Per-chip traffic convention (ring algorithms): all-gather counts its result
size, reduce-scatter its operand size, all-to-all its operand size, and
all-reduce 2x operand (reduce-scatter + all-gather phases); the (n-1)/n
ring factor is folded to 1.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_MULT = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_KIND_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    entry: str | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    comps["__entry__"] = [entry or ""]
    return comps


def _line_collective(line: str) -> tuple[str, int] | None:
    """Returns (kind, payload bytes) if this line is a collective op."""
    if "-done(" in line:        # async pair: payload counted at -start
        if any(k + "-done(" in line for k in _COLLECTIVE_MULT):
            return None
    m = _KIND_RE.search(line)
    if not m:
        return None
    kind = m.group(1)
    lhs = line.split("=", 1)[0]
    shapes = _SHAPE_RE.findall(lhs) or _SHAPE_RE.findall(line)
    if not shapes:
        return None
    nbytes = max(_shape_bytes(dt, dims) for dt, dims in shapes)
    if kind == "reduce-scatter" or kind == "all-to-all":
        # operand is the larger side for RS; payload ~ operand size
        rhs_shapes = _SHAPE_RE.findall(line.split("=", 1)[1])
        if rhs_shapes:
            nbytes = max(nbytes, max(_shape_bytes(dt, dims)
                                     for dt, dims in rhs_shapes))
    return kind, nbytes


def collective_stats(hlo_text: str) -> dict:
    """Loop-aware collective traffic per chip. See module docstring."""
    comps = _split_computations(hlo_text)
    entry = comps.pop("__entry__")[0]

    direct_bytes: dict[str, dict[str, float]] = {}
    direct_count: dict[str, dict[str, int]] = {}
    edges: dict[str, list[tuple[str, float]]] = {}
    for name, lines in comps.items():
        db: dict[str, float] = defaultdict(float)
        dc: dict[str, int] = defaultdict(int)
        ed: list[tuple[str, float]] = []
        for line in lines:
            col = _line_collective(line)
            if col:
                kind, nbytes = col
                db[kind] += nbytes * _COLLECTIVE_MULT[kind]
                dc[kind] += 1
            mult = 1.0
            if "while(" in line:
                t = _TRIP_RE.search(line)
                mult = float(t.group(1)) if t else 1.0
            for callee in _CALL_RE.findall(line):
                ed.append((callee, mult))
            bm = _BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    ed.append((b.strip().lstrip("%"), 1.0))
        direct_bytes[name] = db
        direct_count[name] = dc
        edges[name] = ed

    memo: dict[str, dict[str, float]] = {}
    cmemo: dict[str, dict[str, float]] = {}
    visiting: set[str] = set()

    def total(name: str) -> tuple[dict[str, float], dict[str, float]]:
        if name in memo:
            return memo[name], cmemo[name]
        if name in visiting or name not in direct_bytes:
            return {}, {}
        visiting.add(name)
        agg = defaultdict(float, direct_bytes[name])
        cagg = defaultdict(float, direct_count[name])
        for callee, mult in edges.get(name, ()):
            sub_b, sub_c = total(callee)
            for k, v in sub_b.items():
                agg[k] += mult * v
            for k, v in sub_c.items():
                cagg[k] += mult * v
        visiting.discard(name)
        memo[name] = dict(agg)
        cmemo[name] = dict(cagg)
        return memo[name], cmemo[name]

    agg, cagg = total(entry) if entry else ({}, {})
    return {
        "bytes": {k: float(v) for k, v in agg.items()},
        "count": {k: float(v) for k, v in cagg.items()},
        "total_bytes": float(sum(agg.values())),
        "static_count": {
            k: sum(direct_count[c].get(k, 0) for c in direct_count)
            for k in _COLLECTIVE_MULT},
    }


def op_histogram(hlo_text: str, ops=("fusion", "dot", "scatter", "gather",
                                     "while", "reshape", "transpose", "copy")
                 ) -> dict[str, int]:
    hist: dict[str, int] = {}
    for op in ops:
        hist[op] = len(re.findall(rf"=\s*\S*\s*{op}\(", hlo_text))
    return hist


def while_trip_counts(hlo_text: str) -> list[int]:
    return [int(m) for m in _TRIP_RE.findall(hlo_text)]
